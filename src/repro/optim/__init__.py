from repro.optim.optimizers import (  # noqa: F401
    GradientTransform,
    OptState,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    constant_schedule,
    global_norm,
)
