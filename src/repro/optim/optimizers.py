"""Functional optimizers (optax-style, no external deps).

Production notes:
  * `m_dtype`/`v_dtype` let large models keep the first moment in bf16 —
    this is what fits grok-1's optimizer state on a 16 GB/chip v5e pod
    (DESIGN.md §6); the update math always runs in fp32.
  * The update is pure and pjit-friendly: state is a pytree mirroring the
    params, so any param sharding rule automatically shards the state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def constant_schedule(value: float) -> Schedule:
    return lambda step: jnp.asarray(value, dtype=jnp.float32)


def cosine_schedule(peak: float, warmup_steps: int, total_steps: int,
                    floor: float = 0.0) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup_steps)
        t = (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps)
        t = jnp.clip(t, 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class GradientTransform:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]


def adamw(
    learning_rate: Schedule | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    m_dtype: jnp.dtype | None = None,
    v_dtype: jnp.dtype | None = None,
    max_grad_norm: float | None = None,
) -> GradientTransform:
    sched = learning_rate if callable(learning_rate) else constant_schedule(learning_rate)

    def init(params):
        mu = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=m_dtype or p.dtype), params)
        nu = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=v_dtype or jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr = sched(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
            u = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        deltas = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1] for o in out])
        nu = treedef.unflatten([o[2] for o in out])
        return deltas, OptState(step=step, mu=mu, nu=nu)

    return GradientTransform(init=init, update=update)


def apply_updates(params: Any, deltas: Any) -> Any:
    return jax.tree_util.tree_map(lambda p, d: p + d.astype(p.dtype), params, deltas)
