"""Atomic pytree checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json   (tree structure, per-leaf shape/dtype/digest)
            <leaf_id>.bin   (raw little-endian bytes; bf16 stored as u16)

Commit protocol: write to `step_<N>.tmp/`, fsync files, atomic rename to
`step_<N>/` — a crashed writer can never leave a readable-but-corrupt
checkpoint, and the restart driver simply takes `latest_step()`.

Restore is *elastic*: leaves are materialized as global arrays and
device_put against whatever sharding the new mesh wants — a checkpoint
taken on one topology restores onto any other (tests/test_checkpoint.py
exercises 8 -> 4 devices).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _leaf_to_bytes(x) -> tuple[bytes, dict]:
    arr = np.asarray(x)
    logical = str(arr.dtype)
    if arr.dtype.name == "bfloat16":
        arr = arr.view(np.uint16)
    meta = {"shape": list(arr.shape), "store_dtype": str(arr.dtype),
            "dtype": logical}
    raw = np.ascontiguousarray(arr).tobytes()
    meta["digest"] = hashlib.blake2b(raw, digest_size=16).hexdigest()
    return raw, meta


def _bytes_to_leaf(raw: bytes, meta: dict):
    arr = np.frombuffer(bytearray(raw), dtype=np.dtype(meta["store_dtype"]))
    arr = arr.reshape(meta["shape"])
    if meta["dtype"] == "bfloat16":
        import ml_dtypes
        arr = arr.view(ml_dtypes.bfloat16)
    return arr


def serialize(tree: Any) -> tuple[list[tuple[str, bytes]], dict]:
    """-> ([(leaf_id, raw_bytes)], manifest). Shared with the dedup store."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    blobs, leaves = [], []
    for i, (path, leaf) in enumerate(flat):
        raw, meta = _leaf_to_bytes(leaf)
        meta["id"] = f"leaf_{i:05d}"
        meta["path"] = jax.tree_util.keystr(path)
        blobs.append((meta["id"], raw))
        leaves.append(meta)
    manifest = {"leaves": leaves, "treedef": str(treedef)}
    return blobs, manifest


def deserialize(blobs: dict[str, bytes], manifest: dict, like: Any) -> Any:
    """Rebuild using `like`'s treedef (stored treedef str is a cross-check)."""
    flat, treedef = jax.tree_util.tree_flatten(like)
    leaves = manifest["leaves"]
    assert len(flat) == len(leaves), \
        f"checkpoint has {len(leaves)} leaves, target tree has {len(flat)}"
    out = []
    for meta, target in zip(leaves, flat):
        raw = blobs[meta["id"]]
        if hashlib.blake2b(raw, digest_size=16).hexdigest() != meta["digest"]:
            raise IOError(f"digest mismatch for {meta['path']}")
        arr = _bytes_to_leaf(raw, meta)
        sharding = getattr(target, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            arr = jax.device_put(arr, sharding)   # elastic reshard
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def save(ckpt_dir: str | Path, tree: Any, step: int) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    blobs, manifest = serialize(tree)
    for leaf_id, raw in blobs:
        with open(tmp / f"{leaf_id}.bin", "wb") as f:
            f.write(raw)
            f.flush()
            os.fsync(f.fileno())
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    return final


def restore(ckpt_dir: str | Path, like: Any, step: Optional[int] = None) -> Any:
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    blobs = {m["id"]: (d / f"{m['id']}.bin").read_bytes()
             for m in manifest["leaves"]}
    return deserialize(blobs, manifest, like)


def list_steps(ckpt_dir: str | Path) -> list[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None
