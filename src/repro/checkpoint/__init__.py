from repro.checkpoint.store import save, restore, latest_step, list_steps  # noqa: F401
from repro.checkpoint.dedup_store import DedupCheckpointStore  # noqa: F401
