"""CARD-deduplicated delta-compressed checkpoint store (DESIGN.md §4).

Successive checkpoints of a training run are the canonical versioned
backup stream the paper targets: step N+1's parameters are byte-similar to
step N's. Each checkpoint is serialized to the same byte layout as
checkpoint/store.py, chunked with FastCDC, exact-deduped, and
delta-compressed against CARD-detected resemblance bases. Restore is
byte-identical (digest-checked).

Why it matters for fault tolerance: storage per checkpoint drops by the
DCR factor, so production runs can checkpoint far more frequently for the
same storage budget — shrinking the restart gap after a failure
(benchmarks/bench_ckpt_store.py quantifies this).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Optional

import numpy as np

from repro import api
from repro.core import chunking, context_model, features, pipeline
from repro.checkpoint import store as base_store


def _default_detector() -> pipeline.CARDDetector:
    return pipeline.CARDDetector(
        feat_cfg=features.FeatureConfig(k=32, m=64, n=2),
        model_cfg=context_model.ContextModelConfig(m=64, d=50, steps=120),
        use_kernel=False)


def _byte_planes(raw: bytes, itemsize: int) -> bytes:
    """[v0b0 v0b1 ...] -> [all b_(n-1) (MSB-ish) planes ... all b0].

    Between adjacent training steps the sign/exponent/high-mantissa bytes of
    most parameters are unchanged while low mantissa bytes are noise;
    grouping planes turns "every 4th byte differs" (incompressible for a
    byte-level delta) into long identical runs + a small noisy region.
    Little-endian, so the high-order byte is the LAST of each item.
    """
    if itemsize <= 1 or len(raw) % itemsize:
        return raw
    a = np.frombuffer(raw, np.uint8).reshape(-1, itemsize)
    return np.ascontiguousarray(a.T[::-1]).tobytes()


def _unbyte_planes(raw: bytes, itemsize: int) -> bytes:
    if itemsize <= 1 or len(raw) % itemsize:
        return raw
    a = np.frombuffer(raw, np.uint8).reshape(itemsize, -1)[::-1]
    return np.ascontiguousarray(a.T).tobytes()


class DedupCheckpointStore:
    def __init__(self, detector: Optional[pipeline.Detector] = None,
                 chunker_cfg: Optional[chunking.ChunkerConfig] = None,
                 byte_plane: bool = True,
                 backend: Optional[api.ContainerBackend] = None):
        self._store = api.DedupStore(
            detector or _default_detector(),
            chunker_cfg or chunking.ChunkerConfig(avg_size=16 * 1024),
            backend=backend)
        self._steps: dict[int, tuple[int, dict]] = {}  # step -> (handle, manifest)
        self._fitted = False
        self._byte_plane = byte_plane

    def _to_stream(self, tree: Any) -> tuple[bytes, dict]:
        blobs, manifest = base_store.serialize(tree)
        sizes = {m["id"]: np.dtype(m["store_dtype"]).itemsize
                 for m in manifest["leaves"]}
        offsets = {}
        out = bytearray()
        for leaf_id, raw in blobs:
            if self._byte_plane:
                raw = _byte_planes(raw, sizes[leaf_id])
            offsets[leaf_id] = [len(out), len(raw)]
            out.extend(raw)
        manifest["offsets"] = offsets
        return bytes(out), manifest

    def save(self, tree: Any, step: int) -> pipeline.StoreStats:
        stream, manifest = self._to_stream(tree)
        if not self._fitted:
            self._store.fit([stream])
            self._fitted = True
        session = self._store.open_stream()
        session.write(stream)
        report = session.commit()
        self._steps[step] = (report.handle, manifest)
        return self.stats

    def restore(self, like: Any, step: int) -> Any:
        idx, manifest = self._steps[step]
        stream = self._store.restore(idx)
        sizes = {m["id"]: np.dtype(m["store_dtype"]).itemsize
                 for m in manifest["leaves"]}
        blobs = {}
        for lid, (off, ln) in manifest["offsets"].items():
            raw = stream[off:off + ln]
            if self._byte_plane:
                raw = _unbyte_planes(raw, sizes[lid])
            blobs[lid] = raw
        return base_store.deserialize(blobs, manifest, like)

    @property
    def stats(self) -> pipeline.StoreStats:
        return self._store.stats

    @property
    def steps(self) -> list[int]:
        return sorted(self._steps)
