"""Declarative pipeline construction: ``DedupConfig.from_dict`` -> ``build_store``.

One construction path for everything (benchmarks, examples, the
checkpoint store, services): a plain-dict config names each component by
its registry key plus keyword arguments for its factory:

    cfg = DedupConfig.from_dict({
        "detector": "card",
        "detector_args": {"feat": {"k": 32, "m": 64, "n": 2},
                          "model": {"d": 50, "steps": 150},
                          "index": "banded-lsh",       # vs "exact"
                          "use_kernel": False},
        "chunker": "fastcdc",
        "chunker_args": {"avg_size": 8192},
        "backend": "file",
        "backend_args": {"path": "/data/containers"},
        "policy": "threshold",               # reclamation (DESIGN.md §7.4)
        "policy_args": {"ratio": 0.25},
        "restore_cache_bytes": 64 << 20,     # decode-cache budget (§9.2)
    })
    store = build_store(cfg)

Configs are JSON-serializable (``to_dict`` round-trips) so a service can
ship them over the wire or pin them in a manifest next to the containers.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any

from repro.api import registry
from repro.api.store import DedupStore

_KNOWN_KEYS = {"detector", "detector_args", "chunker", "chunker_args",
               "backend", "backend_args", "policy", "policy_args",
               "restore_cache_bytes", "restore_cache_shards",
               "restore_cache_policy", "restore_reader_fds",
               "restore_readahead", "restore_coalesce_gap",
               "restore_tier_path", "restore_tier_bytes",
               "verify_reads", "retry_deadline",
               "trace_path", "trace_ring_events",
               "server_workers", "server_args", "tenant_args"}

# serving/integrity knobs (DESIGN.md §10, §11.3, §13) -> backend factory
# kwargs; each is forwarded only when set and only to factories that
# declare the kwarg
_BACKEND_KNOBS = {"restore_cache_bytes": "cache_bytes",
                  "restore_cache_shards": "cache_shards",
                  "restore_cache_policy": "cache_policy",
                  "restore_reader_fds": "reader_fds",
                  "restore_readahead": "readahead",
                  "restore_coalesce_gap": "coalesce_gap",
                  "restore_tier_path": "tier_path",
                  "restore_tier_bytes": "tier_bytes",
                  "verify_reads": "verify_reads",
                  "retry_deadline": "retry_deadline"}

# integer knobs validated in from_dict: knob name -> smallest legal value
_INT_KNOB_FLOORS = {"restore_cache_bytes": 1, "restore_cache_shards": 1,
                    "restore_reader_fds": 1, "restore_readahead": 0,
                    "restore_coalesce_gap": 0, "restore_tier_bytes": 1}


@dataclasses.dataclass
class DedupConfig:
    detector: str = "card"
    detector_args: dict[str, Any] = dataclasses.field(default_factory=dict)
    chunker: str = "fastcdc"
    chunker_args: dict[str, Any] = dataclasses.field(default_factory=dict)
    backend: str = "memory"
    backend_args: dict[str, Any] = dataclasses.field(default_factory=dict)
    policy: str = "never"
    policy_args: dict[str, Any] = dataclasses.field(default_factory=dict)
    # serving-engine knobs (DESIGN.md §9.2, §10); None keeps each
    # backend's default. Forwarded as the ``cache_bytes`` /
    # ``cache_shards`` / ``reader_fds`` / ``readahead`` factory
    # arguments to backends that declare them (the file backend);
    # backends without a decode cache / reader pool (memory) ignore all.
    restore_cache_bytes: int | None = None      # decode-cache budget
    restore_cache_shards: int | None = None     # cache lock stripes
    # decode-cache eviction policy by registry name (DESIGN.md §14.1):
    # "lru" (default) or the scan-resistant "arc"; resolved through
    # registry.get_cache_policy at backend construction
    restore_cache_policy: str | None = None
    restore_reader_fds: int | None = None       # pread pool size
    restore_readahead: int | None = None        # read runs in flight (0 off)
    # largest gap (bytes) two payload reads may straddle and still be
    # fetched as one pread / ranged GET (§11.3). Backends default it to
    # their medium — 4 KiB for the file log, 1 MiB for object stores —
    # so set it only to override; 0 coalesces exactly-adjacent reads only.
    restore_coalesce_gap: int | None = None
    # local-disk chunk cache tier in front of remote backends
    # (DESIGN.md §14.3): tier_path roots the per-chunk payload files,
    # tier_bytes budgets them (None = backend default). Backends without
    # a remote hop (file, memory) ignore both.
    restore_tier_path: str | None = None
    restore_tier_bytes: int | None = None
    # integrity knobs (DESIGN.md §13): verify_reads=True makes backends
    # that persist checksums validate every payload on the read path,
    # raising CorruptChunkError instead of serving garbage;
    # retry_deadline bounds the object-store retry policy's total sleep
    # per logical request (seconds) — exceeding it raises
    # RetryBudgetExceeded (§13.5). None keeps each backend's default.
    verify_reads: bool | None = None
    retry_deadline: float | None = None
    # multi-tenant serving (DESIGN.md §15): build_server wraps the store
    # in a DedupServer with server_workers executor threads; server_args
    # are extra DedupServer kwargs and tenant_args the default
    # TenantConfig fields (quota_bytes / max_inflight / max_queue /
    # cache_bytes / cache_policy / default_timeout) applied to tenants
    # created on first use. All ignored by plain build_store.
    server_workers: int | None = None
    server_args: dict[str, Any] = dataclasses.field(default_factory=dict)
    tenant_args: dict[str, Any] = dataclasses.field(default_factory=dict)
    # observability (DESIGN.md §12): every store gets a metrics registry
    # unconditionally; structured op tracing turns on only when one of
    # these is set. trace_path appends spans as JSONL (followable with
    # ``python -m repro.api.observe tail``); trace_ring_events keeps the
    # last N spans in memory (``store.observe.tracer.events()``).
    # Setting trace_path alone also enables a default-sized ring.
    trace_path: str | None = None
    trace_ring_events: int | None = None

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "DedupConfig":
        unknown = set(d) - _KNOWN_KEYS
        if unknown:
            raise ValueError(f"unknown DedupConfig keys {sorted(unknown)}; "
                             f"known: {sorted(_KNOWN_KEYS)}")
        cfg = cls(**{k: dict(v) if isinstance(v, dict) else v
                     for k, v in d.items()})
        for name in ("detector", "chunker", "backend", "policy"):
            if not isinstance(getattr(cfg, name), str):
                raise TypeError(f"{name} must be a registry name (str)")
        for name, floor in _INT_KNOB_FLOORS.items():
            value = getattr(cfg, name)
            if value is None:
                continue
            # 0 is meaningful for readahead (serial reads) and for the
            # coalesce gap (merge exactly-adjacent reads only)
            if (not isinstance(value, int) or isinstance(value, bool)
                    or value < floor):
                raise ValueError(f"{name} must be an int >= {floor}, "
                                 f"got {value!r}")
        for name in ("restore_cache_policy", "restore_tier_path"):
            value = getattr(cfg, name)
            if value is not None and not isinstance(value, str):
                raise TypeError(f"{name} must be a str, got {value!r}")
        if cfg.verify_reads is not None and not isinstance(cfg.verify_reads,
                                                           bool):
            raise TypeError(f"verify_reads must be a bool, "
                            f"got {cfg.verify_reads!r}")
        deadline = cfg.retry_deadline
        if deadline is not None and (isinstance(deadline, bool)
                                     or not isinstance(deadline, (int, float))
                                     or deadline < 0):
            raise ValueError(f"retry_deadline must be a number >= 0 "
                             f"(seconds), got {deadline!r}")
        if cfg.trace_path is not None and not isinstance(cfg.trace_path,
                                                         str):
            raise TypeError("trace_path must be a str (JSONL sink path)")
        ring = cfg.trace_ring_events
        if ring is not None and (not isinstance(ring, int) or ring < 0):
            raise ValueError(f"trace_ring_events must be an int >= 0, "
                             f"got {ring!r}")
        workers = cfg.server_workers
        if workers is not None and (not isinstance(workers, int)
                                    or isinstance(workers, bool)
                                    or workers < 1):
            raise ValueError(f"server_workers must be an int >= 1, "
                             f"got {workers!r}")
        return cfg

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def build_detector(cfg: DedupConfig) -> Any:
    return registry.get_detector(cfg.detector)(**cfg.detector_args)


def build_chunker(cfg: DedupConfig) -> Any:
    return registry.get_chunker(cfg.chunker)(**cfg.chunker_args)


def build_backend(cfg: DedupConfig) -> Any:
    factory = registry.get_backend(cfg.backend)
    args = dict(cfg.backend_args)
    wanted = {kwarg: getattr(cfg, name)
              for name, kwarg in _BACKEND_KNOBS.items()
              if getattr(cfg, name) is not None and kwarg not in args}
    if wanted:
        # forward only to factories that declare the knob; backends with
        # no decode cache / reader pool (memory) legitimately skip them.
        # A factory whose signature cannot be inspected gets an explicit
        # error instead of a silently ignored knob — pass backend_args
        # directly there.
        try:
            params = inspect.signature(factory).parameters
        except (TypeError, ValueError) as e:
            raise ValueError(
                f"serving knobs {sorted(wanted)} are set but backend "
                f"{cfg.backend!r} has an uninspectable factory signature; "
                "pass them via backend_args instead") from e
        args.update({k: v for k, v in wanted.items() if k in params})
    return factory(**args)


def build_policy(cfg: DedupConfig) -> Any:
    return registry.get_policy(cfg.policy)(**cfg.policy_args)


def build_store(cfg: DedupConfig) -> DedupStore:
    """Resolve every component through the registry and assemble the store."""
    return DedupStore(build_detector(cfg), build_chunker(cfg),
                      backend=build_backend(cfg), policy=build_policy(cfg),
                      trace_path=cfg.trace_path,
                      trace_ring_events=cfg.trace_ring_events)


def build_server(cfg: DedupConfig, store: DedupStore | None = None):
    """One-call multi-tenant deployment (DESIGN.md §15): ``build_store``
    plus a ``DedupServer`` over it, sized by ``server_workers`` with
    ``tenant_args`` as the default per-tenant limits. Pass an existing
    ``store`` to front one that is already serving."""
    from repro.api.serve import DedupServer, TenantConfig
    if store is None:
        store = build_store(cfg)
    kwargs = dict(cfg.server_args)
    if cfg.server_workers is not None and "workers" not in kwargs:
        kwargs["workers"] = cfg.server_workers
    if cfg.tenant_args and "default_tenant" not in kwargs:
        kwargs["default_tenant"] = TenantConfig(**cfg.tenant_args)
    return DedupServer(store, **kwargs)
