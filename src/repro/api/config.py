"""Declarative pipeline construction: ``DedupConfig.from_dict`` -> ``build_store``.

One construction path for everything (benchmarks, examples, the
checkpoint store, services): a plain-dict config names each component by
its registry key plus keyword arguments for its factory:

    cfg = DedupConfig.from_dict({
        "detector": "card",
        "detector_args": {"feat": {"k": 32, "m": 64, "n": 2},
                          "model": {"d": 50, "steps": 150},
                          "index": "banded-lsh",       # vs "exact"
                          "use_kernel": False},
        "chunker": "fastcdc",
        "chunker_args": {"avg_size": 8192},
        "backend": "file",
        "backend_args": {"path": "/data/containers"},
        "policy": "threshold",               # reclamation (DESIGN.md §7.4)
        "policy_args": {"ratio": 0.25},
        "restore_cache_bytes": 64 << 20,     # decode-cache budget (§9.2)
    })
    store = build_store(cfg)

Configs are JSON-serializable (``to_dict`` round-trips) so a service can
ship them over the wire or pin them in a manifest next to the containers.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any

from repro.api import registry
from repro.api.store import DedupStore

_KNOWN_KEYS = {"detector", "detector_args", "chunker", "chunker_args",
               "backend", "backend_args", "policy", "policy_args",
               "restore_cache_bytes"}


@dataclasses.dataclass
class DedupConfig:
    detector: str = "card"
    detector_args: dict[str, Any] = dataclasses.field(default_factory=dict)
    chunker: str = "fastcdc"
    chunker_args: dict[str, Any] = dataclasses.field(default_factory=dict)
    backend: str = "memory"
    backend_args: dict[str, Any] = dataclasses.field(default_factory=dict)
    policy: str = "never"
    policy_args: dict[str, Any] = dataclasses.field(default_factory=dict)
    # decode-cache budget for the restore path (DESIGN.md §9.2); None
    # keeps the backend's default. Forwarded as the ``cache_bytes``
    # factory argument to backends that take one (the file backend);
    # backends without a decode cache (memory) ignore it.
    restore_cache_bytes: int | None = None

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "DedupConfig":
        unknown = set(d) - _KNOWN_KEYS
        if unknown:
            raise ValueError(f"unknown DedupConfig keys {sorted(unknown)}; "
                             f"known: {sorted(_KNOWN_KEYS)}")
        cfg = cls(**{k: dict(v) if isinstance(v, dict) else v
                     for k, v in d.items()})
        for name in ("detector", "chunker", "backend", "policy"):
            if not isinstance(getattr(cfg, name), str):
                raise TypeError(f"{name} must be a registry name (str)")
        if cfg.restore_cache_bytes is not None:
            if (not isinstance(cfg.restore_cache_bytes, int)
                    or cfg.restore_cache_bytes <= 0):
                raise ValueError("restore_cache_bytes must be a positive "
                                 f"int, got {cfg.restore_cache_bytes!r}")
        return cfg

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def build_detector(cfg: DedupConfig) -> Any:
    return registry.get_detector(cfg.detector)(**cfg.detector_args)


def build_chunker(cfg: DedupConfig) -> Any:
    return registry.get_chunker(cfg.chunker)(**cfg.chunker_args)


def build_backend(cfg: DedupConfig) -> Any:
    factory = registry.get_backend(cfg.backend)
    args = dict(cfg.backend_args)
    if cfg.restore_cache_bytes is not None and "cache_bytes" not in args:
        # forward only to factories that declare the knob; backends with
        # no decode cache (memory) legitimately skip it. A factory whose
        # signature cannot be inspected gets an explicit error instead of
        # a silently ignored budget — pass backend_args directly there.
        try:
            params = inspect.signature(factory).parameters
        except (TypeError, ValueError) as e:
            raise ValueError(
                f"restore_cache_bytes is set but backend {cfg.backend!r} "
                "has an uninspectable factory signature; pass the budget "
                "via backend_args instead") from e
        if "cache_bytes" in params:
            args["cache_bytes"] = cfg.restore_cache_bytes
    return factory(**args)


def build_policy(cfg: DedupConfig) -> Any:
    return registry.get_policy(cfg.policy)(**cfg.policy_args)


def build_store(cfg: DedupConfig) -> DedupStore:
    """Resolve every component through the registry and assemble the store."""
    return DedupStore(build_detector(cfg), build_chunker(cfg),
                      backend=build_backend(cfg), policy=build_policy(cfg))
