"""Staged detector protocol (DESIGN.md §2.1).

The v0 ``Detector.detect(chunks, ids, is_new, stream_hashes)`` god-method
hid three different concerns behind one call: feature extraction (pure,
expensive, batchable), candidate scoring against current index state
(pure), and index admission (the only mutation). The staged protocol makes
each explicit:

    extract(batch)            -> features     pure; the heavy batched work
    score(features, batch)    -> DetectResult pure; no index mutation
    observe(features, batch)  -> None         the ONE mutating step

``score`` must behave as if every chunk of the batch were scored against
the index state at batch entry plus earlier chunks of the *same* batch —
i.e. exactly what the v0 interleaved query/insert loop produced — without
touching the shared index, so a crashed or aborted stream admits nothing.

``run_detect`` drives either shape (staged detectors, or third-party
legacy detectors that only implement ``detect``), and ``LegacyDetectMixin``
gives staged detectors the v0 ``detect`` method for free, bit-identical to
the pre-refactor behaviour.
"""
from __future__ import annotations

from typing import Any, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.api.types import DetectBatch, DetectResult


@runtime_checkable
class StagedDetector(Protocol):
    name: str

    def fit(self, training_streams: Sequence[bytes], cfg: Any) -> None: ...

    def extract(self, batch: DetectBatch) -> Any: ...

    def score(self, features: Any, batch: DetectBatch) -> DetectResult: ...

    def observe(self, features: Any, batch: DetectBatch) -> None: ...


def is_staged(detector: Any) -> bool:
    return (hasattr(detector, "extract") and hasattr(detector, "score")
            and hasattr(detector, "observe"))


def run_detect(detector: Any, batch: DetectBatch) -> DetectResult:
    """Full detection pass for one stream: extract -> score -> observe.

    Falls back to the legacy single-call protocol for detectors that only
    implement ``detect`` so third-party detectors keep working unchanged.
    """
    if is_staged(detector):
        features = detector.extract(batch)
        result = detector.score(features, batch)
        detector.observe(features, batch)
        return result
    base_ids = detector.detect(list(batch.chunks), batch.ids, batch.is_new,
                               batch.stream_hashes)
    return DetectResult(base_ids=np.asarray(base_ids, np.int64))


class LegacyDetectMixin:
    """v0 compatibility shim: provides ``detect(chunks, ids, is_new,
    stream_hashes)`` on top of the staged methods, bit-identical to the
    pre-refactor monolithic implementations."""

    def detect(self, chunks, ids, is_new, stream_hashes) -> np.ndarray:
        batch = DetectBatch(chunks=list(chunks), ids=ids, is_new=is_new,
                            stream_hashes=stream_hashes)
        return run_detect(self, batch).base_ids
