"""Value types of the layered detection & store API (DESIGN.md §2).

These are the *only* objects that cross layer boundaries:

  DetectBatch    one stream's worth of chunks handed to a detector —
                 replaces the positional ``(chunks, ids, is_new,
                 stream_hashes)`` array soup of the v0 ``Detector.detect``
                 protocol;
  DetectResult   per-chunk resemblance verdict (base chunk id, score);
  IngestReport   immutable per-stream accounting returned by
                 ``StreamSession.commit()`` — the stream handle plus the
                 stream's own byte/chunk/time counters;
  RestoreReport  immutable per-restore accounting (DESIGN.md §9.4):
                 bytes served vs container bytes read, read/decode time
                 split, decode-cache hits/misses. The store keeps the
                 latest on ``DedupStore.last_restore``;
  StoreStats     the store-lifetime aggregate (sum of every IngestReport
                 and RestoreReport plus offline fit time). Kept for the
                 v0 surface; new code should prefer the per-call reports.

Nothing in this module mutates anything and nothing here imports the
pipeline, so every layer (core detectors, container backends, registry,
benchmarks) can depend on it without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

import numpy as np

if TYPE_CHECKING:  # avoid an import cycle at runtime; chunking is a leaf
    from repro.core.chunking import Chunk


@dataclasses.dataclass
class DetectBatch:
    """One stream of chunks, exact-dedup already resolved.

    chunks         the stream's chunks, in stream order
    ids            [n] int64 chunk id per chunk (duplicates share ids)
    is_new         [n] bool — True where the chunk's content was never
                   stored before (first occurrence wins inside a stream)
    stream_hashes  [len(stream)] uint32 windowed gear hashes of the whole
                   stream, as produced by the chunker scan — detectors
                   reuse them for free sub-chunk features. May be a
                   device-resident ``kernels.ingest.StreamScan`` (indexes
                   like the numpy array; fused detectors read its
                   ``.device`` handle and skip the host round-trip)
    """

    chunks: "Sequence[Chunk]"
    ids: np.ndarray
    is_new: np.ndarray
    stream_hashes: np.ndarray

    def __post_init__(self) -> None:
        self.ids = np.asarray(self.ids, np.int64)
        self.is_new = np.asarray(self.is_new, bool)
        if len(self.chunks) != self.ids.shape[0] or self.ids.shape != self.is_new.shape:
            raise ValueError(
                f"DetectBatch shape mismatch: {len(self.chunks)} chunks, "
                f"ids {self.ids.shape}, is_new {self.is_new.shape}")

    def __len__(self) -> int:
        return len(self.chunks)

    @property
    def offsets(self) -> np.ndarray:
        return np.asarray([c.offset for c in self.chunks], np.int64)


@dataclasses.dataclass
class DetectResult:
    """Per-chunk verdict: base chunk id to delta-encode against (-1 = store
    raw) and, when the detector produces one, the resemblance score."""

    base_ids: np.ndarray
    scores: np.ndarray | None = None

    def __post_init__(self) -> None:
        self.base_ids = np.asarray(self.base_ids, np.int64)

    def __len__(self) -> int:
        return int(self.base_ids.shape[0])


@dataclasses.dataclass(frozen=True)
class IngestReport:
    """What one committed stream did to the store (returned by
    ``StreamSession.commit()``; never mutated afterwards)."""

    handle: int                 # pass to DedupStore.restore()
    bytes_in: int = 0
    bytes_stored: int = 0
    chunks: int = 0
    dup_chunks: int = 0
    delta_chunks: int = 0
    raw_chunks: int = 0
    detect_seconds: float = 0.0
    chunk_seconds: float = 0.0
    delta_seconds: float = 0.0
    # detect/store stage breakdown (benchmarks/bench_ingest.py): for a
    # staged detector, detect_seconds == extract + score + observe;
    # legacy single-call detectors book everything under score_seconds.
    # store_seconds is backend I/O (put_many/recipe/flush), excluding the
    # delta encodes already counted by delta_seconds.
    extract_seconds: float = 0.0
    score_seconds: float = 0.0
    observe_seconds: float = 0.0
    store_seconds: float = 0.0

    @property
    def dcr(self) -> float:
        """This stream's own deduplication-compression ratio."""
        return self.bytes_in / max(1, self.bytes_stored)


@dataclasses.dataclass(frozen=True)
class RestoreReport:
    """What one restore (full, ranged, or fully-consumed iterator) cost
    (DESIGN.md §9.4). ``read_seconds``/``decode_seconds``/``bytes_read``
    and the cache counters come from backend telemetry deltas; backends
    without counters (e.g. the in-memory one) report zeros there while
    ``seconds``/``bytes_out`` stay exact."""

    handle: int
    bytes_out: int = 0          # bytes served to the caller
    chunks: int = 0             # recipe slots touched
    seconds: float = 0.0        # end-to-end wall time
    read_seconds: float = 0.0   # container payload I/O (summed across
    #                             pooled readers, so it can exceed the
    #                             wall-clock share once readahead overlaps
    #                             reads with decode — DESIGN.md §10.5)
    decode_seconds: float = 0.0  # delta-chain decoding
    bytes_read: int = 0         # container bytes fetched (vs bytes_out)
    cache_hits: int = 0
    cache_misses: int = 0
    # container bytes whose read was fully hidden behind decode work by
    # the double-buffered fetcher (§10.3) — the readahead payoff gauge
    prefetch_bytes: int = 0
    # physical payload reads issued (preads / ranged GETs): the cost
    # metric for latency-bound remote backends (DESIGN.md §11.3)
    requests: int = 0

    @property
    def read_amplification(self) -> float:
        """Container bytes read per byte served (< 1 once cache-warm)."""
        return self.bytes_read / max(1, self.bytes_out)


@dataclasses.dataclass
class StoreStats:
    """Store-lifetime aggregate: the sum of every committed IngestReport
    plus offline model-fit time (invariant tested in tests/test_api.py).

    The lifecycle fields (DESIGN.md §7) are maintained by the reclamation
    subsystem, not by ``absorb``: ``live_bytes``/``dead_bytes`` mirror the
    refcount table after every commit/delete/collect (``dead_bytes``
    counts everything a compaction pass can drop — unreferenced records
    plus records pinned only as delta bases, which rebasing frees);
    ``reclaimed_bytes`` accumulates the measured container shrink across
    compactions; ``chain_depth_hist`` is the live delta-chain depth
    histogram from the last ``collect()``."""

    bytes_in: int = 0
    bytes_stored: int = 0
    chunks: int = 0
    dup_chunks: int = 0
    delta_chunks: int = 0
    raw_chunks: int = 0
    detect_seconds: float = 0.0
    chunk_seconds: float = 0.0
    delta_seconds: float = 0.0
    extract_seconds: float = 0.0
    score_seconds: float = 0.0
    observe_seconds: float = 0.0
    store_seconds: float = 0.0
    fit_seconds: float = 0.0
    live_bytes: int = 0
    dead_bytes: int = 0
    reclaimed_bytes: int = 0
    chain_depth_hist: dict[int, int] = dataclasses.field(default_factory=dict)
    # restore telemetry (DESIGN.md §9.4): the running sum of every
    # absorbed RestoreReport, maintained by absorb_restore
    restores: int = 0
    restore_bytes_out: int = 0
    restore_bytes_read: int = 0
    restore_seconds: float = 0.0
    restore_read_seconds: float = 0.0
    restore_decode_seconds: float = 0.0
    restore_cache_hits: int = 0
    restore_cache_misses: int = 0
    restore_prefetch_bytes: int = 0
    restore_requests: int = 0

    @property
    def dcr(self) -> float:
        return self.bytes_in / max(1, self.bytes_stored)

    def absorb(self, report: IngestReport) -> None:
        self.bytes_in += report.bytes_in
        self.bytes_stored += report.bytes_stored
        self.chunks += report.chunks
        self.dup_chunks += report.dup_chunks
        self.delta_chunks += report.delta_chunks
        self.raw_chunks += report.raw_chunks
        self.detect_seconds += report.detect_seconds
        self.chunk_seconds += report.chunk_seconds
        self.delta_seconds += report.delta_seconds
        self.extract_seconds += report.extract_seconds
        self.score_seconds += report.score_seconds
        self.observe_seconds += report.observe_seconds
        self.store_seconds += report.store_seconds

    def absorb_restore(self, report: "RestoreReport") -> None:
        self.restores += 1
        self.restore_bytes_out += report.bytes_out
        self.restore_bytes_read += report.bytes_read
        self.restore_seconds += report.seconds
        self.restore_read_seconds += report.read_seconds
        self.restore_decode_seconds += report.decode_seconds
        self.restore_cache_hits += report.cache_hits
        self.restore_cache_misses += report.cache_misses
        self.restore_prefetch_bytes += report.prefetch_bytes
        self.restore_requests += report.requests
