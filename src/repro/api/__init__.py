"""repro.api — the layered public surface of the dedup/delta system.

Layers (DESIGN.md §2), each depending only on the ones above it:

  types        DetectBatch / DetectResult / IngestReport / StoreStats
  detect       staged detector protocol (extract -> score -> observe),
               legacy-``detect`` compatibility shim
  containers   ContainerBackend protocol; memory + file backends
  store        DedupStore with transactional StreamSession ingestion
  registry     name -> factory tables for detectors/indexes/chunkers/backends
  config       DedupConfig.from_dict(...) -> build_store(...)

Quick start:

    from repro import api
    store = api.build_store(api.DedupConfig.from_dict({"detector": "card"}))
    store.fit([first_version])
    with store.open_stream() as s:
        s.write(first_version)
    report = store.reports[-1]          # or: s = store.open_stream();
    restored = store.restore(report.handle)
"""
from repro.api.types import (  # noqa: F401
    DetectBatch,
    DetectResult,
    IngestReport,
    StoreStats,
)
from repro.api.detect import (  # noqa: F401
    LegacyDetectMixin,
    StagedDetector,
    is_staged,
    run_detect,
)
from repro.api.containers import (  # noqa: F401
    ContainerBackend,
    FileBackend,
    InMemoryBackend,
)
from repro.api.store import DedupStore, StreamSession, chunk_with  # noqa: F401
from repro.api.registry import (  # noqa: F401
    available_backends,
    available_chunkers,
    available_detectors,
    available_indexes,
    get_backend,
    get_chunker,
    get_detector,
    get_index,
    register_backend,
    register_chunker,
    register_detector,
    register_index,
)
from repro.api.config import (  # noqa: F401
    DedupConfig,
    build_backend,
    build_chunker,
    build_detector,
    build_store,
)
