"""repro.api — the layered public surface of the dedup/delta system.

Layers (DESIGN.md §2 and §7), each depending only on the ones above it:

  types        DetectBatch / DetectResult / IngestReport / StoreStats
  detect       staged detector protocol (extract -> score -> observe),
               legacy-``detect`` compatibility shim
  concurrency  RWLock + per-thread I/O telemetry for concurrent serving
  containers   ContainerBackend protocol; memory + file backends and the
               shared PlannedChainReader read engine
  objectstore  ranged-GET ObjectStoreBackend over an object API, the
               fault-injecting LocalObjectStore fake, the boto3 seam,
               and the cp/ls/stat/verify CLI (DESIGN.md §11)
  observe      metrics registry (counters/gauges/log2 histograms with
               per-thread shards), structured trace spans (ring buffer +
               JSONL sink), Prometheus/JSON exporters and the dump/tail
               CLI (DESIGN.md §12)
  faults       deterministic fault injection: FaultSchedule, named
               crashpoints + FaultInjector, bit-flip/truncate helpers,
               and the crash-script harness (DESIGN.md §13.4)
  integrity    crc32c, typed corruption errors, and the scrub/repair
               fsck walk behind DedupStore.scrub (DESIGN.md §13)
  refcount     chunk recipe/base refcounting for space reclamation
  restore      serving-path policy: restore planner (chain-grouped,
               topologically ordered, offset-sorted reads), byte-budgeted
               DecodeCache, recipe prefix sums for ranged reads
  store        DedupStore with transactional StreamSession ingestion and
               the restore/restore_iter/restore_range serving surface
  serve        multi-tenant DedupServer front end: per-tenant
               namespaces/quotas, admission control, request deadlines,
               circuit-breaker degradation (DESIGN.md §15)
  lifecycle    delete / mark-sweep collect / compaction with rebase,
               pluggable reclamation policies
  registry     name -> factory tables for detectors/indexes/chunkers/
               backends/policies
  config       DedupConfig.from_dict(...) -> build_store(...)

Quick start:

    from repro import api
    store = api.build_store(api.DedupConfig.from_dict({"detector": "card"}))
    store.fit([first_version])
    with store.open_stream() as s:
        s.write(first_version)
    report = s.report                     # IngestReport from the commit
    assert store.restore(report.handle) == first_version
    store.delete(report.handle)           # retire the stream ...
    store.collect()
    store.compact()                       # ... and reclaim its bytes

(The snippet above is executed verbatim by tests/test_api.py, so it
stays honest.)
"""
from repro.api.types import (  # noqa: F401
    DetectBatch,
    DetectResult,
    IngestReport,
    RestoreReport,
    StoreStats,
)
from repro.api.restore import (  # noqa: F401
    DEFAULT_CACHE_BYTES,
    DEFAULT_CACHE_SHARDS,
    DecodeCache,
    RecipeLayout,
    RestorePlan,
    ShardedDecodeCache,
    coalesce_reads,
    plan_chains,
)
from repro.api.concurrency import (  # noqa: F401
    DeadlineExceededError,
    IoTelemetry,
    LockTimeout,
    RWLock,
    check_deadline,
    current_deadline,
    deadline_scope,
    remaining_time,
)
from repro.api.detect import (  # noqa: F401
    LegacyDetectMixin,
    StagedDetector,
    is_staged,
    run_detect,
)
from repro.api.containers import (  # noqa: F401
    ContainerBackend,
    FileBackend,
    InMemoryBackend,
    PlannedChainReader,
)
# objectstore exports resolve lazily (PEP 562, __getattr__ below): an
# eager import here would land repro.api.objectstore in sys.modules
# while ``python -m repro.api.objectstore`` is still locating it, and
# runpy warns about exactly that. The registry reaches the module by
# name anyway, so nothing else needs it at package-import time.
from repro.api.refcount import RefcountTable  # noqa: F401
from repro.api.store import DedupStore, StreamSession, chunk_with  # noqa: F401
from repro.api.lifecycle import (  # noqa: F401
    CollectReport,
    CompactionRun,
    EagerPolicy,
    NeverPolicy,
    ReclamationPolicy,
    ThresholdPolicy,
)
from repro.api.registry import (  # noqa: F401
    available_backends,
    available_chunkers,
    available_detectors,
    available_indexes,
    available_policies,
    get_backend,
    get_chunker,
    get_detector,
    get_index,
    get_policy,
    register_backend,
    register_chunker,
    register_detector,
    register_index,
    register_policy,
)
from repro.api.config import (  # noqa: F401
    DedupConfig,
    build_backend,
    build_chunker,
    build_detector,
    build_policy,
    build_server,
    build_store,
)

_OBJECTSTORE_EXPORTS = frozenset({
    "FaultSchedule", "LocalObjectStore", "ObjectStoreBackend",
    "S3ObjectClient", "TransientError",
})

# integrity + fault-injection layers (DESIGN.md §13) resolve lazily too:
# both are leaf modules, but keeping them off the package-import path
# keeps ``import repro.api`` lean and mirrors the objectstore treatment.
# FaultSchedule/TransientError stay addressed through objectstore above
# for compatibility (objectstore re-exports them from faults).
_INTEGRITY_EXPORTS = frozenset({
    "CorruptChunkError", "CorruptJournalError", "ScrubReport", "crc32c",
})
_FAULTS_EXPORTS = frozenset({
    "FaultInjector", "RetryBudgetExceeded", "SimulatedCrash",
    "register_crashpoint", "registered_crashpoints",
})

# same lazy treatment for the observability layer: repro.api.observe has
# a ``python -m`` CLI of its own (dump/tail), so it must not be imported
# at package-import time (DedupStore imports it on construction, which
# is after runpy has located the module)
_OBSERVE_EXPORTS = frozenset({
    "MetricsRegistry", "Observability", "Tracer", "parse_prometheus_text",
})

# the §15 multi-tenant serving layer rides on the store, so it stays off
# the package-import path like the other heavy layers
_SERVE_EXPORTS = frozenset({
    "CircuitBreaker", "CircuitOpenError", "DedupServer", "OverloadError",
    "QuotaExceededError", "RequestRejected", "TenantConfig",
})


def __getattr__(name: str):
    if name in _OBJECTSTORE_EXPORTS:
        from repro.api import objectstore
        return getattr(objectstore, name)
    if name in _OBSERVE_EXPORTS:
        from repro.api import observe
        return getattr(observe, name)
    if name in _INTEGRITY_EXPORTS:
        from repro.api import integrity
        return getattr(integrity, name)
    if name in _FAULTS_EXPORTS:
        from repro.api import faults
        return getattr(faults, name)
    if name in _SERVE_EXPORTS:
        from repro.api import serve
        return getattr(serve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
