"""Unified observability: metrics registry, trace layer, exporters (DESIGN.md §12).

One instrument for every subsystem. The engine spans ingest, the
restore/serving path, GC/compaction, the RW-locked concurrency layer and
two durable backends — each used to keep ad-hoc counters (`IoTelemetry`
tuples, report fields, the object client's request tallies) with no
latency distributions and no way to export any of it. This module gives
every ``DedupStore`` a **metrics registry** and an optional **tracer**,
bundled as ``Observability`` (``store.observe``; ``store.metrics()``
returns the registry):

    MetricsRegistry   counters, gauges and bounded-bucket histograms
                      (log2 buckets — the right shape for latencies and
                      sizes spanning decades). The write path is
                      lock-free: every thread owns a private shard (the
                      ``IoTelemetry`` fold pattern generalized), folded
                      into a dead-thread aggregate on thread exit or via
                      ``fold_current()``. Snapshots merge dead + live
                      shards under the registry lock; histogram counts
                      are derived from the bucket copies, so a snapshot
                      can never tear (count always equals the bucket
                      sum). Exporters: Prometheus text exposition
                      (``to_prometheus``) and a JSON snapshot
                      (``to_json`` / ``snapshot``).
    Tracer            per-operation spans — op name, span id, parent id,
                      thread id, wall-clock start, duration, free-form
                      labels — recorded into a fixed-size ring
                      (``trace_ring_events``) and/or appended to a JSONL
                      file (``trace_path``), both ``DedupConfig`` knobs.
                      When neither knob is set a store has **no tracer
                      at all** (``store.observe.tracer is None``), so
                      the serving hot path pays a single ``is None``
                      test — the ±15% warm-restore overhead guard in
                      BENCH_RESTORE.json rides on that.

Two kinds of metric, one registry (the "no parallel bookkeeping" rule):

  * **native** metrics are recorded at the event — stage-timing
    histograms, lock wait times, coalesced-run widths, request
    latencies. They exist nowhere else.
  * **derived views** re-export counters another structure already owns
    (``StoreStats`` lifecycle gauges, ``IoTelemetry`` lifetime totals,
    decode-cache and object-client tallies). A registered snapshot
    callback copies the authoritative value in with ``set_total`` at
    export time, so the registry is a window onto today's report
    fields, never a second copy that can drift.

Naming convention: ``repro_<subsystem>_<name>{label="..."}`` with
subsystems ``ingest`` / ``restore`` / ``gc`` / ``lock`` / ``reader`` /
``objstore`` / ``store`` / ``scrub`` and the §14 cache hierarchy's
``cache`` (eviction/ghost signals) / ``singleflight`` (cold-decode
collapsing) / ``tier`` (local-disk chunk cache) families;
``_total`` suffixes monotonic counters,
``_seconds`` / ``_bytes`` name units (DESIGN.md §12.2 lists the full
catalog).

CLI: ``python -m repro.api.observe dump TRACE.jsonl`` pretty-prints a
recorded trace (per-op roll-up included); ``... tail TRACE.jsonl -f``
follows a live sink. ``parse_prometheus_text`` is the strict parser the
``make observe-smoke`` gate uses to prove the exposition stays
well-formed (TYPE lines, label escaping, cumulative buckets).
"""
from __future__ import annotations

import itertools
import json
import math
import re
import sys
import threading
import time
from bisect import bisect_left
from collections import deque
from contextlib import contextmanager
from typing import Any, Callable, Iterable, Sequence

__all__ = [
    "BYTES_BUCKETS", "COUNT_BUCKETS", "DEFAULT_RING_EVENTS",
    "SECONDS_BUCKETS", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Observability", "Tracer", "log2_bounds", "parse_prometheus_text",
]

#: Ring size used when ``trace_path`` is set without ``trace_ring_events``.
DEFAULT_RING_EVENTS = 2048


def log2_bounds(lo: int, hi: int) -> tuple[float, ...]:
    """Histogram bucket upper bounds ``2**lo .. 2**hi`` (one per power of
    two) — observations beyond ``2**hi`` land in the implicit +Inf
    bucket. Log2 spacing gives constant relative resolution across the
    decades a latency or size distribution actually spans."""
    return tuple(float(2.0 ** e) for e in range(lo, hi + 1))


#: ~1 µs .. 32 s — covers a cache-hit probe through a full cold restore.
SECONDS_BUCKETS = log2_bounds(-20, 5)
#: 64 B .. 4 GiB — payload spans, ranged-GET sizes, coalesced-run widths.
BYTES_BUCKETS = log2_bounds(6, 32)
#: 1 .. 4096 — small cardinalities (records per run, chunks per op).
COUNT_BUCKETS = log2_bounds(0, 12)


def _label_key(labels: dict[str, Any] | None) -> tuple:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


# --- per-thread shards (the IoTelemetry fold pattern, generalized) -----------


class _Shard:
    """One thread's slice of every metric in a registry. The owning
    thread mutates without locks (dict/list ops are GIL-atomic); readers
    copy via single C-level ``list(...)`` calls, which cannot observe a
    mid-operation state."""

    __slots__ = ("counters", "hists")

    def __init__(self) -> None:
        self.counters: dict[tuple, float] = {}
        # key -> [bucket counts (len(bounds)+1, last = +Inf), value sum]
        self.hists: dict[tuple, list] = {}


class _ShardFold:
    """Thread-local anchor folding its shard on thread exit — same
    mechanism as ``concurrency._Fold``; ``fold_current()`` is the
    explicit path that does not wait for GC."""

    __slots__ = ("_reg", "_shard")

    def __init__(self, reg: "MetricsRegistry", shard: _Shard) -> None:
        self._reg = reg
        self._shard = shard

    def __del__(self) -> None:
        try:
            self._reg._fold(self._shard)
        except Exception:       # interpreter teardown: nothing to save
            pass


# --- metric handles ----------------------------------------------------------


class Counter:
    """Monotonic counter child (one (family, labels) series). ``inc``
    writes this thread's shard; ``set_total`` is for snapshot callbacks
    re-exporting an externally-owned total (derived views)."""

    __slots__ = ("_reg", "_key")

    def __init__(self, reg: "MetricsRegistry", key: tuple) -> None:
        self._reg = reg
        self._key = key

    def inc(self, n: float = 1) -> None:
        c = self._reg._shard().counters
        k = self._key
        c[k] = c.get(k, 0) + n

    def set_total(self, value: float) -> None:
        """Override this series' exported value with an authoritative
        external total (snapshot-time derived views; see module doc)."""
        self._reg._views[self._key] = value


class Gauge:
    """Set-semantics value (current level, not a rate). Global per
    series under the registry lock — gauges are set at snapshot time or
    on slow paths, never in per-chunk loops."""

    __slots__ = ("_reg", "_key")

    def __init__(self, reg: "MetricsRegistry", key: tuple) -> None:
        self._reg = reg
        self._key = key

    def set(self, value: float) -> None:
        with self._reg._lock:
            self._reg._gauges[self._key] = value

    def inc(self, n: float = 1) -> None:
        with self._reg._lock:
            g = self._reg._gauges
            g[self._key] = g.get(self._key, 0) + n


class Histogram:
    """Log2-bucketed distribution child. ``observe`` costs one
    thread-local lookup, one bisect and two list writes — cheap enough
    for per-operation (not per-byte) paths."""

    __slots__ = ("_reg", "_key", "_bounds", "_nb")

    def __init__(self, reg: "MetricsRegistry", key: tuple,
                 bounds: tuple[float, ...]) -> None:
        self._reg = reg
        self._key = key
        self._bounds = bounds
        self._nb = len(bounds) + 1      # +Inf overflow bucket

    def observe(self, value: float) -> None:
        hists = self._reg._shard().hists
        h = hists.get(self._key)
        if h is None:
            h = hists[self._key] = [[0] * self._nb, 0.0]
        h[0][bisect_left(self._bounds, value)] += 1
        h[1] += value


class _Family:
    __slots__ = ("kind", "help", "bounds")

    def __init__(self, kind: str, help_text: str,
                 bounds: tuple[float, ...] | None) -> None:
        self.kind = kind
        self.help = help_text
        self.bounds = bounds


class MetricsRegistry:
    """Store-scoped metric namespace (module docstring). Handle creation
    (``counter``/``gauge``/``histogram``) is create-or-get and may run
    on any thread; handles are cheap to cache and safe to share."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}
        self._series: dict[tuple, str] = {}     # (name, labels) -> kind
        self._live: list[_Shard] = []
        self._dead = _Shard()
        self._gauges: dict[tuple, float] = {}
        self._views: dict[tuple, float] = {}    # set_total overrides
        self._callbacks: list[Callable[[], None]] = []
        self._tl = threading.local()

    # --- family / handle management -----------------------------------------

    def _register(self, name: str, kind: str, help_text: str,
                  labels: dict | None,
                  bounds: tuple[float, ...] | None = None) -> tuple:
        key = (name,) + _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                self._families[name] = _Family(kind, help_text, bounds)
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"not {kind}")
            elif bounds is not None and fam.bounds != bounds:
                raise ValueError(
                    f"histogram {name!r} already registered with "
                    f"different buckets")
            self._series.setdefault(key, kind)
        return key

    def counter(self, name: str, help_text: str = "",
                labels: dict | None = None) -> Counter:
        return Counter(self, self._register(name, "counter", help_text,
                                            labels))

    def gauge(self, name: str, help_text: str = "",
              labels: dict | None = None) -> Gauge:
        return Gauge(self, self._register(name, "gauge", help_text, labels))

    def histogram(self, name: str, help_text: str = "",
                  labels: dict | None = None,
                  bounds: Sequence[float] = SECONDS_BUCKETS) -> Histogram:
        bounds = tuple(float(b) for b in bounds)
        return Histogram(self, self._register(name, "histogram", help_text,
                                              labels, bounds), bounds)

    def register_callback(self, fn: Callable[[], None]) -> None:
        """Run ``fn`` at the start of every snapshot — the derived-view
        hook: copy authoritative external counters in via ``set_total``
        / ``Gauge.set``. Callbacks run *outside* the registry lock, so
        they may take their owners' (leaf) locks freely."""
        with self._lock:
            self._callbacks.append(fn)

    # --- per-thread shard plumbing -------------------------------------------

    def _shard(self) -> _Shard:
        sh = getattr(self._tl, "s", None)
        if sh is None:
            sh = _Shard()
            with self._lock:
                self._live.append(sh)
            self._tl.s = sh
            self._tl.fold = _ShardFold(self, sh)
        return sh

    def _fold(self, shard: _Shard) -> None:
        with self._lock:
            try:
                self._live.remove(shard)
            except ValueError:
                return              # already folded
            self._merge_shard_locked(self._dead, shard)

    def fold_current(self) -> None:
        """Fold the calling thread's shard into the dead aggregate now
        (idempotent; the thread-exit fold becomes a no-op). Pooled
        executors call this between tasks so lifetime totals never
        depend on ``__del__``/GC timing."""
        sh = getattr(self._tl, "s", None)
        if sh is None:
            return
        self._tl.s = None
        self._tl.fold = None
        self._fold(sh)

    @staticmethod
    def _merge_shard_locked(into: _Shard, shard: _Shard) -> None:
        for k, v in list(shard.counters.items()):
            into.counters[k] = into.counters.get(k, 0) + v
        for k, h in list(shard.hists.items()):
            counts = list(h[0])
            tgt = into.hists.get(k)
            if tgt is None:
                into.hists[k] = [counts, h[1]]
            else:
                tc = tgt[0]
                for i, n in enumerate(counts):
                    tc[i] += n
                tgt[1] += h[1]

    # --- snapshots / exporters ----------------------------------------------

    def snapshot(self) -> dict:
        """Point-in-time view of every series, as plain JSON-able data:

            {name: {"type": ..., "help": ..., "samples": [
                {"labels": {...}, "value": v}                  # counter/gauge
                {"labels": {...}, "buckets": [[le, n], ...],   # histogram
                 "count": N, "sum": S}                         # (le "+Inf"
            ]}}                                                #  included)

        Histogram ``count`` is derived from the copied bucket array, so
        a snapshot taken mid-hammer is internally consistent (count ==
        sum of buckets) — totals drift only by in-flight increments,
        the same guarantee ``IoTelemetry.totals`` gives."""
        for cb in list(self._callbacks):
            cb()
        with self._lock:
            merged = _Shard()
            self._merge_shard_locked(merged, self._dead)
            for sh in self._live:
                self._merge_shard_locked(merged, sh)
            gauges = dict(self._gauges)
            views = dict(self._views)
            series = dict(self._series)
            families = {name: (f.kind, f.help, f.bounds)
                        for name, f in self._families.items()}
        out: dict[str, dict] = {}
        for name, (kind, help_text, bounds) in sorted(families.items()):
            out[name] = {"type": kind, "help": help_text, "samples": []}
        for key in sorted(series):
            name, labels = key[0], dict(key[1:])
            kind = series[key]
            fam = out[name]
            if kind == "histogram":
                bounds = families[name][2] or ()
                h = merged.hists.get(key)
                counts = list(h[0]) if h else [0] * (len(bounds) + 1)
                total = h[1] if h else 0.0
                fam["samples"].append({
                    "labels": labels,
                    "buckets": [[b, n] for b, n in zip(bounds, counts)]
                    + [["+Inf", counts[-1]]],
                    "count": sum(counts), "sum": total})
            elif kind == "gauge":
                fam["samples"].append({"labels": labels,
                                       "value": gauges.get(key, 0)})
            else:
                value = merged.counters.get(key, 0) + views.get(key, 0)
                fam["samples"].append({"labels": labels, "value": value})
        return out

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.snapshot(), indent=indent,
                          sort_keys=True) + "\n"

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format version 0.0.4): HELP/TYPE
        lines per family; histogram series expand to cumulative
        ``_bucket{le=...}`` plus ``_sum``/``_count``. Label values are
        escaped per the spec (backslash, quote, newline)."""
        snap = self.snapshot()
        lines: list[str] = []
        for name, fam in snap.items():
            if fam["help"]:
                lines.append(f"# HELP {name} {_escape_help(fam['help'])}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for s in fam["samples"]:
                base = _format_labels(s["labels"])
                if fam["type"] == "histogram":
                    cum = 0
                    for le, n in s["buckets"]:
                        cum += n
                        lbl = _format_labels(
                            dict(s["labels"], le=_format_float(le)))
                        lines.append(f"{name}_bucket{lbl} {cum}")
                    lines.append(f"{name}_sum{base} "
                                 f"{_format_float(s['sum'])}")
                    lines.append(f"{name}_count{base} {s['count']}")
                else:
                    lines.append(f"{name}{base} "
                                 f"{_format_float(s['value'])}")
        return "\n".join(lines) + "\n"


def _format_float(v) -> str:
    if isinstance(v, str):          # the "+Inf" bucket bound
        return v
    if isinstance(v, float):
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        if v == int(v) and abs(v) < 1e15:
            return str(int(v))
        return repr(v)
    return str(v)


def _escape_label(v: str) -> str:
    return (v.replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _escape_help(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def _format_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


# --- exposition parser (the observe-smoke gate) ------------------------------

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_NAME_RE = re.compile(r"[a-zA-Z_][a-zA-Z0-9_]*")
_ESCAPES = {"\\": "\\", '"': '"', "n": "\n"}


def _parse_sample_line(line: str) -> tuple[str, dict, float]:
    i = 0
    while i < len(line) and line[i] not in "{ ":
        i += 1
    name = line[:i]
    if not _NAME_RE.match(name):
        raise ValueError(f"bad metric name in line {line!r}")
    labels: dict[str, str] = {}
    if i < len(line) and line[i] == "{":
        i += 1
        while i < len(line) and line[i] != "}":
            m = _LABEL_NAME_RE.match(line, i)
            if not m:
                raise ValueError(f"bad label name in line {line!r}")
            lname = m.group(0)
            i = m.end()
            if line[i:i + 2] != '="':
                raise ValueError(f"bad label syntax in line {line!r}")
            i += 2
            out: list[str] = []
            while True:
                if i >= len(line):
                    raise ValueError(f"unterminated label in {line!r}")
                ch = line[i]
                if ch == "\\":
                    esc = _ESCAPES.get(line[i + 1:i + 2])
                    if esc is None:
                        raise ValueError(f"bad escape in line {line!r}")
                    out.append(esc)
                    i += 2
                elif ch == '"':
                    i += 1
                    break
                else:
                    out.append(ch)
                    i += 1
            labels[lname] = "".join(out)
            if i < len(line) and line[i] == ",":
                i += 1
        if i >= len(line) or line[i] != "}":
            raise ValueError(f"unterminated label set in {line!r}")
        i += 1
    rest = line[i:].strip()
    if not rest or " " in rest:     # no timestamps in our exposition
        raise ValueError(f"bad sample value in line {line!r}")
    try:
        value = float(rest)
    except ValueError:
        raise ValueError(f"non-numeric sample value in line {line!r}") \
            from None
    return name, labels, value


def parse_prometheus_text(text: str) -> dict:
    """Strict parser/validator for ``to_prometheus`` output. Returns

        {"types": {family: kind},
         "samples": [(name, labels_dict, value), ...]}

    and raises ``ValueError`` on any malformed line, a sample whose
    family has no TYPE line, or a histogram whose cumulative buckets
    decrease / disagree with ``_count`` — the checks ``make
    observe-smoke`` runs against a live store's exposition."""
    types: dict[str, str] = {}
    samples: list[tuple[str, dict, float]] = []
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(f"bad comment line {line!r}")
            if parts[1] == "TYPE":
                if parts[3] if len(parts) > 3 else "" not in (
                        "counter", "gauge", "histogram"):
                    kind = parts[3] if len(parts) > 3 else ""
                    if kind not in ("counter", "gauge", "histogram"):
                        raise ValueError(f"bad TYPE line {line!r}")
                types[parts[2]] = parts[3]
            continue
        samples.append(_parse_sample_line(line))

    def family_of(name: str) -> str:
        for suffix in ("_bucket", "_sum", "_count"):
            base = name[:-len(suffix)] if name.endswith(suffix) else None
            if base and types.get(base) == "histogram":
                return base
        return name

    hist_buckets: dict[tuple, list[float]] = {}
    hist_counts: dict[tuple, float] = {}
    for name, labels, value in samples:
        fam = family_of(name)
        if fam not in types:
            raise ValueError(f"sample {name!r} has no TYPE line")
        if types[fam] == "histogram":
            series = (fam,) + _label_key(
                {k: v for k, v in labels.items() if k != "le"})
            if name.endswith("_bucket"):
                if "le" not in labels:
                    raise ValueError(f"histogram bucket without le: "
                                     f"{name} {labels}")
                hist_buckets.setdefault(series, []).append(value)
            elif name.endswith("_count"):
                hist_counts[series] = value
    for series, cums in hist_buckets.items():
        if any(b > a for a, b in zip(cums[1:], cums)):
            raise ValueError(f"non-cumulative histogram buckets for "
                             f"{series[0]}")
        count = hist_counts.get(series)
        if count is not None and cums and cums[-1] != count:
            raise ValueError(
                f"histogram {series[0]}: +Inf bucket {cums[-1]} != "
                f"_count {count}")
    return {"types": types, "samples": samples}


# --- trace layer -------------------------------------------------------------


class Tracer:
    """Structured per-operation spans (module docstring). ``record``
    books a completed operation retroactively (the instrumented code
    already timed it); ``span`` is the context-manager form for code
    that has no timer of its own. Events are plain dicts::

        {"op": str, "id": int, "parent": int|None, "tid": int,
         "t0": epoch-seconds, "s": duration-seconds, **labels}

    kept in a bounded ring (oldest evicted) and/or appended — one JSON
    object per line, flushed per event so ``tail -f``-style followers
    see them live — to a JSONL sink."""

    def __init__(self, ring_events: int = DEFAULT_RING_EVENTS,
                 path: str | None = None) -> None:
        self.ring_events = max(0, int(ring_events))
        self.path = path
        self._ring: deque | None = (deque(maxlen=self.ring_events)
                                    if self.ring_events else None)
        self._file = open(path, "a", encoding="utf-8") if path else None
        self._wlock = threading.Lock()
        self._ids = itertools.count(1)

    def record(self, op: str, seconds: float, *, t0: float | None = None,
               parent: int | None = None, **labels) -> int:
        """Book one completed span; returns its id (pass as ``parent``
        to attach stage children to an operation)."""
        span_id = next(self._ids)
        # structural fields win over same-named labels — a label called
        # "op" must not clobber the span's identity
        event = dict(labels)
        event.update({"op": op, "id": span_id, "parent": parent,
                      "tid": threading.get_ident(),
                      "t0": time.time() - seconds if t0 is None else t0,
                      "s": float(seconds)})
        ring = self._ring
        if ring is not None:
            ring.append(event)
        f = self._file
        if f is not None:
            line = json.dumps(event, default=str)
            with self._wlock:
                f.write(line + "\n")
                f.flush()
        return span_id

    @contextmanager
    def span(self, op: str, parent: int | None = None, **labels):
        """Time a block as one span; the yielded dict is the label set
        (mutate it to attach results discovered inside the block)."""
        lbl = dict(labels)
        t0 = time.time()
        t0p = time.perf_counter()
        try:
            yield lbl
        finally:
            self.record(op, time.perf_counter() - t0p, t0=t0,
                        parent=parent, **lbl)

    def events(self) -> list[dict]:
        """Ring contents, oldest first (empty if no ring configured)."""
        ring = self._ring
        return list(ring) if ring is not None else []

    def ops(self) -> dict[str, int]:
        """Per-op event counts over the current ring."""
        out: dict[str, int] = {}
        for e in self.events():
            out[e["op"]] = out.get(e["op"], 0) + 1
        return out

    def close(self) -> None:
        f, self._file = self._file, None
        if f is not None:
            with self._wlock:
                f.close()


class Observability:
    """What a ``DedupStore`` owns: always a registry, and a tracer only
    when tracing was asked for (``trace_path`` and/or
    ``trace_ring_events`` — a path alone gets the default ring too, so
    ``store.observe.tracer.events()`` works whenever tracing is on)."""

    def __init__(self, trace_path: str | None = None,
                 trace_ring_events: int | None = None) -> None:
        self.metrics = MetricsRegistry()
        ring = trace_ring_events
        if trace_path is not None and not ring:
            ring = DEFAULT_RING_EVENTS
        self.tracer = (Tracer(ring or 0, trace_path)
                       if (trace_path or ring) else None)

    def close(self) -> None:
        if self.tracer is not None:
            self.tracer.close()


# --- CLI: dump / tail over a JSONL trace sink (§12.4) ------------------------


def _iter_trace(path: str) -> Iterable[dict]:
    with open(path, "r", encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{lineno}: not JSONL ({e})")


def _format_event(e: dict) -> str:
    meta = {"op", "id", "parent", "tid", "t0", "s"}
    lbl = " ".join(f"{k}={e[k]}" for k in sorted(e) if k not in meta)
    clock = time.strftime("%H:%M:%S", time.localtime(e.get("t0", 0)))
    parent = f"<{e['parent']} " if e.get("parent") else ""
    return (f"{clock} tid={e.get('tid', '?'):<8} #{e.get('id', '?'):<5} "
            f"{parent}{e.get('op', '?'):<20} "
            f"{1e3 * float(e.get('s', 0)):>10.3f} ms  {lbl}")


def _cmd_dump(args) -> int:
    events = [e for e in _iter_trace(args.trace)
              if args.op is None or e.get("op") == args.op]
    shown = events[-args.limit:] if args.limit else events
    for e in shown:
        print(_format_event(e))
    by_op: dict[str, list[float]] = {}
    for e in events:
        by_op.setdefault(e.get("op", "?"), []).append(float(e.get("s", 0)))
    print(f"# {len(events)} spans, {len(by_op)} ops")
    for op in sorted(by_op):
        ss = sorted(by_op[op])
        print(f"#   {op:<22} n={len(ss):<6} total={sum(ss):.4f}s "
              f"p50={1e3 * ss[len(ss) // 2]:.3f}ms "
              f"max={1e3 * ss[-1]:.3f}ms")
    return 0


def _cmd_tail(args) -> int:
    deadline = (time.monotonic() + args.timeout) if args.timeout else None
    shown = 0
    with open(args.trace, "r", encoding="utf-8") as f:
        if not args.from_start:
            f.seek(0, 2)
        buf = ""
        while True:
            chunk = f.readline()
            if chunk:
                buf += chunk
                if not buf.endswith("\n"):      # partial line: keep waiting
                    continue
                line, buf = buf.strip(), ""
                if line:
                    try:
                        print(_format_event(json.loads(line)))
                    except json.JSONDecodeError:
                        print(f"? {line}")
                    shown += 1
                    if args.max_events and shown >= args.max_events:
                        return 0
                continue
            if not args.follow:
                return 0
            if deadline is not None and time.monotonic() >= deadline:
                return 0
            time.sleep(0.2)


def main(argv: Sequence[str] | None = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.api.observe",
        description="Pretty-print or follow a JSONL trace sink written "
                    "by a store with DedupConfig.trace_path set "
                    "(DESIGN.md §12.4).")
    sub = ap.add_subparsers(dest="cmd", required=True)
    dp = sub.add_parser("dump", help="pretty-print a recorded trace "
                                     "with a per-op roll-up")
    dp.add_argument("trace", help="trace JSONL file")
    dp.add_argument("--op", default=None, help="show only this op")
    dp.add_argument("--limit", type=int, default=0,
                    help="show only the last N spans (0 = all)")
    tp = sub.add_parser("tail", help="print spans as they are appended")
    tp.add_argument("trace", help="trace JSONL file")
    tp.add_argument("-f", "--follow", action="store_true",
                    help="keep waiting for new spans (default: stop at "
                         "end of file)")
    tp.add_argument("--from-start", action="store_true",
                    help="start at the beginning, not the current end")
    tp.add_argument("--max-events", type=int, default=0,
                    help="stop after printing N spans (0 = unbounded)")
    tp.add_argument("--timeout", type=float, default=0,
                    help="stop following after S seconds (0 = forever)")
    args = ap.parse_args(argv)
    return {"dump": _cmd_dump, "tail": _cmd_tail}[args.cmd](args)


if __name__ == "__main__":      # pragma: no cover - thin; logic is main()
    # defer to the canonical module (same pattern as objectstore's CLI)
    from repro.api import observe as _canonical
    sys.exit(_canonical.main(sys.argv[1:]))
