"""Name -> factory registries for every pluggable pipeline component.

Four registries, one per seam the pipeline varies along (the CDC survey
literature's observation that chunkers, resemblance schemes, and stores
evolve independently):

    detectors   "card", "finesse", "n-transform", "dedup-only"
    indexes     "exact" (cosine top-1), "banded-lsh" (SimHash banding)
    chunkers    "fastcdc" (a ChunkerConfig factory); custom chunker
                factories must return an object with
                ``chunk(stream) -> (chunks, stream_hashes)`` — the store
                dispatches through ``repro.api.store.chunk_with``
    backends    "memory", "file", "objectstore", "s3" container backends
    policies    "eager", "threshold", "never" reclamation policies
                (DESIGN.md §7.4) — when a delete should trigger compaction
    cache policies  "lru", "arc" decode-cache eviction policies
                (DESIGN.md §14.1) — factories taking ``budget_bytes`` and
                returning a ``CachePolicy`` (api/restore.py)

Built-ins register themselves via the decorators at their definition site
(e.g. ``@register_index("exact")`` in core/similarity.py); third-party
code registers the same way. Lookup is by name through ``get_*``; the
declarative config path (api/config.py) resolves every component here so
benchmarks, examples, and the checkpoint store construct pipelines one
way.

This module imports nothing from repro.core at module scope — core modules
import *it* for the decorators — so there is no import cycle. Built-in
registration is triggered lazily on first lookup.
"""
from __future__ import annotations

from typing import Any, Callable, TypeVar

F = TypeVar("F", bound=Callable[..., Any])

_DETECTORS: dict[str, Callable[..., Any]] = {}
_INDEXES: dict[str, Callable[..., Any]] = {}
_CHUNKERS: dict[str, Callable[..., Any]] = {}
_BACKENDS: dict[str, Callable[..., Any]] = {}
_POLICIES: dict[str, Callable[..., Any]] = {}
_CACHE_POLICIES: dict[str, Callable[..., Any]] = {}

_builtins_loaded = False


def _ensure_builtins() -> None:
    """Import the modules whose import side effect registers built-ins."""
    global _builtins_loaded
    if _builtins_loaded:
        return
    from repro.api import containers, lifecycle, objectstore  # noqa: F401
    from repro.core import chunking, pipeline, similarity  # noqa: F401
    _CHUNKERS.setdefault("fastcdc", chunking.ChunkerConfig)
    # only after every import succeeded — a failure above must surface
    # again on the next lookup, not leave the registries silently empty
    _builtins_loaded = True


def _make_register(table: dict[str, Callable[..., Any]],
                   kind: str) -> Callable[[str], Callable[[F], F]]:
    def register(name: str) -> Callable[[F], F]:
        def deco(factory: F) -> F:
            existing = table.get(name)
            if existing is not None and existing is not factory:
                raise ValueError(f"{kind} {name!r} already registered")
            table[name] = factory
            return factory
        return deco
    return register


def _make_get(table: dict[str, Callable[..., Any]],
              kind: str) -> Callable[[str], Callable[..., Any]]:
    def get(name: str) -> Callable[..., Any]:
        _ensure_builtins()
        try:
            return table[name]
        except KeyError:
            raise KeyError(
                f"unknown {kind} {name!r}; available: "
                f"{sorted(table)}") from None
    return get


def _make_available(table: dict[str, Callable[..., Any]]) -> Callable[[], list[str]]:
    def available() -> list[str]:
        _ensure_builtins()
        return sorted(table)
    return available


register_detector = _make_register(_DETECTORS, "detector")
register_index = _make_register(_INDEXES, "index")
register_chunker = _make_register(_CHUNKERS, "chunker")
register_backend = _make_register(_BACKENDS, "backend")
register_policy = _make_register(_POLICIES, "policy")
register_cache_policy = _make_register(_CACHE_POLICIES, "cache policy")

get_detector = _make_get(_DETECTORS, "detector")
get_index = _make_get(_INDEXES, "index")
get_chunker = _make_get(_CHUNKERS, "chunker")
get_backend = _make_get(_BACKENDS, "backend")
get_policy = _make_get(_POLICIES, "policy")
get_cache_policy = _make_get(_CACHE_POLICIES, "cache policy")

available_detectors = _make_available(_DETECTORS)
available_indexes = _make_available(_INDEXES)
available_chunkers = _make_available(_CHUNKERS)
available_backends = _make_available(_BACKENDS)
available_policies = _make_available(_POLICIES)
available_cache_policies = _make_available(_CACHE_POLICIES)
