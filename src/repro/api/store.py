"""Session-oriented dedup + delta-compression store (DESIGN.md §2.2).

The store composes the three pluggable seams — a (possibly staged)
detector, a chunker config, and a ``ContainerBackend`` — and owns the
policy between them: exact dedup by content digest, the delta-vs-raw
decision, and accounting.

Ingestion is transactional per stream:

    session = store.open_stream()
    session.write(part1); session.write(part2)   # stage bytes
    report = session.commit()                    # chunk/detect/store
    store.restore(report.handle)                 # byte-identical

``commit()`` returns an immutable per-stream ``IngestReport`` (handle,
per-stream DCR, chunk/dup/delta counts, detect time); the store-lifetime
``StoreStats`` aggregate is the running sum of all reports plus fit time.
Until ``commit()`` a session has only buffered bytes in memory — no
chunking, no detector state, no backend writes — so an *abandoned*
session leaves no trace. A commit that *fails mid-storage* is messier:
records already appended by it persist as unreferenced garbage (swept by
compaction, recovered from by the torn-tail scan), but it still admits
nothing to the detector index and registers no digests, because that
bookkeeping runs only after every backend write succeeded. Storage is a
group commit (DESIGN.md §8): delta decisions run over a worklist first,
then the whole stream lands as one batched backend write (``put_many``),
one recipe append and one flush.

The serving path (DESIGN.md §9) is ``restore(handle)`` plus the two
ranged primitives: ``restore_iter`` yields chunk-aligned views without
materializing the stream, and ``restore_range`` decodes only the chunks
a byte range overlaps (recipe prefix sums, persisted with the recipe).
All three go through the restore planner + ``ContainerBackend.get_many``
so shared base chains decode once per call, and record per-call
``RestoreReport`` telemetry (``store.last_restore``, aggregated on
``StoreStats``).

The v0 surface (``ingest``, integer stream indexes for ``restore``)
remains as thin wrappers: handles are assigned densely in commit order, so
v0 callers keep working unchanged.

Space reclamation (DESIGN.md §7) is delegated to ``repro.api.lifecycle``:
``delete(handle)`` retires a stream and decrefs its chunks (chunks another
stream's patch depends on stay pinned), ``collect()`` is the mark-sweep
accounting pass, ``compact()`` rewrites the container without dead
records, rebasing surviving patches whose base was evicted. The
``RefcountTable`` is rebuilt from the backend on open, so a store reopened
on an existing directory can delete/compact streams it did not ingest.
"""
from __future__ import annotations

import inspect
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Sequence

import numpy as np

from repro.api import containers, lifecycle
from repro.api.concurrency import (DeadlineExceededError, LockTimeout, RWLock,
                                   accumulate, check_deadline, remaining_time,
                                   zero_deltas)
from repro.api.detect import is_staged
from repro.api.refcount import RefcountTable
from repro.api.restore import RecipeLayout
from repro.api.types import DetectBatch, IngestReport, RestoreReport, StoreStats
from repro.core import chunking, delta


def _accepts_lengths(add_recipe: Any) -> bool:
    """Whether a backend's ``add_recipe`` takes the ``lengths`` argument
    (§9.3); conservatively False when the signature is uninspectable —
    the store then falls back to materialize-once for ranged reads."""
    try:
        params = inspect.signature(add_recipe).parameters
    except (TypeError, ValueError):
        return False
    return "lengths" in params or any(
        p.kind is inspect.Parameter.VAR_POSITIONAL
        for p in params.values())


def chunk_with(chunker: Any, stream: bytes):
    """Dispatch chunking through a registered chunker.

    Custom chunkers implement ``chunk(stream) -> (chunks, stream_hashes)``
    where chunks are ``repro.core.chunking.Chunk`` and stream_hashes are
    the per-position window hashes detectors reuse (may be the gear scan
    or the chunker's own). Anything without a ``chunk`` method is treated
    as a FastCDC ``ChunkerConfig`` (the "fastcdc" builtin) and goes
    through the device gear-scan program (kernels/ingest, DESIGN.md §8):
    bytes go up, bit-packed boundary-candidate maps come back, and the
    returned stream hashes are a device-resident ``StreamScan`` that
    fused detectors consume without a round-trip (legacy consumers can
    index it like the old numpy array).
    """
    if hasattr(chunker, "chunk"):
        return chunker.chunk(stream)
    buf = np.frombuffer(stream, dtype=np.uint8)
    n = len(buf)
    if n == 0:
        return [], np.zeros(0, np.uint32)
    from repro.kernels import ingest as kingest
    scan, cand_s, cand_l = kingest.scan_stream(
        buf, chunker.mask_s, chunker.mask_l)
    bounds = chunking.select_boundaries(n, cand_s, cand_l, chunker)
    return chunking.chunks_from_bounds(stream, bounds), scan


class StreamSession:
    """Write-then-commit handle for ingesting one stream. After a
    successful ``commit()`` (including via the context manager) the
    IngestReport is also available as ``session.report``."""

    def __init__(self, store: "DedupStore") -> None:
        self._store = store
        self._parts: list[bytes] = []
        self._closed = False
        self.report: IngestReport | None = None

    def write(self, data: bytes) -> None:
        if self._closed:
            raise RuntimeError("stream session already committed/aborted")
        self._parts.append(bytes(data))

    def commit(self) -> IngestReport:
        if self._closed:
            raise RuntimeError("stream session already committed/aborted")
        self._closed = True
        self.report = self._store._commit_stream(b"".join(self._parts))
        return self.report

    def abort(self) -> None:
        self._closed = True
        self._parts.clear()

    def __enter__(self) -> "StreamSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._closed:
            if exc_type is None:
                self.commit()
            else:
                self.abort()


class DedupStore:
    """Container store with exact dedup + detector-driven delta compression."""

    def __init__(self, detector: Any,
                 chunker_cfg: chunking.ChunkerConfig | None = None,
                 backend: containers.ContainerBackend | None = None,
                 policy: Any | None = None,
                 trace_path: str | None = None,
                 trace_ring_events: int | None = None):
        self.detector = detector
        self.cfg = chunker_cfg or chunking.ChunkerConfig()
        self.backend = backend if backend is not None else containers.InMemoryBackend()
        self.policy = policy if policy is not None else lifecycle.NeverPolicy()
        self.stats = StoreStats()
        self.reports: list[IngestReport] = []
        self._by_digest: dict[bytes, int] = {}
        # a reopened (file-backed) backend already holds chunk ids; start
        # past them so new chunks never shadow persisted records
        self._next_id = self.backend.max_chunk_id() + 1
        # capability probe, once: third-party backends may predate the
        # two-argument add_recipe (§9.3). Probing the signature up front
        # beats catching TypeError around the call — a TypeError raised
        # *inside* a new-signature backend after it mutated state must
        # propagate, not trigger a second (duplicating) append.
        self._recipe_lengths_ok = _accepts_lengths(self.backend.add_recipe)
        self._refs = RefcountTable.rebuild(self.backend)
        # ranged-restore prefix sums per handle (DESIGN.md §9.3), built
        # lazily; dropped on delete, *kept* across compaction (lengths
        # are invariant under rebasing)
        self._layouts: dict[int, RecipeLayout] = {}
        self.last_restore: RestoreReport | None = None
        # concurrent serving (DESIGN.md §10.4): restores and commits take
        # the shared side, lifecycle mutations (delete/collect/compact —
        # they swap the backend's index and reopen its read fds) the
        # exclusive side; commits are additionally serialized against
        # each other, and the aggregate stats/layout caches have their
        # own leaf mutex. The prefetch pool runs restore_iter's
        # next-batch fetches (§10.3), created on first use.
        # observability (DESIGN.md §12): every store owns a metrics
        # registry; the tracer exists only when tracing was configured.
        # Must be built before the lifecycle lock (its wait-time
        # observer) and before the backend binding below.
        self._init_observability(trace_path, trace_ring_events)
        self._lifecycle_lock = RWLock(observer=self._observe_lock_wait)
        self._commit_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._prefetch: ThreadPoolExecutor | None = None
        # two close flags (§10.4): _closed flips first (under the stats
        # lock) and stops prefetch-pool (re)creation; _backend_closed
        # flips under the exclusive lifecycle lock right before the
        # backend closes, so fetches that were in flight when close()
        # started — including the drained prefetch tasks — still finish,
        # while any fetch arriving after gets a clean RuntimeError
        self._closed = False
        self._backend_closed = False
        # bound once: per-thread backend telemetry hook (None -> the
        # global-attr fallback in _backend_counters)
        self._io_counters = getattr(self.backend, "io_counters", None)
        self._fold_io = getattr(self.backend, "fold_io_counters", None)
        # route the backend's own counters through the registry as
        # derived views (+ native run-width/request histograms there)
        bind = getattr(self.backend, "bind_observability", None)
        if bind is not None:
            bind(self.observe)
        self._refresh_lifecycle_stats()

    def _init_observability(self, trace_path: str | None,
                            trace_ring_events: int | None) -> None:
        from repro.api import observe as om   # local: keeps module import
        self.observe = om.Observability(      # light for the observe CLI
            trace_path=trace_path, trace_ring_events=trace_ring_events)
        m = self.observe.metrics
        # native ingest/restore instruments (recorded at the event);
        # handles are pre-created so every family appears in the
        # exposition from the first snapshot, zeros included
        self._c_ingest_commits = m.counter(
            "repro_ingest_commits_total", "Committed stream sessions")
        self._c_ingest_bytes = {
            d: m.counter("repro_ingest_bytes_total",
                         "Stream bytes in vs. container bytes stored",
                         labels={"dir": d}) for d in ("in", "stored")}
        self._c_ingest_chunks = {
            k: m.counter("repro_ingest_chunks_total",
                         "Chunk dispositions at commit (DESIGN.md §2.2)",
                         labels={"kind": k})
            for k in ("dup", "delta", "raw")}
        self._h_ingest_stage = {
            s: m.histogram("repro_ingest_stage_seconds",
                           "Per-commit ingest phase timings (§8)",
                           labels={"stage": s}, bounds=om.SECONDS_BUCKETS)
            for s in ("chunk", "extract", "score", "observe", "delta",
                      "store")}
        self._c_restore_ops = {
            s: m.counter("repro_restore_ops_total",
                         "Restore calls by serving surface (§9)",
                         labels={"surface": s})
            for s in ("full", "iter", "range")}
        self._c_restore_bytes = {
            d: m.counter("repro_restore_bytes_total",
                         "Bytes served vs. physical payload bytes read",
                         labels={"dir": d}) for d in ("out", "read")}
        self._h_restore_stage = {
            s: m.histogram("repro_restore_stage_seconds",
                           "Per-restore wall/read/decode timings (§9)",
                           labels={"stage": s}, bounds=om.SECONDS_BUCKETS)
            for s in ("total", "read", "decode")}
        self._h_restore_requests = m.histogram(
            "repro_restore_requests",
            "Physical payload reads (preads / ranged GETs) per restore",
            bounds=om.COUNT_BUCKETS)
        self._h_lock_wait = {
            s: m.histogram("repro_lock_wait_seconds",
                           "RWLock acquire wait time — the §10 "
                           "lock-contention signal",
                           labels={"lock": "lifecycle", "side": s},
                           bounds=om.SECONDS_BUCKETS)
            for s in ("read", "write")}
        # lifecycle gauges are derived views over StoreStats — the
        # authoritative aggregate — copied in at snapshot time
        g_bytes = {k: m.gauge("repro_store_bytes",
                              "Store accounting (live/dead per §7.2)",
                              labels={"kind": k})
                   for k in ("in", "stored", "live", "dead", "reclaimed")}
        g_dcr = m.gauge("repro_store_dcr",
                        "Lifetime data compression ratio (bytes_in / "
                        "bytes_stored)")
        g_streams = m.gauge("repro_store_streams", "Committed streams")

        def _export_store_views() -> None:
            with self._stats_lock:
                s = self.stats
                vals = {"in": s.bytes_in, "stored": s.bytes_stored,
                        "live": s.live_bytes, "dead": s.dead_bytes,
                        "reclaimed": s.reclaimed_bytes}
                dcr = s.dcr
                streams = len(self.reports)
            for k, v in vals.items():
                g_bytes[k].set(v)
            g_dcr.set(dcr)
            g_streams.set(streams)

        m.register_callback(_export_store_views)

    def _observe_lock_wait(self, side: str, seconds: float) -> None:
        self._h_lock_wait[side].observe(seconds)

    def metrics(self):
        """The store's ``MetricsRegistry`` (DESIGN.md §12) — call
        ``.to_prometheus()`` / ``.to_json()`` / ``.snapshot()`` on it.
        Also reachable as ``store.observe.metrics``."""
        return self.observe.metrics

    def cache_stats(self) -> dict:
        """Lifetime cache-hierarchy signals (DESIGN.md §14) as one flat
        dict: eviction-policy name plus ghost hits and evictions from the
        decode cache, cold-decode singleflight waits/collapsed and the
        total decode count, and the local-disk tier's hit/miss/byte/drop
        tallies when a tier is configured. Every key reads straight off
        the backend (derived view, never a second copy); backends without
        the §14 read engine (memory, third-party) report zeros."""
        b = self.backend
        cache = getattr(b, "_cache", None)
        out = {
            "policy": getattr(cache, "policy_name", None),
            "ghost_hits": getattr(cache, "ghost_hits", 0),
            "evictions": getattr(cache, "evictions", 0),
            "singleflight_waits": getattr(b, "_sf_waits", 0),
            "singleflight_collapsed": getattr(b, "_sf_collapsed", 0),
            "decoded_chunks": getattr(b, "decoded_chunks", 0),
        }
        tier = getattr(b, "_tier", None)
        out["tier"] = None if tier is None else {
            "bytes": tier.bytes, "entries": len(tier),
            "hits": tier.hits, "misses": tier.misses,
            "bytes_served": tier.bytes_served,
            "bytes_filled": tier.bytes_filled, "dropped": tier.dropped,
        }
        return out

    def fit(self, training_streams: Sequence[bytes]) -> None:
        t0 = time.perf_counter()
        self.detector.fit(training_streams, self.cfg)
        self.stats.fit_seconds += time.perf_counter() - t0

    def open_stream(self) -> StreamSession:
        return StreamSession(self)

    def ingest(self, stream: bytes) -> StoreStats:
        """v0 surface: one-shot session commit; returns the aggregate."""
        session = self.open_stream()
        session.write(stream)
        session.commit()
        return self.stats

    def _commit_stream(self, stream: bytes) -> IngestReport:
        # one commit at a time (id assignment, digest table, one group
        # commit in flight); commits run concurrently with restores but
        # are excluded from lifecycle mutations (DESIGN.md §10.4).
        # Under a deadline scope (§15.3) both lock waits are bounded:
        # shedding here — before any chunking work — is the cheap place.
        check_deadline("commit")
        t = remaining_time()
        if t is None:
            self._commit_lock.acquire()
        elif not self._commit_lock.acquire(timeout=max(0.0, t)):
            raise DeadlineExceededError("commit (commit-lock wait)")
        try:
            self._acquire_read_deadline("commit")
            try:
                # post-close contract: fail here, before the chunk/detect
                # passes run, instead of dying on the closed append handle
                # after the work is done
                self._check_open()
                return self._commit_stream_locked(stream)
            finally:
                self._lifecycle_lock.release_read()
        finally:
            self._commit_lock.release()

    def _commit_stream_locked(self, stream: bytes) -> IngestReport:
        # pass 0: chunk
        t0 = time.perf_counter()
        chunks, stream_hashes = chunk_with(self.cfg, stream)
        chunk_seconds = time.perf_counter() - t0

        # pass 1: exact dedup; assign ids
        n = len(chunks)
        ids = np.empty(n, np.int64)
        is_new = np.zeros(n, bool)
        digests = [ck.digest for ck in chunks]
        seen_in_stream: dict[bytes, int] = {}
        for i, dig in enumerate(digests):
            ref = self._by_digest.get(dig)
            if ref is None:
                ref = seen_in_stream.get(dig)
            if ref is not None:
                ids[i] = ref
            else:
                ids[i] = self._next_id
                self._next_id += 1
                is_new[i] = True
                seen_in_stream[dig] = int(ids[i])

        # deadline probes (§15.3) run only in passes 0-3a — after the
        # first pass-3b backend write the commit must finish (aborting
        # mid-group-commit would orphan records the bookkeeping below
        # never learned about)
        check_deadline("commit")

        # pass 2: resemblance detection (batched, staged). For staged
        # detectors, index admission (`observe`) is deferred until the
        # backend writes succeed, so a commit that fails mid-storage
        # admits nothing to the detector index. Legacy single-call
        # detectors mutate inside detect() and can't make that promise.
        # A zero-chunk stream (``ingest(b"")``) never reaches a detector
        # at all — neither path is required to accept an empty batch.
        extract_seconds = score_seconds = observe_seconds = 0.0
        batch = DetectBatch(chunks=chunks, ids=ids, is_new=is_new,
                            stream_hashes=stream_hashes)
        staged = n > 0 and is_staged(self.detector)
        feats = None
        if n == 0:
            base_ids = np.empty(0, np.int64)
        elif staged:
            t0 = time.perf_counter()
            feats = self.detector.extract(batch)
            extract_seconds = time.perf_counter() - t0
            t0 = time.perf_counter()
            base_ids = self.detector.score(feats, batch).base_ids
            score_seconds = time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            base_ids = np.asarray(
                self.detector.detect(chunks, ids, is_new, stream_hashes),
                np.int64)
            score_seconds = time.perf_counter() - t0

        # pass 3a: delta-vs-raw decisions over a worklist — every
        # delta.encode runs here, back to back, with no backend I/O
        # interleaved. A same-stream base that is not persisted yet is
        # resolved from the staged records (identical semantics to the
        # old put-then-lookup interleaving).
        backend = self.backend
        bytes_in = sum(ck.length for ck in chunks)
        bytes_stored = 0
        # per-record container overhead (headers etc.), backend-reported
        # so per-stream DCR matches the real on-disk footprint —
        # FileBackend's record header is 25 bytes, not a nominal 8
        overhead = int(getattr(backend, "record_overhead", 0))
        dup_chunks = int(n - is_new.sum())
        delta_chunks = raw_chunks = 0
        delta_seconds = 0.0
        staged_data: dict[int, bytes] = {}
        records: list[tuple[int, int, bytes, bytes | None]] = []
        check_deadline("commit")
        for i in np.flatnonzero(is_new):
            check_deadline("commit")    # last shed point: nothing written yet
            ck = chunks[i]
            cid = int(ids[i])
            entry = None
            base = int(base_ids[i])
            if base >= 0:
                base_data = staged_data.get(base)
                if base_data is None and backend.contains(base):
                    base_data = backend.get(base)
                if base_data is not None:
                    t0 = time.perf_counter()
                    d = delta.encode(ck.data, base_data)
                    delta_seconds += time.perf_counter() - t0
                    if len(d) < ck.length:
                        entry = (cid, base, d, ck.data)
                        bytes_stored += len(d) + overhead
                        delta_chunks += 1
            if entry is None:
                entry = (cid, -1, ck.data, None)
                bytes_stored += ck.length + overhead
                raw_chunks += 1
            records.append(entry)
            staged_data[cid] = ck.data

        # pass 3b: one batched backend write + recipe + flush (group
        # commit: a stream is a single buffered append, DESIGN.md §8).
        # Refcount/digest bookkeeping happens only after the writes
        # succeed, so a failed commit cannot leave digests pointing at
        # payloads that were never stored.
        t0 = time.perf_counter()
        put_many = getattr(backend, "put_many", None)
        if put_many is not None:
            put_many(records)
        else:                       # third-party backends: per-chunk puts
            for cid, base, payload, data in records:
                if base < 0:
                    backend.put_raw(cid, payload)
                else:
                    backend.put_delta(cid, base, payload, data=data)
        for i, (cid, base, payload, _) in zip(np.flatnonzero(is_new),
                                              records):
            self._refs.track(cid, base, len(payload))
            self._by_digest[digests[i]] = cid
        recipe = [int(c) for c in ids]
        if self._recipe_lengths_ok:     # persist materialized lengths
            handle = backend.add_recipe(recipe,     # for ranged restores
                                        [int(ck.length) for ck in chunks])
        else:                           # pre-§9 backend signature
            handle = backend.add_recipe(recipe)
        for cid in recipe:      # only now do the chunks become live
            self._refs.incref_recipe(cid)
        backend.flush()
        store_seconds = time.perf_counter() - t0

        if staged:
            t0 = time.perf_counter()
            self.detector.observe(feats, batch)
            observe_seconds = time.perf_counter() - t0

        report = IngestReport(
            handle=handle, bytes_in=bytes_in, bytes_stored=bytes_stored,
            chunks=n, dup_chunks=dup_chunks, delta_chunks=delta_chunks,
            raw_chunks=raw_chunks,
            detect_seconds=extract_seconds + score_seconds + observe_seconds,
            chunk_seconds=chunk_seconds, delta_seconds=delta_seconds,
            extract_seconds=extract_seconds, score_seconds=score_seconds,
            observe_seconds=observe_seconds, store_seconds=store_seconds)
        with self._stats_lock:
            self.reports.append(report)
            self.stats.absorb(report)
            self._refresh_lifecycle_stats()
        self._observe_ingest(report)
        return report

    def _observe_ingest(self, r: IngestReport) -> None:
        """Record one commit into the registry (and ring, when tracing):
        the stage timings the report already measured — no new timers on
        the ingest path (DESIGN.md §12.3)."""
        self._c_ingest_commits.inc()
        self._c_ingest_bytes["in"].inc(r.bytes_in)
        self._c_ingest_bytes["stored"].inc(r.bytes_stored)
        self._c_ingest_chunks["dup"].inc(r.dup_chunks)
        self._c_ingest_chunks["delta"].inc(r.delta_chunks)
        self._c_ingest_chunks["raw"].inc(r.raw_chunks)
        stages = (("chunk", r.chunk_seconds), ("extract", r.extract_seconds),
                  ("score", r.score_seconds), ("observe", r.observe_seconds),
                  ("delta", r.delta_seconds), ("store", r.store_seconds))
        for stage, seconds in stages:
            self._h_ingest_stage[stage].observe(seconds)
        tr = self.observe.tracer
        if tr is not None:
            total = sum(s for _, s in stages)
            pid = tr.record("ingest", total, handle=r.handle,
                            bytes_in=r.bytes_in, bytes_stored=r.bytes_stored,
                            chunks=r.chunks, dup_chunks=r.dup_chunks,
                            delta_chunks=r.delta_chunks,
                            dcr=round(r.dcr, 4))
            t0 = time.time() - total
            for stage, seconds in stages:
                tr.record("ingest." + stage, seconds, t0=t0, parent=pid)
                t0 += seconds

    # --- serving path (repro.api.restore, DESIGN.md §9) ----------------------

    def restore(self, handle: int) -> bytes:
        """Reconstruct a committed stream byte-for-byte by its handle.
        Raises KeyError once the stream has been deleted (IndexError for
        a handle the store never issued). Safe to call from any number
        of threads at once (DESIGN.md §10.4)."""
        recipe = self.backend.recipe(handle)
        t0 = time.perf_counter()
        data, d = self._fetch_counted(recipe)
        out = b"".join(data[cid] for cid in recipe)
        self._note_restore(handle, len(out), len(recipe),
                           time.perf_counter() - t0, d, surface="full")
        return out

    def restore_iter(self, handle: int, batch_chunks: int = 256):
        """Stream a committed object as chunk-aligned ``bytes`` views.

        Chunks are materialized ``batch_chunks`` recipe slots at a time
        (one planned ``get_many`` per batch), so serving a stream far
        larger than the decode-cache budget never holds more than a
        couple of batches of output in memory. While the caller consumes
        batch *k*, batch *k+1* is already being fetched on the prefetch
        pool (DESIGN.md §10.3), so I/O, decode and consumer work
        overlap. Same errors as ``restore``, raised at call time; the
        ``RestoreReport`` is recorded when the iterator is exhausted."""
        recipe = self.backend.recipe(handle)    # raise before iterating

        def gen():
            t0 = time.perf_counter()
            acc = zero_deltas()
            total = 0
            fut = None
            try:
                for i in range(0, len(recipe), batch_chunks):
                    part = recipe[i:i + batch_chunks]
                    if fut is not None:
                        data, d = fut.result()
                        fut = None
                    else:
                        data, d = self._fetch_counted(part)
                    accumulate(acc, d)
                    nxt = recipe[i + batch_chunks:i + 2 * batch_chunks]
                    if nxt:     # overlap the next fetch with consumption
                        fut = self._prefetch_pool().submit(
                            self._prefetch_fetch, nxt)
                    for cid in part:
                        piece = data[cid]
                        total += len(piece)
                        yield piece
            finally:
                if fut is not None:     # abandoned mid-stream
                    fut.cancel()
            self._note_restore(handle, total, len(recipe),
                               time.perf_counter() - t0, acc,
                               surface="iter")

        return gen()

    def restore_range(self, handle: int, offset: int, length: int) -> bytes:
        """Serve ``stream[offset:offset + length]`` — the partial-read
        serving primitive. Recipe prefix sums (persisted at commit) map
        the byte range onto the minimal chunk window, so only the chunks
        overlapping the range are read and chain-decoded. Ranges are
        clamped to the stream tail; negative offset/length raise
        ValueError; same handle errors as ``restore``."""
        recipe = self.backend.recipe(handle)
        t0 = time.perf_counter()
        acc = zero_deltas()
        first, last, skip = self._layout(handle, recipe, acc).chunk_window(
            offset, length)
        if last < first:
            self._note_restore(handle, 0, 0, time.perf_counter() - t0, acc,
                               surface="range")
            return b""
        part = recipe[first:last + 1]
        data, d = self._fetch_counted(part)
        accumulate(acc, d)
        blob = b"".join(data[cid] for cid in part)
        out = blob[skip:skip + min(length, len(blob) - skip)]
        self._note_restore(handle, len(out), len(part),
                           time.perf_counter() - t0, acc, surface="range")
        return out

    def stream_length(self, handle: int) -> int:
        """Total materialized bytes of a committed stream (no decoding
        when the backend persisted recipe lengths)."""
        return self._layout(handle, self.backend.recipe(handle)).total_bytes

    # --- digest-table persistence seam (DESIGN.md §11.5) ---------------------

    def digest_seeds(self) -> dict[bytes, int]:
        """Snapshot of the exact-dedup digest table (content digest ->
        stored chunk id). The table is in-memory only: a store reopened
        on an existing backend starts with it empty, so re-ingesting
        bytes it already holds stores them again physically. Callers
        that reopen stores across processes (the object-store CLI)
        persist this snapshot and hand it back via ``seed_digests``."""
        with self._stats_lock:
            return dict(self._by_digest)

    def seed_digests(self, mapping: dict[bytes, int]) -> int:
        """Preload the exact-dedup digest table from a ``digest_seeds``
        snapshot taken before the store was closed. Entries whose chunk
        id is no longer stored (deleted + compacted away meanwhile) are
        skipped, so a stale snapshot can never alias fresh content onto
        missing records. Returns how many entries were admitted."""
        admitted = 0
        with self._commit_lock, self._lifecycle_lock.read():
            self._check_open()
            for dig, cid in mapping.items():
                cid = int(cid)
                if self.backend.contains(cid):
                    self._by_digest[bytes(dig)] = cid
                    admitted += 1
        return admitted

    def _fetch_unique(self, cids: Sequence[int]) -> dict[int, bytes]:
        """Materialize each distinct chunk id once: planned ``get_many``
        when the backend implements it, per-chunk ``get`` otherwise."""
        uniq = list(dict.fromkeys(int(c) for c in cids))
        get_many = getattr(self.backend, "get_many", None)
        if get_many is not None:
            return dict(zip(uniq, get_many(uniq)))
        return {cid: self.backend.get(cid) for cid in uniq}

    def _acquire_read_deadline(self, op: str) -> None:
        """Shared lifecycle lock, bounded by the caller's deadline scope
        (§15.3): unbounded callers block exactly as before; a request
        with a budget waits at most what is left of it and fails with
        the deadline error its server maps to the shed taxonomy —
        a wedged compaction then costs one request, not a hung thread."""
        t = remaining_time()
        if t is None:
            self._lifecycle_lock.acquire_read()
            return
        try:
            self._lifecycle_lock.acquire_read(timeout=max(0.0, t))
        except LockTimeout as e:
            raise DeadlineExceededError(f"{op} (lifecycle-lock wait)") from e

    def _fetch_counted(self, cids: Sequence[int]) -> tuple[dict, list]:
        """``_fetch_unique`` under the shared lifecycle lock, returning
        ``(data, io_counter_deltas)``. The snapshot pair runs on the
        same thread as the fetch (see ``FileBackend.io_counters``), so
        the deltas are exact per call even with other restores in
        flight — including when this runs on the prefetch pool."""
        lock = self._lifecycle_lock
        check_deadline("restore")
        snap = self._backend_counters()
        self._acquire_read_deadline("restore")
        try:
            # a resumed restore_iter generator can arrive here after
            # close(): the backend's reader fds are gone, so fail with a
            # clean error instead of whatever the closed backend raises.
            # The flag flips under the write lock, so a reader seeing it
            # False is ordered before the close and fetches safely.
            self._check_open()
            data = self._fetch_unique(cids)
        finally:
            lock.release_read()
        now = self._backend_counters()
        return data, [now[i] - snap[i] for i in range(len(snap))]

    def _prefetch_fetch(self, cids: Sequence[int]) -> tuple[dict, list]:
        """``_fetch_counted`` as a prefetch-pool task: folds this pool
        thread's telemetry record and metric shard when the task is
        done. Pool threads live as long as the store, so without the
        explicit fold (concurrency.IoTelemetry.fold_current) their
        counters would sit outside the dead aggregate until close —
        lifetime totals must be exact under thread reuse, not GC-timed.
        Folding happens after the counter snapshot pair, so the per-call
        deltas the caller consumes are unaffected."""
        try:
            return self._fetch_counted(cids)
        finally:
            fold = self._fold_io
            if fold is not None:
                fold()
            self.observe.metrics.fold_current()

    def _prefetch_pool(self) -> ThreadPoolExecutor:
        pool = self._prefetch
        if pool is None:
            with self._stats_lock:
                # never recreate the pool after close() drained it —
                # the executor would leak (nothing shuts it down again)
                if self._closed:
                    raise RuntimeError("store is closed")
                if self._prefetch is None:
                    self._prefetch = ThreadPoolExecutor(
                        max_workers=4, thread_name_prefix="repro-prefetch")
                pool = self._prefetch
        return pool

    def _layout(self, handle: int, recipe: Sequence[int],
                acc: list | None = None) -> RecipeLayout:
        layout = self._layouts.get(handle)
        if layout is None:
            lengths = None
            recipe_lengths = getattr(self.backend, "recipe_lengths", None)
            if recipe_lengths is not None:
                lengths = recipe_lengths(handle)
            if lengths is None:     # pre-§9 recipe: materialize once
                data, d = self._fetch_counted(recipe)
                if acc is not None:
                    accumulate(acc, d)
                lengths = [len(data[cid]) for cid in recipe]
            layout = RecipeLayout(lengths)
            # cache only while the handle is still live, checked under
            # the shared lifecycle lock: a write-locked delete retires
            # the recipe and pops the layout as one atomic step, so an
            # unguarded insert could land *after* the pop and pin the
            # layout forever (handles are never reused). Two threads may
            # still build the same layout concurrently; both compute
            # identical sums, so last-writer-wins is benign.
            lock = self._lifecycle_lock
            self._acquire_read_deadline("restore")
            try:
                try:
                    self.backend.recipe(handle)
                except (KeyError, IndexError):
                    pass        # deleted meanwhile: serve, don't cache
                else:
                    self._layouts[handle] = layout
            finally:
                lock.release_read()
        return layout

    def _backend_counters(self) -> tuple:
        """This thread's backend I/O counters (concurrency.COUNTER_FIELDS
        order); falls back to the backend-lifetime totals for third-party
        backends without per-thread telemetry (exact under serial use,
        which is all such backends support)."""
        io_counters = self._io_counters
        if io_counters is not None:
            return io_counters()
        b = self.backend
        return (getattr(b, "read_seconds", 0.0),
                getattr(b, "decode_seconds", 0.0),
                getattr(b, "bytes_read", 0),
                getattr(b, "cache_hits", 0),
                getattr(b, "cache_misses", 0),
                getattr(b, "prefetch_bytes", 0),
                getattr(b, "read_requests", 0))

    def _note_restore(self, handle: int, bytes_out: int, chunks: int,
                      seconds: float, d: Sequence,
                      surface: str = "full") -> None:
        report = RestoreReport(
            handle=handle, bytes_out=bytes_out, chunks=chunks,
            seconds=seconds,
            read_seconds=d[0], decode_seconds=d[1], bytes_read=int(d[2]),
            cache_hits=int(d[3]), cache_misses=int(d[4]),
            prefetch_bytes=int(d[5]), requests=int(d[6]))
        with self._stats_lock:
            self.last_restore = report
            self.stats.absorb_restore(report)
        self._c_restore_ops[surface].inc()
        self._c_restore_bytes["out"].inc(report.bytes_out)
        self._c_restore_bytes["read"].inc(report.bytes_read)
        self._h_restore_stage["total"].observe(seconds)
        self._h_restore_stage["read"].observe(report.read_seconds)
        self._h_restore_stage["decode"].observe(report.decode_seconds)
        self._h_restore_requests.observe(report.requests)
        tr = self.observe.tracer
        if tr is not None:
            hits, misses = report.cache_hits, report.cache_misses
            pid = tr.record(
                "restore", seconds, surface=surface, handle=handle,
                bytes_out=report.bytes_out, bytes_read=report.bytes_read,
                requests=report.requests, cache_hits=hits,
                cache_misses=misses,
                hit_ratio=round(hits / max(1, hits + misses), 4))
            t0 = time.time() - seconds
            tr.record("restore.plan", max(
                0.0, seconds - report.read_seconds - report.decode_seconds),
                t0=t0, parent=pid, chunks=chunks)
            tr.record("restore.read", report.read_seconds, t0=t0,
                      parent=pid, bytes_read=report.bytes_read,
                      requests=report.requests)
            tr.record("restore.decode", report.decode_seconds, t0=t0,
                      parent=pid)
            tr.record("restore.prefetch", 0.0, t0=t0, parent=pid,
                      prefetch_bytes=report.prefetch_bytes)

    # --- space reclamation (repro.api.lifecycle, DESIGN.md §7) ---------------

    def _check_open(self) -> None:
        # uniform post-close contract: every surface fails with the same
        # clean error before mutating anything (a delete reaching the
        # closed backend would retire the recipe in memory, then die on
        # the closed journal handle mid-mutation)
        if self._backend_closed:
            raise RuntimeError("store is closed")

    def _acquire_write_deadline(self, op: str) -> None:
        """Exclusive lifecycle lock, bounded by the caller's deadline
        scope — the write-side twin of ``_acquire_read_deadline``. A
        deadline-carrying delete waiting out a storm of restores sheds
        instead of blocking its server slot forever."""
        t = remaining_time()
        if t is None:
            self._lifecycle_lock.acquire_write()
            return
        try:
            self._lifecycle_lock.acquire_write(timeout=max(0.0, t))
        except LockTimeout as e:
            raise DeadlineExceededError(f"{op} (lifecycle-lock wait)") from e

    def delete(self, handle: int) -> int:
        """Retire a committed stream; returns the logical bytes the delete
        made reclaimable. May trigger compaction per the store policy.
        Takes the exclusive lifecycle lock: in-flight restores finish
        first, restores arriving later run against the post-delete state
        (a restore of the deleted handle then raises KeyError)."""
        check_deadline("delete")
        self._acquire_write_deadline("delete")
        try:
            self._check_open()
            return lifecycle.delete_stream(self, handle)
        finally:
            self._lifecycle_lock.release_write()

    def collect(self) -> lifecycle.CollectReport:
        """Mark-sweep accounting pass (mutates no data)."""
        self._acquire_write_deadline("collect")
        try:
            self._check_open()
            return lifecycle.collect(self)
        finally:
            self._lifecycle_lock.release_write()

    def compact(self) -> lifecycle.CompactionRun:
        """Rewrite the container without dead records, rebasing survivors.
        Exclusive: the backend swaps its chunk index and reopens its
        reader-pool fds, so no restore may be mid-plan while it runs."""
        self._acquire_write_deadline("compact")
        try:
            self._check_open()
            return lifecycle.compact(self)
        finally:
            self._lifecycle_lock.release_write()

    def scrub(self, repair: bool = False):
        """Fsck walk (DESIGN.md §13.3): verify every stored record
        against its persisted checksum, check recipe reachability (every
        live recipe's chunks exist, every delta base resolves) and
        refcount consistency, and return a ``ScrubReport`` with the
        per-chunk blast radius. With ``repair=True`` corrupt chunks and
        their transitive dependents are durably quarantined and every
        affected stream retired through the recovery-retire tombstone
        machinery — a follow-up scrub reports clean. Exclusive, like
        delete/compact: nothing reads or commits while the walk runs."""
        from repro.api import integrity
        self._acquire_write_deadline("scrub")
        try:
            self._check_open()
            return integrity.scrub(self, repair=repair)
        finally:
            self._lifecycle_lock.release_write()

    def _refresh_lifecycle_stats(self) -> None:
        # dead_bytes = everything compaction can drop: unreferenced records
        # plus records pinned only as delta bases (rebasing frees them)
        self.stats.live_bytes = self._refs.live_bytes
        self.stats.dead_bytes = self._refs.dead_bytes + self._refs.pinned_bytes

    def close(self) -> None:
        """Idempotent. Restores arriving after close — including a
        partially-consumed ``restore_iter`` generator being resumed —
        raise RuntimeError instead of touching the closed backend."""
        # the flag flips under the same lock that guards prefetch-pool
        # creation, so no pool can be created after it is set; then
        # drain the pool BEFORE taking the exclusive lock — its tasks
        # acquire the shared side, so the reverse order deadlocks.
        # Finally close the backend under exclusion: in-flight restores
        # finish before the reader-pool fds go away (the contract
        # FileBackend documents).
        with self._stats_lock:
            if self._closed:
                return
            self._closed = True
        if self._prefetch is not None:
            self._prefetch.shutdown(wait=True)
            self._prefetch = None
        with self._lifecycle_lock.write():
            self._backend_closed = True
            self.backend.close()
        self.observe.close()    # flush + close the JSONL trace sink
