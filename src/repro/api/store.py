"""Session-oriented dedup + delta-compression store (DESIGN.md §2.2).

The store composes the three pluggable seams — a (possibly staged)
detector, a chunker config, and a ``ContainerBackend`` — and owns the
policy between them: exact dedup by content digest, the delta-vs-raw
decision, and accounting.

Ingestion is transactional per stream:

    session = store.open_stream()
    session.write(part1); session.write(part2)   # stage bytes
    report = session.commit()                    # chunk/detect/store
    store.restore(report.handle)                 # byte-identical

``commit()`` returns an immutable per-stream ``IngestReport`` (handle,
per-stream DCR, chunk/dup/delta counts, detect time); the store-lifetime
``StoreStats`` aggregate is the running sum of all reports plus fit time.
Until ``commit()``, nothing — not even detector index admission — has
happened, so an abandoned session leaves no trace. With a staged
detector, admission runs only after every backend write succeeded, so a
commit that fails mid-storage admits nothing to the index either (chunk
records already appended by the failed commit remain as unreferenced
garbage; digests stored before the failure may still dedup against them,
which is safe — the payloads exist).

The v0 surface (``ingest``, integer stream indexes for ``restore``)
remains as thin wrappers: handles are assigned densely in commit order, so
v0 callers keep working unchanged.

Space reclamation (DESIGN.md §7) is delegated to ``repro.api.lifecycle``:
``delete(handle)`` retires a stream and decrefs its chunks (chunks another
stream's patch depends on stay pinned), ``collect()`` is the mark-sweep
accounting pass, ``compact()`` rewrites the container without dead
records, rebasing surviving patches whose base was evicted. The
``RefcountTable`` is rebuilt from the backend on open, so a store reopened
on an existing directory can delete/compact streams it did not ingest.
"""
from __future__ import annotations

import time
from typing import Any, Sequence

import numpy as np

from repro.api import containers, lifecycle
from repro.api.detect import is_staged
from repro.api.refcount import RefcountTable
from repro.api.types import DetectBatch, IngestReport, StoreStats
from repro.core import chunking, delta, hashing


def chunk_with(chunker: Any, stream: bytes):
    """Dispatch chunking through a registered chunker.

    Custom chunkers implement ``chunk(stream) -> (chunks, stream_hashes)``
    where chunks are ``repro.core.chunking.Chunk`` and stream_hashes are
    the per-position window hashes detectors reuse (may be the gear scan
    or the chunker's own). Anything without a ``chunk`` method is treated
    as a FastCDC ``ChunkerConfig`` (the "fastcdc" builtin) and goes
    through the parallel gear-hash scan.
    """
    if hasattr(chunker, "chunk"):
        return chunker.chunk(stream)
    buf = np.frombuffer(stream, dtype=np.uint8)
    stream_hashes = hashing.gear_hashes_np(buf)
    return chunking.chunk_stream(stream, chunker, hashes=stream_hashes), stream_hashes


class StreamSession:
    """Write-then-commit handle for ingesting one stream. After a
    successful ``commit()`` (including via the context manager) the
    IngestReport is also available as ``session.report``."""

    def __init__(self, store: "DedupStore") -> None:
        self._store = store
        self._parts: list[bytes] = []
        self._closed = False
        self.report: IngestReport | None = None

    def write(self, data: bytes) -> None:
        if self._closed:
            raise RuntimeError("stream session already committed/aborted")
        self._parts.append(bytes(data))

    def commit(self) -> IngestReport:
        if self._closed:
            raise RuntimeError("stream session already committed/aborted")
        self._closed = True
        self.report = self._store._commit_stream(b"".join(self._parts))
        return self.report

    def abort(self) -> None:
        self._closed = True
        self._parts.clear()

    def __enter__(self) -> "StreamSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._closed:
            if exc_type is None:
                self.commit()
            else:
                self.abort()


class DedupStore:
    """Container store with exact dedup + detector-driven delta compression."""

    def __init__(self, detector: Any,
                 chunker_cfg: chunking.ChunkerConfig | None = None,
                 backend: containers.ContainerBackend | None = None,
                 policy: Any | None = None):
        self.detector = detector
        self.cfg = chunker_cfg or chunking.ChunkerConfig()
        self.backend = backend if backend is not None else containers.InMemoryBackend()
        self.policy = policy if policy is not None else lifecycle.NeverPolicy()
        self.stats = StoreStats()
        self.reports: list[IngestReport] = []
        self._by_digest: dict[bytes, int] = {}
        # a reopened (file-backed) backend already holds chunk ids; start
        # past them so new chunks never shadow persisted records
        self._next_id = self.backend.max_chunk_id() + 1
        self._refs = RefcountTable.rebuild(self.backend)
        self._refresh_lifecycle_stats()

    def fit(self, training_streams: Sequence[bytes]) -> None:
        t0 = time.perf_counter()
        self.detector.fit(training_streams, self.cfg)
        self.stats.fit_seconds += time.perf_counter() - t0

    def open_stream(self) -> StreamSession:
        return StreamSession(self)

    def ingest(self, stream: bytes) -> StoreStats:
        """v0 surface: one-shot session commit; returns the aggregate."""
        session = self.open_stream()
        session.write(stream)
        session.commit()
        return self.stats

    def _commit_stream(self, stream: bytes) -> IngestReport:
        # pass 0: chunk
        t0 = time.perf_counter()
        chunks, stream_hashes = chunk_with(self.cfg, stream)
        chunk_seconds = time.perf_counter() - t0

        # pass 1: exact dedup; assign ids
        n = len(chunks)
        ids = np.empty(n, np.int64)
        is_new = np.zeros(n, bool)
        digests = [ck.digest for ck in chunks]
        seen_in_stream: dict[bytes, int] = {}
        for i, dig in enumerate(digests):
            ref = self._by_digest.get(dig)
            if ref is None:
                ref = seen_in_stream.get(dig)
            if ref is not None:
                ids[i] = ref
            else:
                ids[i] = self._next_id
                self._next_id += 1
                is_new[i] = True
                seen_in_stream[dig] = int(ids[i])

        # pass 2: resemblance detection (batched, staged). For staged
        # detectors, index admission (`observe`) is deferred until the
        # backend writes succeed, so a commit that fails mid-storage
        # admits nothing to the detector index. Legacy single-call
        # detectors mutate inside detect() and can't make that promise.
        t0 = time.perf_counter()
        batch = DetectBatch(chunks=chunks, ids=ids, is_new=is_new,
                            stream_hashes=stream_hashes)
        staged = is_staged(self.detector)
        if staged:
            feats = self.detector.extract(batch)
            base_ids = self.detector.score(feats, batch).base_ids
        else:
            base_ids = np.asarray(
                self.detector.detect(chunks, ids, is_new, stream_hashes),
                np.int64)
        detect_seconds = time.perf_counter() - t0

        # pass 3: store through the container backend
        backend = self.backend
        bytes_in = bytes_stored = 0
        dup_chunks = delta_chunks = raw_chunks = 0
        delta_seconds = 0.0
        recipe: list[int] = []
        for i, ck in enumerate(chunks):
            bytes_in += ck.length
            cid = int(ids[i])
            recipe.append(cid)
            if not is_new[i]:
                dup_chunks += 1
                continue
            stored = None
            base = int(base_ids[i])
            if base >= 0 and backend.contains(base):
                t0 = time.perf_counter()
                d = delta.encode(ck.data, backend.get(base))
                delta_seconds += time.perf_counter() - t0
                if len(d) < ck.length:
                    stored = len(d) + 8  # + recipe metadata
                    backend.put_delta(cid, base, d, data=ck.data)
                    self._refs.track(cid, base, len(d))
                    delta_chunks += 1
            if stored is None:
                stored = ck.length
                backend.put_raw(cid, ck.data)
                self._refs.track(cid, -1, ck.length)
                raw_chunks += 1
            self._by_digest[digests[i]] = cid
            bytes_stored += stored
        handle = backend.add_recipe(recipe)
        for cid in recipe:      # only now do the chunks become live
            self._refs.incref_recipe(cid)
        backend.flush()

        if staged:
            t0 = time.perf_counter()
            self.detector.observe(feats, batch)
            detect_seconds += time.perf_counter() - t0

        report = IngestReport(
            handle=handle, bytes_in=bytes_in, bytes_stored=bytes_stored,
            chunks=n, dup_chunks=dup_chunks, delta_chunks=delta_chunks,
            raw_chunks=raw_chunks, detect_seconds=detect_seconds,
            chunk_seconds=chunk_seconds, delta_seconds=delta_seconds)
        self.reports.append(report)
        self.stats.absorb(report)
        self._refresh_lifecycle_stats()
        return report

    def restore(self, handle: int) -> bytes:
        """Reconstruct a committed stream byte-for-byte by its handle.
        Raises KeyError once the stream has been deleted."""
        out = bytearray()
        for cid in self.backend.recipe(handle):
            out.extend(self.backend.get(cid))
        return bytes(out)

    # --- space reclamation (repro.api.lifecycle, DESIGN.md §7) ---------------

    def delete(self, handle: int) -> int:
        """Retire a committed stream; returns the logical bytes the delete
        made reclaimable. May trigger compaction per the store policy."""
        return lifecycle.delete_stream(self, handle)

    def collect(self) -> lifecycle.CollectReport:
        """Mark-sweep accounting pass (mutates no data)."""
        return lifecycle.collect(self)

    def compact(self) -> lifecycle.CompactionRun:
        """Rewrite the container without dead records, rebasing survivors."""
        return lifecycle.compact(self)

    def _refresh_lifecycle_stats(self) -> None:
        # dead_bytes = everything compaction can drop: unreferenced records
        # plus records pinned only as delta bases (rebasing frees them)
        self.stats.live_bytes = self._refs.live_bytes
        self.stats.dead_bytes = self._refs.dead_bytes + self._refs.pinned_bytes

    def close(self) -> None:
        self.backend.close()
