"""Chunk refcounting for space reclamation (DESIGN.md §7.1).

A deleted stream cannot simply drop its chunks: delta base-chains
(DESIGN.md §2.3) mean a chunk with no recipe reference may still be the
base some live patch decodes against. The table therefore tracks *two*
reference kinds per chunk and classifies every chunk into one of three
categories:

    recipe refs   occurrences of the chunk in live (non-retired) stream
                  recipes — one ref per recipe slot, so a chunk repeated
                  inside a stream is decref'd symmetrically on delete;
    base deps     number of *retained* chunks whose stored patch decodes
                  against this chunk.

    live     recipe refs > 0            (some live stream needs it)
    pinned   recipe refs == 0, deps > 0 (held only as a delta base)
    dead     both zero                  (reclaimable garbage)

"Retained" = live or pinned. Retained-ness cascades along the base
chain: when the last dependent of a chunk goes away the chunk may become
dead, which releases *its* base in turn (and symmetrically on revival —
a new stream deduping against a dead-but-unswept chunk brings its whole
chain back). `live_bytes` / `pinned_bytes` / `dead_bytes` are maintained
incrementally, so the policy check after every delete is O(chain), not
O(chunks).

The table is an in-memory view; the durable truth is the container
backend (records + recipes), from which `RefcountTable.rebuild` derives
an identical table on store reopen and after compaction.
"""
from __future__ import annotations

from typing import Any


class RefcountTable:
    """Per-chunk recipe/base refcounts with incremental byte accounting."""

    def __init__(self) -> None:
        self._recipe: dict[int, int] = {}    # cid -> live recipe slots
        self._deps: dict[int, int] = {}      # cid -> retained dependents
        self._base_of: dict[int, int] = {}   # cid -> base cid (-1 = raw)
        self._size: dict[int, int] = {}      # cid -> stored payload bytes
        self.live_bytes = 0
        self.pinned_bytes = 0
        self.dead_bytes = 0

    # --- registration --------------------------------------------------------

    def track(self, cid: int, base: int, size: int) -> None:
        """Register a stored chunk (starts dead until a recipe refs it)."""
        if cid in self._size:
            raise ValueError(f"chunk {cid} already tracked")
        self._recipe[cid] = 0
        self._deps[cid] = 0
        self._base_of[cid] = int(base)
        self._size[cid] = int(size)
        self.dead_bytes += size

    @classmethod
    def rebuild(cls, backend: Any) -> "RefcountTable":
        """Derive the table from a backend's records + live recipes (store
        reopen, and the post-compaction reset)."""
        table = cls()
        for cid in backend.chunk_ids():
            table.track(cid, backend.base_of(cid), backend.payload_size(cid))
        for handle in backend.live_handles():
            for cid in backend.recipe(handle):
                table.incref_recipe(cid)
        return table

    # --- refcount transitions ------------------------------------------------

    def incref_recipe(self, cid: int) -> None:
        self._shift(cid, +1)

    def decref_recipe(self, cid: int) -> None:
        self._shift(cid, -1)

    def _shift(self, cid: int, d_recipe: int) -> None:
        """Apply a recipe-ref delta, cascading retained-ness flips down the
        base chain (iterative — chains can be arbitrarily deep)."""
        d_deps = 0
        while True:
            r0, d0 = self._recipe[cid], self._deps[cid]
            r1, d1 = r0 + d_recipe, d0 + d_deps
            if r1 < 0 or d1 < 0:
                raise ValueError(f"refcount underflow on chunk {cid}")
            self._recipe[cid], self._deps[cid] = r1, d1
            size = self._size[cid]
            self._account(r0, d0, -size)
            self._account(r1, d1, +size)
            base = self._base_of[cid]
            flipped = (r0 + d0 > 0) != (r1 + d1 > 0)
            if not flipped or base < 0:
                return
            cid, d_recipe, d_deps = base, 0, (1 if r1 + d1 > 0 else -1)

    def _account(self, recipe: int, deps: int, delta: int) -> None:
        if recipe > 0:
            self.live_bytes += delta
        elif deps > 0:
            self.pinned_bytes += delta
        else:
            self.dead_bytes += delta

    # --- queries -------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._size)

    def __contains__(self, cid: int) -> bool:
        return cid in self._size

    def base_of(self, cid: int) -> int:
        return self._base_of[cid]

    def size_of(self, cid: int) -> int:
        return self._size[cid]

    def recipe_refs(self, cid: int) -> int:
        return self._recipe.get(cid, 0)

    def base_deps(self, cid: int) -> int:
        return self._deps.get(cid, 0)

    def is_live(self, cid: int) -> bool:
        return self._recipe.get(cid, 0) > 0

    def is_pinned(self, cid: int) -> bool:
        return self._recipe.get(cid, 0) == 0 and self._deps.get(cid, 0) > 0

    def is_retained(self, cid: int) -> bool:
        return self._recipe.get(cid, 0) + self._deps.get(cid, 0) > 0

    def chunk_ids(self) -> list[int]:
        return list(self._size)

    def live_cids(self) -> list[int]:
        return [c for c in self._size if self.is_live(c)]

    def pinned_cids(self) -> list[int]:
        return [c for c in self._size if self.is_pinned(c)]

    def dead_cids(self) -> list[int]:
        return [c for c in self._size if not self.is_retained(c)]

    def chain_depth_hist(self) -> dict[int, int]:
        """Histogram {depth: count} over *live* chunks; raw chunks are depth
        0, each patch hop adds 1. The compaction rebase exists to keep this
        from growing unboundedly as old generations are deleted."""
        memo: dict[int, int] = {}
        hist: dict[int, int] = {}
        for cid in self._size:
            if not self.is_live(cid):
                continue
            path: list[int] = []
            cur = cid
            while cur >= 0 and cur not in memo:
                path.append(cur)
                cur = self._base_of[cur]
            depth = -1 if cur < 0 else memo[cur]
            for c in reversed(path):
                depth += 1
                memo[c] = depth
            hist[memo[cid]] = hist.get(memo[cid], 0) + 1
        return hist
