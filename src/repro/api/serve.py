"""Multi-tenant serving front end over one long-lived DedupStore
(DESIGN.md §15).

The paper's setting is a cloud provider deduplicating across many
users; §10 made one store safe under concurrent threads, but nothing
stopped one caller from monopolizing it. ``DedupServer`` is that
missing service layer:

    per-tenant namespaces   stream handles are owned by the tenant that
                            committed them; a restore/delete of a
                            foreign handle fails with KeyError exactly
                            like a handle that never existed
    quotas                  stored bytes (admission-checked against the
                            upper bound, settled to the deduped actual
                            after commit), concurrent in-flight
                            requests, and an optional per-tenant
                            ``DecodeCache`` budget (§14.1 policy
                            machinery, keyed by stream handle) in front
                            of the shared store
    admission control       a bounded per-tenant queue; a request that
                            cannot be queued is shed *synchronously*
                            with ``OverloadError`` — typed rejection,
                            never queue-to-collapse
    request deadlines       every request runs inside a
                            ``deadline_scope`` (§15.3); lock waits,
                            restore runs, and commit passes shed with
                            ``DeadlineExceededError`` instead of
                            blocking past the budget
    graceful degradation    a ``CircuitBreaker`` over backend
                            transient-fault rates flips tenants to
                            read-only serving (restores still run —
                            cache/tier hits keep working through an
                            outage) and re-closes via half-open probes

Error taxonomy (§15.2) — every shed is typed, synchronous at the edge
it happens, and leaves the store untouched:

    OverloadError           tenant queue full (raised by ``submit``)
    QuotaExceededError      stored-bytes quota would be exceeded
    CircuitOpenError        breaker not closed; write rejected
    DeadlineExceededError   end-to-end budget ran out (re-exported from
                            ``concurrency``; also covers LockTimeout)

Observability: ``repro_server_*`` / ``repro_tenant_*`` families through
the store's §12 registry — request outcomes, breaker state and
transitions, per-tenant bytes/inflight/queue-depth/shed counters.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable

from repro.api.concurrency import (DeadlineExceededError, LockTimeout,
                                   check_deadline, deadline_scope,
                                   remaining_time)
from repro.api.faults import TransientError
from repro.api.restore import DecodeCache


class RequestRejected(Exception):
    """Base of the shed taxonomy (§15.2): raised instead of queueing
    when admitting (or continuing) the request could not meet its SLO.
    The request did no store work; the client may back off and retry."""


class OverloadError(RequestRejected):
    """The tenant's admission queue is full. Raised synchronously by
    ``submit`` — overload is the caller's backpressure signal, so it
    must never itself queue."""

    def __init__(self, tenant: str, pending: int, limit: int) -> None:
        super().__init__(
            f"tenant {tenant!r} overloaded: {pending} requests pending "
            f"(limit {limit})")
        self.tenant = tenant
        self.pending = pending
        self.limit = limit


class QuotaExceededError(RequestRejected):
    """Admitting this ingest could exceed the tenant's stored-bytes
    quota. Checked against the *upper bound* (raw length plus bytes
    already reserved by in-flight ingests) — dedup may store far less,
    but a quota must hold under concurrency, not just after the fact."""

    def __init__(self, tenant: str, used: int, wanted: int,
                 quota: int) -> None:
        super().__init__(
            f"tenant {tenant!r} quota exceeded: {used} bytes charged + "
            f"{wanted} requested > quota {quota}")
        self.tenant = tenant
        self.used = used
        self.wanted = wanted
        self.quota = quota


class CircuitOpenError(RequestRejected):
    """The backend circuit breaker is not closed: mutations are
    rejected so a struggling backend sees only read traffic (which the
    cache/tier can often serve) plus the half-open probes."""

    def __init__(self, state: str) -> None:
        super().__init__(
            f"backend circuit breaker is {state}: store is read-only "
            f"until half-open probes succeed")
        self.state = state


DEFAULT_MAX_INFLIGHT = 8
DEFAULT_MAX_QUEUE = 32


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """Per-tenant limits. ``quota_bytes`` bounds *charged* stored bytes
    (None = unlimited); ``max_inflight`` requests run concurrently and
    up to ``max_queue`` more wait; past that ``submit`` sheds.
    ``cache_bytes`` > 0 gives the tenant a private whole-stream
    ``DecodeCache`` (§14.1 policy machinery — ``cache_policy`` names a
    registered eviction policy) in front of the shared store, so one
    tenant's scan traffic cannot churn another's working set.
    ``default_timeout`` applies to requests submitted without one."""

    quota_bytes: int | None = None
    max_inflight: int = DEFAULT_MAX_INFLIGHT
    max_queue: int = DEFAULT_MAX_QUEUE
    cache_bytes: int = 0
    cache_policy: str = "arc"
    default_timeout: float | None = None


class CircuitBreaker:
    """Three-state breaker (§15.4) over backend transient-fault rates.

    closed — normal service; ``fail_threshold`` failures within a
    sliding ``window_seconds`` trip it open. open — writes shed
    instantly; after ``cooldown_seconds`` the next state probe moves it
    to half_open (lazily: no timer thread). half_open — reads flow as
    probes; ``probe_successes`` consecutive successes re-close it, any
    failure re-opens (and restarts the cooldown).

    ``record_failure``/``record_success`` are fed by the server with
    backend outcomes only (a quota rejection is not a backend fault).
    ``on_transition(to_state)`` is the metrics hook. ``clock`` is
    injectable for deterministic tests."""

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"
    STATE_CODE = {CLOSED: 0, HALF_OPEN: 1, OPEN: 2}

    def __init__(self, fail_threshold: int = 5, window_seconds: float = 10.0,
                 cooldown_seconds: float = 5.0, probe_successes: int = 2,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Callable[[str], None] | None = None) -> None:
        self.fail_threshold = max(1, int(fail_threshold))
        self.window_seconds = float(window_seconds)
        self.cooldown_seconds = float(cooldown_seconds)
        self.probe_successes = max(1, int(probe_successes))
        self.on_transition = on_transition
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._failures: list[float] = []    # timestamps inside the window
        self._opened_at = 0.0
        self._probes_ok = 0
        #: lifetime transition tally by target state — the §15.4
        #: "demonstrably opens and recovers" evidence
        self.transitions: dict[str, int] = {self.CLOSED: 0,
                                            self.HALF_OPEN: 0, self.OPEN: 0}

    def _set(self, state: str) -> None:
        # lock held. on_transition must be leaf-shaped (metrics inc).
        if state == self._state:
            return
        self._state = state
        self.transitions[state] += 1
        if self.on_transition is not None:
            self.on_transition(state)

    def _state_locked(self) -> str:
        if (self._state == self.OPEN
                and self._clock() - self._opened_at >= self.cooldown_seconds):
            self._probes_ok = 0
            self._set(self.HALF_OPEN)
        return self._state

    def state(self) -> str:
        with self._lock:
            return self._state_locked()

    def allow_write(self) -> bool:
        """Mutations only in the closed state: half-open probes are
        reads — a write probe against a flaky backend could half-commit."""
        return self.state() == self.CLOSED

    def record_failure(self) -> None:
        with self._lock:
            now = self._clock()
            st = self._state_locked()
            if st == self.HALF_OPEN:
                self._opened_at = now       # failed probe: back to open,
                self._failures.clear()      # cooldown restarts
                self._set(self.OPEN)
                return
            if st == self.OPEN:
                return
            self._failures.append(now)
            cutoff = now - self.window_seconds
            self._failures = [t for t in self._failures if t >= cutoff]
            if len(self._failures) >= self.fail_threshold:
                self._opened_at = now
                self._set(self.OPEN)

    def record_success(self) -> None:
        with self._lock:
            if self._state_locked() == self.HALF_OPEN:
                self._probes_ok += 1
                if self._probes_ok >= self.probe_successes:
                    self._failures.clear()
                    self._set(self.CLOSED)


class _Tenant:
    """One tenant's namespace + accounting. ``bytes_stored`` is the live
    charge (sum of each live handle's commit-time ``bytes_stored``);
    ``bytes_ingested`` the lifetime charge (never decremented — the
    per-tenant share of ``StoreStats.bytes_stored``, which is also
    lifetime). ``reserved`` holds the raw upper bound of in-flight
    ingests so the quota check is exact under concurrency."""

    def __init__(self, name: str, cfg: TenantConfig) -> None:
        self.name = name
        self.cfg = cfg
        self.lock = threading.Lock()
        self.slots = threading.BoundedSemaphore(cfg.max_inflight)
        self.handle_cost: dict[int, int] = {}
        self.bytes_stored = 0
        self.bytes_ingested = 0
        self.reserved = 0
        self.pending = 0        # admitted, not yet finished
        self.inflight = 0       # holding an execution slot right now
        self.requests = 0
        self.shed: dict[str, int] = {}
        self.cache = (DecodeCache(cfg.cache_bytes, policy=cfg.cache_policy)
                      if cfg.cache_bytes > 0 else None)

    def shed_one(self, reason: str) -> None:
        with self.lock:
            self.shed[reason] = self.shed.get(reason, 0) + 1


class DedupServer:
    """Thread-pool request router over one ``DedupStore`` (§15.1).

    ``submit(tenant, op, *args, timeout=...)`` admission-checks and
    returns a Future; ``ingest``/``restore``/``restore_range``/
    ``delete`` are the blocking wrappers. The executor is shared across
    tenants (work-conserving); fairness comes from the per-tenant
    inflight semaphore — a tenant can queue work but never hold more
    than ``max_inflight`` executor threads, so no tenant starves the
    pool. Tenants are auto-created on first use with ``default_tenant``
    limits; ``add_tenant`` registers explicit ones."""

    _OPS = frozenset({"ingest", "restore", "restore_range", "delete"})

    def __init__(self, store, *, workers: int = 8,
                 breaker: CircuitBreaker | None = None,
                 default_tenant: TenantConfig | None = None) -> None:
        self.store = store
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self._default_cfg = (default_tenant if default_tenant is not None
                             else TenantConfig())
        self._tenants: dict[str, _Tenant] = {}
        self._lock = threading.Lock()
        self._closed = False
        self._pool = ThreadPoolExecutor(max_workers=max(1, int(workers)),
                                        thread_name_prefix="repro-serve")
        self._init_observability()

    # --- tenants -------------------------------------------------------------

    def add_tenant(self, name: str,
                   cfg: TenantConfig | None = None, **limits) -> TenantConfig:
        """Register a tenant with explicit limits (either a
        ``TenantConfig`` or its fields as keywords). Must happen before
        the tenant's first request; re-registering raises."""
        if cfg is None:
            cfg = TenantConfig(**limits)
        elif limits:
            raise TypeError("pass a TenantConfig or keyword limits, not both")
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already exists")
            self._tenants[name] = _Tenant(name, cfg)
        return cfg

    def _tenant(self, name: str) -> _Tenant:
        with self._lock:
            t = self._tenants.get(name)
            if t is None:
                t = _Tenant(name, self._default_cfg)
                self._tenants[name] = t
            return t

    def tenants(self) -> list[str]:
        with self._lock:
            return sorted(self._tenants)

    def tenant_stats(self, name: str) -> dict:
        """Point-in-time accounting snapshot for one tenant."""
        t = self._tenant(name)
        with t.lock:
            out = {
                "tenant": t.name,
                "bytes_stored": t.bytes_stored,
                "bytes_ingested": t.bytes_ingested,
                "reserved": t.reserved,
                "quota_bytes": t.cfg.quota_bytes,
                "streams": len(t.handle_cost),
                "pending": t.pending,
                "inflight": t.inflight,
                "requests": t.requests,
                "shed": dict(t.shed),
            }
        cache = t.cache
        if cache is not None:
            out["cache_hits"] = cache.hits
            out["cache_misses"] = cache.misses
        return out

    # --- metrics -------------------------------------------------------------

    def _init_observability(self) -> None:
        m = self.store.observe.metrics
        self._m = m
        self._c_transitions = {
            s: m.counter("repro_server_breaker_transitions_total",
                         "Breaker transitions by target state (§15.4)",
                         labels={"to": s})
            for s in (CircuitBreaker.CLOSED, CircuitBreaker.HALF_OPEN,
                      CircuitBreaker.OPEN)}
        self._g_state = m.gauge(
            "repro_server_breaker_state",
            "Breaker state: 0 closed / 1 half-open / 2 open")
        self._g_inflight = m.gauge(
            "repro_server_inflight",
            "Requests holding an execution slot, all tenants")
        # chain, don't clobber: a caller may have installed its own hook
        prev = self.breaker.on_transition

        def note(state: str) -> None:
            self._c_transitions[state].inc()
            if prev is not None:
                prev(state)

        self.breaker.on_transition = note
        m.register_callback(self._export_views)

    def _count(self, op: str, outcome: str) -> None:
        self._m.counter("repro_server_requests_total",
                        "Requests by op and outcome (§15.2 taxonomy)",
                        labels={"op": op, "outcome": outcome}).inc()

    def _export_views(self) -> None:
        # derived views (§12): tenant accounting is authoritative in
        # _Tenant; copied into gauges/set_total counters at snapshot time
        self._g_state.set(CircuitBreaker.STATE_CODE[self.breaker.state()])
        with self._lock:
            tenants = list(self._tenants.values())
        m = self._m
        inflight_total = 0
        for t in tenants:
            lb = {"tenant": t.name}
            with t.lock:
                stored, inflight = t.bytes_stored, t.inflight
                queued = max(0, t.pending - t.inflight)
                requests = t.requests
                shed = dict(t.shed)
            inflight_total += inflight
            m.gauge("repro_tenant_bytes_stored",
                    "Live stored-bytes charge per tenant (§15.1)",
                    labels=lb).set(stored)
            m.gauge("repro_tenant_inflight",
                    "Requests holding an execution slot", labels=lb
                    ).set(inflight)
            m.gauge("repro_tenant_queue_depth",
                    "Admitted requests waiting for a slot", labels=lb
                    ).set(queued)
            m.counter("repro_tenant_requests_total",
                      "Lifetime requests submitted", labels=lb
                      ).set_total(requests)
            for reason, n in shed.items():
                m.counter("repro_tenant_shed_total",
                          "Requests shed by typed reason (§15.2)",
                          labels={"tenant": t.name, "reason": reason}
                          ).set_total(n)
            cache = t.cache
            if cache is not None:
                for outcome, n in (("hit", cache.hits),
                                   ("miss", cache.misses)):
                    m.counter("repro_tenant_cache_lookups_total",
                              "Per-tenant stream-cache lookups (§15.1)",
                              labels={"tenant": t.name, "outcome": outcome}
                              ).set_total(n)
        self._g_inflight.set(inflight_total)

    # --- request routing -----------------------------------------------------

    def submit(self, tenant: str, op: str, *args,
               timeout: float | None = None) -> Future:
        """Admission-check and enqueue one request; returns its Future.
        Sheds synchronously with ``OverloadError`` when the tenant's
        queue (``max_inflight + max_queue``) is full — backpressure must
        reach the caller now, not after a queue delay."""
        if op not in self._OPS:
            raise ValueError(f"unknown op {op!r} (have {sorted(self._OPS)})")
        if self._closed:
            raise RuntimeError("server is closed")
        t = self._tenant(tenant)
        if timeout is None:
            timeout = t.cfg.default_timeout
        limit = t.cfg.max_inflight + t.cfg.max_queue
        with t.lock:
            t.requests += 1
            if t.pending >= limit:
                t.shed["overload"] = t.shed.get("overload", 0) + 1
                self._count(op, "overload")
                raise OverloadError(tenant, t.pending, limit)
            t.pending += 1
        try:
            return self._pool.submit(self._run, t, op, args, timeout,
                                     time.monotonic())
        except BaseException:
            with t.lock:        # executor refused (shutdown race)
                t.pending -= 1
            raise

    # blocking wrappers — the client surface most callers want

    def ingest(self, tenant: str, data: bytes,
               timeout: float | None = None):
        """Commit one stream under the tenant's namespace; returns its
        ``IngestReport``."""
        return self.submit(tenant, "ingest", data, timeout=timeout).result()

    def restore(self, tenant: str, handle: int,
                timeout: float | None = None) -> bytes:
        return self.submit(tenant, "restore", handle,
                           timeout=timeout).result()

    def restore_range(self, tenant: str, handle: int, offset: int,
                      length: int, timeout: float | None = None) -> bytes:
        return self.submit(tenant, "restore_range", handle, offset, length,
                           timeout=timeout).result()

    def delete(self, tenant: str, handle: int,
               timeout: float | None = None) -> int:
        return self.submit(tenant, "delete", handle,
                           timeout=timeout).result()

    # --- worker body ---------------------------------------------------------

    def _run(self, t: _Tenant, op: str, args: tuple,
             timeout: float | None, t_submit: float) -> Any:
        # the deadline is end-to-end from submit(): time spent queued in
        # the executor before a worker picked this up already counts
        budget = timeout
        if timeout is not None:
            budget = max(0.0, timeout - (time.monotonic() - t_submit))
        try:
            with deadline_scope(budget):
                # the inflight slot wait counts against the deadline: a
                # request that spent its whole budget queued must shed,
                # not start a restore it can no longer finish in time
                wait = remaining_time()
                ok = (t.slots.acquire() if wait is None
                      else t.slots.acquire(timeout=max(0.0, wait)))
                if not ok:
                    raise DeadlineExceededError(f"{op} (tenant slot wait)",
                                                timeout)
                with t.lock:
                    t.inflight += 1
                try:
                    result = self._dispatch(t, op, args)
                finally:
                    with t.lock:
                        t.inflight -= 1
                    t.slots.release()
                    # pooled worker: fold per-thread I/O + metric shards
                    # so lifetime totals stay exact under thread reuse
                    self.store.observe.metrics.fold_current()
            self._count(op, "ok")
            return result
        except BaseException as e:
            self._note_failure(t, op, e)
            raise
        finally:
            with t.lock:
                t.pending -= 1

    def _note_failure(self, t: _Tenant, op: str, e: BaseException) -> None:
        if isinstance(e, QuotaExceededError):
            reason = "quota"
        elif isinstance(e, CircuitOpenError):
            reason = "circuit"
        elif isinstance(e, (DeadlineExceededError, LockTimeout)):
            reason = "deadline"
        elif isinstance(e, TransientError):
            # RetryBudgetExceeded included: the backend's own retry
            # policy already gave up, which is exactly the breaker signal
            self.breaker.record_failure()
            self._count(op, "backend_error")
            return
        else:
            self._count(op, "error")
            return
        t.shed_one(reason)
        self._count(op, reason)

    def _dispatch(self, t: _Tenant, op: str, args: tuple) -> Any:
        check_deadline(op)
        if op == "ingest":
            (data,) = args
            return self._ingest(t, data)
        if op == "restore":
            (handle,) = args
            return self._restore(t, int(handle))
        if op == "restore_range":
            handle, offset, length = args
            return self._restore_range(t, int(handle), int(offset),
                                       int(length))
        (handle,) = args
        return self._delete(t, int(handle))

    def _check_owned(self, t: _Tenant, handle: int) -> None:
        # namespace isolation: a foreign (or never-issued) handle is
        # indistinguishable from a missing one
        with t.lock:
            if handle not in t.handle_cost:
                raise KeyError(
                    f"tenant {t.name!r} has no stream {handle}")

    def _ingest(self, t: _Tenant, data: bytes):
        if not self.breaker.allow_write():
            raise CircuitOpenError(self.breaker.state())
        upper = len(data)
        quota = t.cfg.quota_bytes
        with t.lock:
            if (quota is not None
                    and t.bytes_stored + t.reserved + upper > quota):
                raise QuotaExceededError(t.name, t.bytes_stored + t.reserved,
                                         upper, quota)
            t.reserved += upper
        try:
            session = self.store.open_stream()
            session.write(data)
            report = session.commit()
        except BaseException:
            with t.lock:
                t.reserved -= upper
            raise
        with t.lock:
            t.reserved -= upper
            t.handle_cost[report.handle] = report.bytes_stored
            t.bytes_stored += report.bytes_stored
            t.bytes_ingested += report.bytes_stored
        self.breaker.record_success()
        return report

    def _probing(self) -> bool:
        """Half-open breaker: reads must bypass the tenant cache so they
        reach the backend and act as live probes — a cache hit proves
        nothing about backend health and would leave the breaker stuck
        half-open forever (§15.4)."""
        return self.breaker.state() == CircuitBreaker.HALF_OPEN

    def _restore(self, t: _Tenant, handle: int) -> bytes:
        self._check_owned(t, handle)
        cache = t.cache
        if cache is not None and not self._probing():
            data = cache.get(handle)
            if data is not None:
                return data     # tenant-cache hit: no store, no breaker
        data = self.store.restore(handle)
        self.breaker.record_success()
        if cache is not None and len(data) <= cache.budget_bytes:
            cache.put(handle, data)
        return data

    def _restore_range(self, t: _Tenant, handle: int, offset: int,
                       length: int) -> bytes:
        if offset < 0 or length < 0:
            raise ValueError("offset/length must be non-negative")
        self._check_owned(t, handle)
        cache = t.cache
        if cache is not None and not self._probing():
            data = cache.get(handle)
            if data is not None:
                return data[offset:offset + length]
        out = self.store.restore_range(handle, offset, length)
        self.breaker.record_success()
        return out

    def _delete(self, t: _Tenant, handle: int) -> int:
        if not self.breaker.allow_write():
            raise CircuitOpenError(self.breaker.state())
        self._check_owned(t, handle)
        freed = self.store.delete(handle)
        with t.lock:
            cost = t.handle_cost.pop(handle, 0)
            t.bytes_stored -= cost
        if t.cache is not None:
            t.cache.retain(lambda h: h != handle)
        self.breaker.record_success()
        return freed

    # --- lifecycle -----------------------------------------------------------

    def close(self, close_store: bool = False) -> None:
        """Stop admitting, drain in-flight requests, optionally close
        the underlying store. Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._pool.shutdown(wait=True)
        if close_store:
            self.store.close()

    def __enter__(self) -> "DedupServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
