"""Fault injection for durability testing (DESIGN.md §13).

The recovery machinery in this codebase — torn-tail truncation
(§10.6), orphan-container cleanup (§11.4), retry-with-backoff (§11.2),
scrub/repair (§13) — is only as trustworthy as the faults it has been
exercised against. This module is the single home for injecting them:

    TransientError      retryable object-store failure (the moral
                        equivalent of HTTP 429/5xx); raised by fault
                        hooks, absorbed by ``ObjectStoreBackend``'s
                        retry policy
    RetryBudgetExceeded a ``TransientError`` raised when the retry
                        policy's *total-deadline* budget runs out; it
                        carries how many attempts were made and how
                        long the policy slept
    FaultSchedule       a deterministic ``fault_hook`` failing chosen
                        per-op request ordinals (historically lived in
                        ``repro.api.objectstore``, still re-exported
                        there)
    SimulatedCrash      raised by an armed crashpoint; derives from
                        ``BaseException`` so no ``except Exception``
                        recovery path can accidentally absorb the
                        "process died here" signal
    FaultInjector       arms named crashpoints; backends thread one
                        through their write paths via ``faults=``
    flip_bit / flip_byte / truncate_tail
                        on-disk corruption injectors (bit rot, torn
                        writes, power-loss truncation)
    run_crash_script / check_crash_invariants
                        the crash-matrix harness: drive a portable op
                        script against a store until an armed
                        crashpoint fires, snapshot the directory as a
                        ``kill -9`` would have left it, then reopen
                        and assert the §13 invariants

Crashpoints are *registered* at import time by the modules that place
them (``containers.py``, ``objectstore.py``) so harnesses can enumerate
every fsync/rename/PUT boundary without grepping:
``registered_crashpoints()`` is the authoritative matrix.

This module is a leaf: it imports nothing from the rest of
``repro.api``, so every layer (containers, objectstore, store) can
depend on it without cycles.
"""
from __future__ import annotations

import dataclasses
import os
import random
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Sequence


class TransientError(Exception):
    """A retryable object-store failure — the moral equivalent of HTTP
    429/500/503 or a socket timeout. ``ObjectStoreBackend`` retries
    these with exponential backoff; anything else propagates."""

    def __init__(self, status: int = 503,
                 msg: str = "transient object-store error") -> None:
        super().__init__(f"{status}: {msg}")
        self.status = status


class RetryBudgetExceeded(TransientError):
    """The retry policy's total-deadline budget ran out (§11.2).

    Subclasses ``TransientError`` so callers that treat "the store is
    flaky right now" generically keep working; carries the forensic
    detail a bounded-hang policy owes its caller: how many attempts
    were issued and how long the policy slept before giving up."""

    def __init__(self, attempts: int, slept: float, deadline: float,
                 last: Exception | None = None) -> None:
        self.attempts = int(attempts)
        self.slept = float(slept)
        self.deadline = float(deadline)
        self.last = last
        status = getattr(last, "status", 503)
        Exception.__init__(
            self,
            f"retry deadline of {deadline:.3f}s exceeded after "
            f"{attempts} attempts ({slept:.3f}s slept); last error: "
            f"{last}")
        self.status = status


def with_retries(fn: Callable, args: Sequence = (), *,
                 max_retries: int = 4, backoff: float = 0.05,
                 cap: float | None = None, deadline: float | None = None,
                 rng: random.Random | None = None,
                 on_attempt: Callable[[float, bool], None] | None = None,
                 on_backoff: Callable[[float, int], None] | None = None,
                 sleep: Callable[[float], None] = time.sleep):
    """The audited decorrelated-jitter retry loop (§11.2/§13.5) as a
    reusable helper — one implementation shared by
    ``ObjectStoreBackend._call`` and the §15 serving layer instead of
    each caller hand-rolling its own backoff math.

    Calls ``fn(*args)``. On ``TransientError``: sleep a decorrelated
    jittered delay (``uniform(backoff, 3 * previous_delay)``, capped at
    ``cap``, default ``backoff * 2^max_retries``) and reissue, up to
    ``max_retries`` reissues AND at most ``deadline`` total seconds
    asleep — whichever budget runs out first. Exhausting the attempt
    budget re-raises the last ``TransientError``; exhausting the
    deadline raises ``RetryBudgetExceeded`` with the attempt count and
    slept seconds. Any non-transient exception propagates immediately.

    Observation hooks (all optional, all called outside the sleep):
    ``on_attempt(seconds, ok)`` after every issue of ``fn`` — including
    failed ones — with its wall time; ``on_backoff(delay, attempt)``
    once per absorbed fault, right before sleeping. ``rng``/``sleep``
    are injectable for deterministic tests."""
    if rng is None:
        rng = random.Random()
    if cap is None:
        cap = backoff * (1 << max_retries)
    attempt = 0
    slept = 0.0
    prev_delay = backoff
    while True:
        t0 = time.perf_counter() if on_attempt is not None else 0.0
        try:
            result = fn(*args)
        except TransientError as e:
            if on_attempt is not None:
                on_attempt(time.perf_counter() - t0, False)
            if attempt >= max_retries:
                raise
            delay = rng.uniform(backoff, min(cap, prev_delay * 3))
            if deadline is not None and slept + delay > deadline:
                raise RetryBudgetExceeded(attempt + 1, slept, deadline,
                                          last=e) from e
            prev_delay = delay
            if on_backoff is not None:
                on_backoff(delay, attempt + 1)
            sleep(delay)
            slept += delay
            attempt += 1
            continue
        if on_attempt is not None:
            on_attempt(time.perf_counter() - t0, True)
        return result


class FaultSchedule:
    """A ``fault_hook`` that fails chosen per-op request ordinals.

    ``FaultSchedule({"get": [2, 3]})`` raises a ``TransientError`` on
    the 2nd and 3rd GET-class requests (counting per op, 1-based) and
    lets everything else through — deterministic, so tests can assert
    exactly how many retries a restore needed."""

    def __init__(self, fail: dict[str, Sequence[int]],
                 status: int = 503) -> None:
        self._fail = {op: set(int(n) for n in ns) for op, ns in fail.items()}
        self._status = status
        self._seen: dict[str, int] = {}
        self._lock = threading.Lock()

    def __call__(self, op: str, key: str, n: int) -> Exception | None:
        with self._lock:
            k = self._seen.get(op, 0) + 1
            self._seen[op] = k
        if k in self._fail.get(op, ()):
            return TransientError(self._status,
                                  f"injected fault: {op} #{k} ({key})")
        return None


# --- crashpoints --------------------------------------------------------------

class SimulatedCrash(BaseException):
    """Raised when an armed crashpoint is hit. A ``BaseException`` on
    purpose: the point is to model the process dying *here*, and a
    well-meaning ``except Exception`` recovery path absorbing it would
    test the handler instead of the crash."""

    def __init__(self, point: str) -> None:
        super().__init__(f"simulated crash at {point!r}")
        self.point = point


# name -> description; populated at import time by the modules that
# place the crashpoint calls (containers.py, objectstore.py)
_CRASHPOINTS: dict[str, str] = {}


def register_crashpoint(name: str, description: str) -> str:
    """Declare a crashpoint once, at module import. Re-registering the
    same name with the same description is a no-op (modules reload under
    ``python -m``); a conflicting description is a hard error — two
    different boundaries must never share a matrix row."""
    existing = _CRASHPOINTS.get(name)
    if existing is not None and existing != description:
        raise ValueError(f"crashpoint {name!r} already registered with a "
                         f"different description")
    _CRASHPOINTS[name] = description
    return name


def registered_crashpoints() -> dict[str, str]:
    """The crash matrix: every registered ``name -> description``."""
    return dict(_CRASHPOINTS)


class FaultInjector:
    """Arms crashpoints; backends call ``crashpoint(name)`` at every
    fsync/rename/PUT boundary they registered.

    ``arm(name, ordinal)`` makes the *ordinal*-th hit of ``name``
    *after arming* raise ``SimulatedCrash`` (1-based; default the next
    one). Counting from the arm call — not from injector construction —
    means a harness can build a store (whose setup may already cross
    the boundary, e.g. the manifest PUT) and still catch the first hit
    its own op script causes. Hit counts are kept for every registered
    point whether armed or not, so a harness can assert its op script
    actually reached the boundary it meant to test (``hits``).
    Thread-safe — write paths may run on pool threads."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._armed: dict[str, int] = {}
        self.hits: dict[str, int] = {}
        self.fired: list[str] = []

    def arm(self, point: str, ordinal: int = 1) -> None:
        if point not in _CRASHPOINTS:
            raise ValueError(f"unknown crashpoint {point!r}; registered: "
                             f"{sorted(_CRASHPOINTS)}")
        if ordinal < 1:
            raise ValueError(f"ordinal must be >= 1, got {ordinal}")
        with self._lock:
            # absolute target hit count: ordinal is relative to *now*
            self._armed[point] = self.hits.get(point, 0) + int(ordinal)

    def disarm(self, point: str | None = None) -> None:
        with self._lock:
            if point is None:
                self._armed.clear()
            else:
                self._armed.pop(point, None)

    def crashpoint(self, point: str) -> None:
        """Called by instrumented code. Counts the hit; raises
        ``SimulatedCrash`` when this hit is the armed ordinal."""
        with self._lock:
            n = self.hits.get(point, 0) + 1
            self.hits[point] = n
            fire = self._armed.get(point) == n
            if fire:
                self.fired.append(point)
        if fire:
            raise SimulatedCrash(point)


# --- on-disk corruption injectors ---------------------------------------------

def flip_bit(path: str | Path, byte_offset: int, bit: int = 0) -> int:
    """Flip one bit of the file at ``path`` in place (bit rot). Returns
    the new byte value. Offsets are validated so a drifted test corrupts
    loudly instead of silently extending the file."""
    if not 0 <= bit < 8:
        raise ValueError(f"bit must be in [0, 8), got {bit}")
    path = os.fspath(path)
    size = os.path.getsize(path)
    if not 0 <= byte_offset < size:
        raise ValueError(f"offset {byte_offset} outside {path} "
                         f"({size} bytes)")
    with open(path, "r+b") as f:
        f.seek(byte_offset)
        old = f.read(1)[0]
        new = old ^ (1 << bit)
        f.seek(byte_offset)
        f.write(bytes([new]))
    return new


def flip_byte(path: str | Path, byte_offset: int) -> int:
    """Invert one whole byte in place; returns the new value."""
    for bit in range(1, 8):     # flip the remaining 7 bits
        flip_bit(path, byte_offset, bit)
    return flip_bit(path, byte_offset, 0)


def truncate_tail(path: str | Path, nbytes: int) -> int:
    """Drop the last ``nbytes`` of the file (power-loss truncation /
    torn write). Returns the new size; truncating more than the file
    holds leaves it empty."""
    path = os.fspath(path)
    size = os.path.getsize(path)
    new = max(0, size - max(0, int(nbytes)))
    os.truncate(path, new)
    return new


# --- crash-matrix harness -----------------------------------------------------

@dataclasses.dataclass
class CrashRun:
    """What one scripted run did before (maybe) crashing.

    ``committed`` maps stream name -> (handle, bytes) for every ingest
    whose commit *returned*; ``deleted`` holds names whose delete
    returned; ``pending`` is the op that was in flight when the crash
    fired (its effects are allowed to be absent — or, for a delete,
    either applied or not); ``crashed_at`` is the crashpoint name, or
    None when the script ran to completion."""

    committed: dict[str, tuple[int, bytes]]
    deleted: set[str]
    pending: tuple | None
    crashed_at: str | None


def run_crash_script(store: Any, ops: Sequence[tuple]) -> CrashRun:
    """Drive a portable op script against ``store`` until an armed
    crashpoint fires (or the script completes). Ops:

        ("ingest", name, data)   open_stream/write/commit
        ("delete", name)         delete a previously committed stream
        ("compact",)             store.compact()
        ("collect",)             store.collect()
        ("flush",)               backend flush

    The shadow state records only *completed* ops, so the returned
    ``CrashRun`` is exactly what a client that saw its calls return
    would be entitled to find after the crash."""
    committed: dict[str, tuple[int, bytes]] = {}
    deleted: set[str] = set()
    pending: tuple | None = None
    crashed_at: str | None = None
    try:
        for op in ops:
            pending = op
            kind = op[0]
            if kind == "ingest":
                _, name, data = op
                with store.open_stream() as s:
                    s.write(data)
                committed[name] = (s.report.handle, bytes(data))
            elif kind == "delete":
                store.delete(committed[op[1]][0])
                deleted.add(op[1])
            elif kind == "compact":
                store.compact()
            elif kind == "collect":
                store.collect()
            elif kind == "flush":
                store.backend.flush()
            else:
                raise ValueError(f"unknown crash-script op {op!r}")
            pending = None
    except SimulatedCrash as crash:
        crashed_at = crash.point
    return CrashRun(committed=committed, deleted=deleted,
                    pending=pending, crashed_at=crashed_at)


def snapshot_dir(src: str | Path, dst: str | Path) -> Path:
    """Copy the store directory as the on-disk state a ``kill -9`` left:
    bytes the process wrote through to the OS are present, bytes still
    sitting in user-space buffers of the abandoned (never closed) store
    object are not — which is exactly the distinction the crash model
    needs. Call it *before* dropping the crashed store, so no interpreter
    finalizer can flush more state into the copy."""
    dst = Path(dst)
    shutil.copytree(src, dst)
    return dst


def check_crash_invariants(store: Any, run: CrashRun) -> list[str]:
    """The §13 post-crash contract, checked on a *reopened* store:

      1. ``scrub()`` reports the store clean (recovery already retired
         anything the crash tore);
      2. every stream whose commit returned — and that was not deleted —
         restores byte-identically;
      3. every stream whose delete returned stays deleted;
      4. the op in flight at the crash may have happened or not, but a
         half-state is never visible: an in-flight ingest's stream simply
         doesn't exist (its commit never returned a handle), an in-flight
         delete's stream is either intact or gone.

    Returns a list of violation descriptions — empty means the store
    honoured the contract."""
    errors: list[str] = []
    report = store.scrub()
    if not report.clean:
        errors.append(f"scrub not clean after reopen: "
                      f"corrupt={list(report.corrupt)} "
                      f"missing={list(report.missing)} "
                      f"streams_lost={list(report.streams_lost)} "
                      f"structural={list(report.structural_errors)}")
    pending_delete = (run.pending[1]
                      if run.pending and run.pending[0] == "delete"
                      else None)
    for name, (handle, data) in run.committed.items():
        if name in run.deleted:
            try:
                store.restore(handle)
            except (KeyError, IndexError):
                continue
            errors.append(f"deleted stream {name!r} (handle {handle}) "
                          f"resurrected")
        elif name == pending_delete:
            try:
                got = store.restore(handle)
            except (KeyError, IndexError):
                continue        # the in-flight delete landed: fine
            if got != data:
                errors.append(f"stream {name!r} (handle {handle}) "
                              f"survived its in-flight delete but "
                              f"restored wrong bytes")
        else:
            try:
                got = store.restore(handle)
            except Exception as e:      # noqa: BLE001 - report, don't mask
                errors.append(f"committed stream {name!r} (handle "
                              f"{handle}) unrestorable: {e!r}")
                continue
            if got != data:
                errors.append(f"committed stream {name!r} (handle "
                              f"{handle}) restored wrong bytes "
                              f"({len(got)} vs {len(data)})")
    return errors


def abandon(store: Any) -> None:
    """Best-effort resource release of a crashed store *after* the
    directory snapshot was taken. Close may legitimately fail (the crash
    fired mid-mutation); anything it still manages to flush goes to the
    original directory, never the snapshot."""
    try:
        store.close()
    except BaseException:       # noqa: BLE001 - crashed object, anything goes
        pass
