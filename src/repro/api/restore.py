"""Restore/serving fast path: planner + bounded decode cache (DESIGN.md §9).

CARD's whole point is detecting *more* resemblance, which means a larger
delta-chunk fraction and deeper base chains — so serving a stream back is
dominated by chain decodes and container reads, not hash lookups. This
module holds the two pieces of the read path that are pure policy (no
backend I/O), so every backend and the store share them:

    plan_chains    group the requested chunk ids by shared base chains,
                   topologically order the decodes so every base is
                   decoded exactly once per restore, and schedule the
                   physical payload reads in ascending log-offset order
                   (the backend coalesces adjacent records into batched
                   sequential reads);
    DecodeCache    byte-budgeted cache over materialized chunk bytes with
                   chain-aware pinning: an entry a still-pending patch in
                   the current plan decodes against is pinned and cannot
                   be evicted, everything else rotates under the budget
                   according to a pluggable :class:`CachePolicy`
                   ("lru" or the scan-resistant "arc", DESIGN.md §14.1).
                   Replaces FileBackend's unbounded dict cache —
                   restoring a store larger than RAM no longer
                   materializes the whole dataset.
    RecipeLayout   prefix sums over a recipe's materialized chunk
                   lengths; maps a byte range onto the minimal chunk-id
                   window so ``restore_range`` decodes only what the
                   range overlaps.

The planner consumes two callbacks instead of a backend so it stays
dependency-free (and unit-testable on synthetic topologies):
``entry(cid) -> (base, offset, length)`` describes the stored record
(``base < 0`` = raw) and ``is_cached(cid)`` asks the decode cache.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
import threading
from collections import OrderedDict
from typing import Callable, Protocol, Sequence

from repro.api.registry import get_cache_policy, register_cache_policy

#: Default decode-cache budget for file-backed stores. Large enough that
#: version-chain restores stay warm, small enough that restoring a
#: multi-GB store does not silently become an in-RAM copy of it.
DEFAULT_CACHE_BYTES = 128 << 20

#: Default shard count for :class:`ShardedDecodeCache` (DESIGN.md §10.2).
#: Sequential chunk ids stripe round-robin across shards, so adjacent
#: chunks of one restore — and concurrent restores of different streams —
#: rarely contend on the same shard lock.
DEFAULT_CACHE_SHARDS = 8

#: Default eviction policy (DESIGN.md §14.1). "lru" preserves the
#: pre-§14 behaviour bit-for-bit; "arc" adds scan resistance.
DEFAULT_CACHE_POLICY = "lru"


class CachePolicy(Protocol):
    """Eviction-ordering strategy behind :class:`DecodeCache`
    (DESIGN.md §14.1).

    The cache owns storage (``cid -> bytes``), pin refcounts, the byte
    ledger, and the lock; the policy owns only *ordering* metadata —
    which live cid to evict next, plus any ghost bookkeeping for
    entries already evicted. Every method is called with the cache's
    shard lock held, so policies need no locking of their own. The
    policy's live-entry book must mirror the cache's entries exactly:
    every ``on_insert``ed cid stays known until ``victim`` returns it
    or ``on_remove`` drops it.

    Factories are registered via ``register_cache_policy(name)`` and
    take the shard's ``budget_bytes`` (ghost lists size themselves off
    it).
    """

    ghost_hits: int   # evicted-then-rereferenced events (scan signal)
    evictions: int    # victims handed back from victim()

    def on_hit(self, cid: int) -> None:
        """A cached cid was referenced (get/get_present/try_pin)."""

    def on_insert(self, cid: int, nbytes: int) -> None:
        """``put`` stored ``nbytes`` for cid (may replace a live entry;
        policies must treat a live re-insert as a size update + touch)."""

    def on_remove(self, cid: int) -> None:
        """cid was invalidated (compaction ``retain``): forget it
        entirely — no ghost entry, the chunk no longer exists."""

    def victim(self, is_pinned: Callable[[int], object]) -> int | None:
        """Pick, book-keep (live -> ghost), and return the next evictee,
        skipping cids where ``is_pinned(cid)`` is truthy; None when every
        live entry is pinned."""


@register_cache_policy("lru")
class LruCachePolicy:
    """Classic least-recently-used — the pre-§14 inlined policy, byte
    identical: one recency queue, oldest unpinned entry evicts first,
    no ghost memory (``ghost_hits`` stays 0)."""

    def __init__(self, budget_bytes: int) -> None:
        self._order: "OrderedDict[int, int]" = OrderedDict()
        self.ghost_hits = 0
        self.evictions = 0

    def on_hit(self, cid: int) -> None:
        self._order.move_to_end(cid)

    def on_insert(self, cid: int, nbytes: int) -> None:
        self._order[cid] = nbytes
        self._order.move_to_end(cid)

    def on_remove(self, cid: int) -> None:
        self._order.pop(cid, None)

    def victim(self, is_pinned: Callable[[int], object]) -> int | None:
        cid = next((c for c in self._order if not is_pinned(c)), None)
        if cid is not None:
            del self._order[cid]
            self.evictions += 1
        return cid


@register_cache_policy("arc")
class ArcCachePolicy:
    """Scan-resistant adaptive policy (ARC-style ghost lists, §14.1).

    Live entries split into a recency queue T1 (seen once) and a
    frequency queue T2 (seen again while live); evicted cids leave a
    byte-sized *ghost* in B1/B2 mirroring the queue they died in. A
    miss that lands on a ghost is a reuse the cache failed to hold —
    the adaptation target ``p`` (how many budget bytes T1 deserves)
    grows on B1 ghost hits and shrinks on B2 ghost hits, and the
    reinserted cid goes straight to T2. A whole-store scan touches
    every chunk exactly once, so its pages live and die in T1 without
    ever displacing T2 — the hot chain bases pointed restores need
    (the 1701.04451 workload argument in ISSUE/ROADMAP).

    Sizes are bytes, not entry counts — chunk sizes vary ~100× and an
    entry-counted ARC would let one jumbo raw chunk evict a thousand
    hot bases. Ghost lists are trimmed to one budget's worth of bytes
    per side, so policy overhead stays O(metadata), never O(payload).
    """

    def __init__(self, budget_bytes: int) -> None:
        self.budget_bytes = int(budget_bytes)
        self._t1: "OrderedDict[int, int]" = OrderedDict()  # recency
        self._t2: "OrderedDict[int, int]" = OrderedDict()  # frequency
        self._b1: "OrderedDict[int, int]" = OrderedDict()  # ghosts of T1
        self._b2: "OrderedDict[int, int]" = OrderedDict()  # ghosts of T2
        self._t1_bytes = 0
        self._b1_bytes = 0
        self._b2_bytes = 0
        self._p = 0               # byte target for T1
        self.ghost_hits = 0
        self.evictions = 0

    def on_hit(self, cid: int) -> None:
        nbytes = self._t1.pop(cid, None)
        if nbytes is not None:    # second reference: promote to T2
            self._t1_bytes -= nbytes
            self._t2[cid] = nbytes
        elif cid in self._t2:
            self._t2.move_to_end(cid)

    def on_insert(self, cid: int, nbytes: int) -> None:
        nbytes = int(nbytes)
        if cid in self._t1:       # live replacement: size update + touch
            self._t1_bytes += nbytes - self._t1[cid]
            self._t1[cid] = nbytes
            self._t1.move_to_end(cid)
            return
        if cid in self._t2:
            self._t2[cid] = nbytes
            self._t2.move_to_end(cid)
            return
        ghost = self._b1.pop(cid, None)
        if ghost is not None:     # recency ghost: T1 was too small
            self._b1_bytes -= ghost
            self.ghost_hits += 1
            self._p = min(self.budget_bytes, self._p + ghost)
            self._t2[cid] = nbytes
            return
        ghost = self._b2.pop(cid, None)
        if ghost is not None:     # frequency ghost: T1 was too greedy
            self._b2_bytes -= ghost
            self.ghost_hits += 1
            self._p = max(0, self._p - ghost)
            self._t2[cid] = nbytes
            return
        self._t1[cid] = nbytes    # brand new: recency side
        self._t1_bytes += nbytes

    def on_remove(self, cid: int) -> None:
        nbytes = self._t1.pop(cid, None)
        if nbytes is not None:
            self._t1_bytes -= nbytes
        else:
            self._t2.pop(cid, None)
        # invalidations leave no ghost: the chunk is gone from the
        # store, remembering it would skew adaptation toward dead ids

    def victim(self, is_pinned: Callable[[int], object]) -> int | None:
        # evict from T1 while it overshoots its target (or T2 is empty),
        # else from T2; fall back to the other queue when the preferred
        # one holds only pinned entries
        prefer_t1 = self._t1_bytes > self._p or not self._t2
        queues = (self._t1, self._t2) if prefer_t1 else (self._t2, self._t1)
        for queue in queues:
            cid = next((c for c in queue if not is_pinned(c)), None)
            if cid is None:
                continue
            nbytes = queue.pop(cid)
            if queue is self._t1:
                self._t1_bytes -= nbytes
                self._b1[cid] = nbytes
                self._b1_bytes += nbytes
            else:
                self._b2[cid] = nbytes
                self._b2_bytes += nbytes
            self.evictions += 1
            self._trim_ghosts()
            return cid
        return None

    def _trim_ghosts(self) -> None:
        # each ghost side remembers at most one budget's worth of
        # evicted bytes — enough to recognize any reuse the live cache
        # could possibly have held, bounded so metadata cannot grow
        # with the store
        while self._b1_bytes > self.budget_bytes and self._b1:
            _, nbytes = self._b1.popitem(last=False)
            self._b1_bytes -= nbytes
        while self._b2_bytes > self.budget_bytes and self._b2:
            _, nbytes = self._b2.popitem(last=False)
            self._b2_bytes -= nbytes


def _resolve_policy(policy: str, budget_bytes: int):
    factory = get_cache_policy(policy)
    return factory(budget_bytes)


class DecodeCache:
    """Byte-budgeted cache of materialized chunk bytes with pinning and
    a pluggable eviction policy (DESIGN.md §9, §14.1).

    ``pin``/``unpin`` are refcounted; pinned entries are skipped by
    eviction (the restore planner pins a base until the last dependent
    patch of the current plan has decoded against it, so a plan never
    re-decodes a chain it already walked). ``peak_bytes`` is sampled at
    stable points (after each eviction pass), which is what the budget
    acceptance test pins.

    Eviction *ordering* is delegated to a :class:`CachePolicy` resolved
    by registry name ("lru" default, "arc" scan-resistant); storage,
    pins, byte accounting, and hit/miss counters live here so the
    pin/try_pin/get_present contracts are identical under every policy.

    Every mutating operation is atomic under an internal lock, so a
    single instance is safe to share between restore threads — and it is
    the shard building block of :class:`ShardedDecodeCache`, which
    spreads that lock N ways (DESIGN.md §10.2).
    """

    def __init__(self, budget_bytes: int = DEFAULT_CACHE_BYTES,
                 policy: str = DEFAULT_CACHE_POLICY) -> None:
        if budget_bytes <= 0:
            raise ValueError(f"cache budget must be positive, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self.policy_name = str(policy)
        self._policy = _resolve_policy(self.policy_name, self.budget_bytes)
        self._lock = threading.Lock()
        self._entries: dict[int, bytes] = {}
        self._pins: dict[int, int] = {}
        self.bytes = 0
        self.peak_bytes = 0
        self.hits = 0
        self.misses = 0

    def __contains__(self, cid: int) -> bool:
        return cid in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, cid: int) -> bytes | None:
        """Cached bytes (touching the policy's ordering) or None; counts
        hit/miss."""
        with self._lock:
            data = self._entries.get(cid)
            if data is None:
                self.misses += 1
                return None
            self.hits += 1
            self._policy.on_hit(cid)
            return data

    def peek(self, cid: int) -> bytes | None:
        """``get`` without touching the hit/miss counters or eviction
        order — for plan-internal base lookups (the plan itself pinned
        the entry moments ago; counting those as hits would inflate the
        §9.4 telemetry every cold restore of a delta chain). Still takes
        the lock — other threads mutate the dict under it, and the
        thread-safety contract is every-operation-atomic, not
        GIL-happens-to-save-us."""
        with self._lock:
            return self._entries.get(cid)

    def get_present(self, cids: Sequence[int]) -> dict[int, bytes]:
        """Batched ``get``: one lock acquisition for the whole batch —
        the warm-restore hot path (§10.2) would otherwise pay a lock
        round-trip per recipe slot. Counter/ordering semantics are
        identical to per-cid ``get``; absent cids are simply missing
        from the result (and counted as misses — a caller that then
        materializes them itself must reclassify, see
        ``PlannedChainReader.get_many``)."""
        with self._lock:
            entries = self._entries
            on_hit = self._policy.on_hit
            found: dict[int, bytes] = {}
            for cid in cids:
                data = entries.get(cid)
                if data is None:
                    self.misses += 1
                else:
                    self.hits += 1
                    on_hit(cid)
                    found[cid] = data
            return found

    def put(self, cid: int, data: bytes, pin: bool = False) -> None:
        with self._lock:
            old = self._entries.get(cid)
            if old is not None:
                self.bytes -= len(old)
            self._entries[cid] = data
            self.bytes += len(data)
            self._policy.on_insert(cid, len(data))
            if pin:
                self._pins[cid] = self._pins.get(cid, 0) + 1
            self._evict()

    def pin(self, cid: int) -> None:
        """Protect an already-cached entry from eviction (refcounted)."""
        with self._lock:
            if cid not in self._entries:
                raise KeyError(f"cannot pin uncached chunk {cid}")
            self._pins[cid] = self._pins.get(cid, 0) + 1

    def try_pin(self, cid: int) -> bytes | None:
        """Atomically pin-and-return a cached entry, or None when absent.

        The concurrent planner probe (DESIGN.md §10.2): between "is this
        cached?" and "pin it" another thread's eviction could drop the
        entry, so the two must be one operation. Deliberately does NOT
        count hits/misses — the serial planner's ``is_cached`` probe was
        uncounted too, and probing every chain node would otherwise
        inflate the §9.4 telemetry on every cold restore. It IS a real
        reuse though, so the policy ordering is touched."""
        with self._lock:
            data = self._entries.get(cid)
            if data is None:
                return None
            self._policy.on_hit(cid)
            self._pins[cid] = self._pins.get(cid, 0) + 1
            return data

    def unpin(self, cid: int) -> None:
        with self._lock:
            left = self._pins.get(cid, 0) - 1
            if left < 0:
                raise ValueError(f"unpin underflow on chunk {cid}")
            if left:
                self._pins[cid] = left
            else:
                self._pins.pop(cid, None)
                self._evict()

    def retain(self, keep: Callable[[int], bool]) -> None:
        """Drop every unpinned entry whose cid fails ``keep`` (compaction)."""
        with self._lock:
            for cid in [c for c in self._entries
                        if not keep(c) and not self._pins.get(c)]:
                data = self._entries.pop(cid)
                self._policy.on_remove(cid)
                self.bytes -= len(data)

    @property
    def ghost_hits(self) -> int:
        return self._policy.ghost_hits

    @property
    def evictions(self) -> int:
        return self._policy.evictions

    def _evict(self) -> None:
        # called with self._lock held. The policy picks victims (and
        # does its ghost bookkeeping); pinned bytes may transiently
        # exceed the budget (the plan working set), and then nothing
        # can be dropped until an unpin
        while self.bytes > self.budget_bytes:
            victim = self._policy.victim(self._pins.get)
            if victim is None:
                break
            self.bytes -= len(self._entries.pop(victim))
        if self.bytes > self.peak_bytes:
            self.peak_bytes = self.bytes


class ShardedDecodeCache:
    """N independent :class:`DecodeCache` shards behind one facade
    (DESIGN.md §10.2).

    Chunk ids stripe across shards (``cid % shards``); the global byte
    budget is apportioned across shards (remainder spread one byte per
    leading shard), so the sum of shard budgets is exactly the global
    budget and the aggregate ``peak_bytes`` (sum of shard peaks) can
    only exceed it when pinned working sets do — same contract a single
    cache has. Each operation takes exactly one shard lock, so restore
    threads working different parts of the id space never contend.

    Counters (``hits``/``misses``/``bytes``/``peak_bytes``) aggregate
    across shards; on a serial workload they equal a single-shard cache's
    counters as long as no eviction fires (eviction order is per-shard,
    not global — the one observable policy difference).

    Every shard runs its own instance of the same :class:`CachePolicy`
    (§14.1), each adapting to the id-striped slice of traffic it sees.
    """

    def __init__(self, budget_bytes: int = DEFAULT_CACHE_BYTES,
                 shards: int = DEFAULT_CACHE_SHARDS,
                 policy: str = DEFAULT_CACHE_POLICY) -> None:
        if budget_bytes <= 0:
            raise ValueError(f"cache budget must be positive, got {budget_bytes}")
        if shards <= 0:
            raise ValueError(f"shard count must be positive, got {shards}")
        # never hand a shard a zero budget (DecodeCache rejects it)
        shards = min(int(shards), int(budget_bytes))
        base, rem = divmod(int(budget_bytes), shards)
        self.shards = [DecodeCache(base + (1 if i < rem else 0), policy=policy)
                       for i in range(shards)]
        self.budget_bytes = int(budget_bytes)
        self.policy_name = str(policy)

    def _shard(self, cid: int) -> DecodeCache:
        return self.shards[cid % len(self.shards)]

    def __contains__(self, cid: int) -> bool:
        return cid in self._shard(cid)

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def get(self, cid: int) -> bytes | None:
        return self._shard(cid).get(cid)

    def peek(self, cid: int) -> bytes | None:
        return self._shard(cid).peek(cid)

    def get_present(self, cids: Sequence[int]) -> dict[int, bytes]:
        """Batched ``get`` across shards: cids group by shard and each
        shard is locked once, so a warm restore costs O(shards) lock
        round-trips instead of O(chunks)."""
        shards = self.shards
        n = len(shards)
        if n == 1:
            return shards[0].get_present(cids)
        groups: list[list[int] | None] = [None] * n
        for cid in cids:
            g = groups[cid % n]
            if g is None:
                groups[cid % n] = [cid]
            else:
                g.append(cid)
        found: dict[int, bytes] = {}
        for idx, group in enumerate(groups):
            if group is not None:
                found.update(shards[idx].get_present(group))
        return found

    def put(self, cid: int, data: bytes, pin: bool = False) -> None:
        self._shard(cid).put(cid, data, pin=pin)

    def pin(self, cid: int) -> None:
        self._shard(cid).pin(cid)

    def try_pin(self, cid: int) -> bytes | None:
        return self._shard(cid).try_pin(cid)

    def unpin(self, cid: int) -> None:
        self._shard(cid).unpin(cid)

    def retain(self, keep: Callable[[int], bool]) -> None:
        for s in self.shards:
            s.retain(keep)

    @property
    def hits(self) -> int:
        return sum(s.hits for s in self.shards)

    @property
    def misses(self) -> int:
        return sum(s.misses for s in self.shards)

    @property
    def bytes(self) -> int:
        return sum(s.bytes for s in self.shards)

    @property
    def peak_bytes(self) -> int:
        return sum(s.peak_bytes for s in self.shards)

    @property
    def ghost_hits(self) -> int:
        return sum(s.ghost_hits for s in self.shards)

    @property
    def evictions(self) -> int:
        return sum(s.evictions for s in self.shards)

    @property
    def _pins(self) -> dict[int, int]:
        # merged read-only view (shards never share a cid)
        merged: dict[int, int] = {}
        for s in self.shards:
            merged.update(s._pins)
        return merged


@dataclasses.dataclass
class RestorePlan:
    """One restore's worth of work, planned before any I/O happens.

    targets       requested chunk ids, deduplicated, request order
    decode_order  every chunk the plan decodes, bases strictly before
                  their dependents, each exactly once
    reads         (offset, length, cid) payload reads in ascending
                  container-offset order — the backend merges adjacent
                  entries into batched sequential reads
    dependents    cid -> how many patches in this plan decode against it
                  (the decode loop pins a base until this drains to 0)
    cached_bases  chain walks that stopped at an already-cached chunk;
                  the executor pins these up front so eviction cannot
                  race the plan
    """

    targets: list[int]
    decode_order: list[int]
    reads: list[tuple[int, int, int]]
    dependents: dict[int, int]
    cached_bases: list[int]

    def __len__(self) -> int:
        return len(self.decode_order)


def plan_chains(targets: Sequence[int],
                entry: Callable[[int], tuple[int, int, int]],
                is_cached: Callable[[int], bool]) -> RestorePlan:
    """Plan the decode of ``targets`` (see module docstring).

    ``entry(cid)`` -> ``(base, offset, length)`` for the stored record
    (``base < 0`` raw); ``is_cached`` consults the decode cache. Chains
    share suffixes freely: a base reached from several targets is read
    and decoded once, and a walk that hits an already-planned or cached
    chunk stops there.
    """
    decode_order: list[int] = []
    planned: set[int] = set()
    dependents: dict[int, int] = {}
    cached_seen: set[int] = set()
    cached_bases: list[int] = []
    reads: list[tuple[int, int, int]] = []
    uniq = list(dict.fromkeys(int(t) for t in targets))
    for tgt in uniq:
        path: list[int] = []
        cur = tgt
        while cur not in planned:
            if is_cached(cur):
                # record it as a pinnable base only when a patch in this
                # plan decodes against it — a cached *target* is served
                # straight from the cache and needs no pin
                if cur != tgt and cur not in cached_seen:
                    cached_seen.add(cur)
                    cached_bases.append(cur)
                break
            base, offset, length = entry(cur)
            path.append(cur)
            planned.add(cur)
            reads.append((offset, length, cur))
            if base < 0:
                break
            dependents[base] = dependents.get(base, 0) + 1
            cur = base
        decode_order.extend(reversed(path))
    reads.sort()
    return RestorePlan(targets=uniq, decode_order=decode_order, reads=reads,
                       dependents=dependents, cached_bases=cached_bases)


def coalesce_reads(reads: Sequence[tuple[int, int, int]], gap: int,
                   max_run: int) -> list[tuple[int, int, list]]:
    """Merge a plan's offset-sorted ``(offset, length, cid)`` reads into
    sequential runs ``(start, end, extents)`` (DESIGN.md §9.1).

    Adjacent extents whose gap is at most ``gap`` bytes are fetched as
    one read; runs are capped near ``max_run`` so a single slab never
    dwarfs the decode-cache budget. The gap is a *backend* knob
    (``coalesce_gap`` / ``DedupConfig.restore_coalesce_gap``): a local
    file wants KB-scale gaps (skipping dead records is nearly free), an
    object store wants MB-scale gaps so one ranged GET amortizes its
    request latency over many extents (§11.3). ``reads`` must already be
    sorted by offset — ``plan_chains`` emits them that way."""
    runs: list[tuple[int, int, list]] = []
    i, n_reads = 0, len(reads)
    while i < n_reads:
        start = reads[i][0]
        end = start + reads[i][1]
        j = i + 1
        while (j < n_reads
               and reads[j][0] - end <= gap
               and end - start < max_run):
            end = max(end, reads[j][0] + reads[j][1])
            j += 1
        runs.append((start, end, list(reads[i:j])))
        i = j
    return runs


class RecipeLayout:
    """Prefix sums over a recipe's materialized chunk lengths.

    Maps byte ranges onto chunk windows for ``restore_range``. Lengths
    are invariant under compaction (rebasing rewrites *patches*, never
    materialized bytes — DESIGN.md §7.2), so a layout stays valid for a
    handle's whole lifetime; the store drops it on ``delete``.
    """

    def __init__(self, lengths: Sequence[int]) -> None:
        # plain list + bisect: scalar np.searchsorted costs ~4µs a call,
        # which dominates small ranged reads (§10.7 profile)
        self._ends = list(itertools.accumulate(int(n) for n in lengths))

    @property
    def total_bytes(self) -> int:
        return self._ends[-1] if self._ends else 0

    def chunk_window(self, offset: int, length: int) -> tuple[int, int, int]:
        """``(first, last, skip)``: recipe slots ``first..last`` (inclusive)
        cover ``[offset, offset+length)``, whose first requested byte sits
        ``skip`` bytes into chunk ``first``. Empty ranges return
        ``(0, -1, 0)``."""
        if offset < 0 or length < 0:
            raise ValueError(f"negative range ({offset}, {length})")
        total = self.total_bytes
        start = min(offset, total)
        end = min(offset + length, total)
        if end <= start:
            return (0, -1, 0)
        ends = self._ends
        first = bisect.bisect_right(ends, start)
        last = bisect.bisect_left(ends, end)
        chunk_start = ends[first - 1] if first else 0
        return (first, last, start - chunk_start)
