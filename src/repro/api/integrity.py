"""End-to-end integrity: checksums, typed corruption errors, scrub/repair
(DESIGN.md §13).

In a dedup+delta store one flipped bit is never one flipped bit: a
corrupt payload that happens to be a shared base poisons every patch
chained on it and every stream whose recipe names any of them —
deduplication *amplifies* loss. This module holds the pieces that bound
that blast radius:

    crc32c()            the record checksum (Castagnoli CRC-32C, the
                        polynomial object stores and filesystems use);
                        hardware-accelerated via ``google_crc32c`` when
                        available, with a pure-Python table fallback so
                        the format never depends on an optional wheel
    CorruptChunkError   a verified read found payload bytes that do not
                        match the stored checksum (carries cid,
                        container, expected/actual digests)
    CorruptJournalError a malformed record in the *middle* of a recipe
                        journal — unlike a torn tail, mid-file damage is
                        corruption and must not be silently truncated
    ScrubReport         what one fsck walk found (and, in repair mode,
                        did): per-chunk verdicts, transitive blast
                        radius, structural-consistency findings
    scrub(store)        the walk itself — ``DedupStore.scrub`` delegates
                        here under its exclusive lifecycle lock

Leaf module: imports only ``repro.api.refcount`` (for the consistency
check) and ``repro.api.lifecycle`` lazily (for the post-repair rebind),
so the container backends can import the error types and the checksum
without cycles.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

try:                                # hardware CRC32C when the wheel exists
    from google_crc32c import value as _crc32c_native
except ImportError:                 # pragma: no cover - env-dependent
    _crc32c_native = None

_CRC32C_POLY = 0x82F63B78           # Castagnoli, reflected
_CRC32C_TABLE: list[int] | None = None


def _crc32c_table() -> list[int]:
    global _CRC32C_TABLE
    if _CRC32C_TABLE is None:
        table = []
        for i in range(256):
            crc = i
            for _ in range(8):
                crc = (crc >> 1) ^ (_CRC32C_POLY if crc & 1 else 0)
            table.append(crc)
        _CRC32C_TABLE = table
    return _CRC32C_TABLE


def _crc32c_py(data: bytes) -> int:
    """Pure-Python CRC-32C — byte-at-a-time, kept for correctness (and
    environments without ``google_crc32c``), not speed. Verified against
    the RFC 3720 test vector in tests/test_integrity.py."""
    table = _crc32c_table()
    crc = 0xFFFFFFFF
    for b in data:
        crc = table[(crc ^ b) & 0xFF] ^ (crc >> 8)
    return crc ^ 0xFFFFFFFF


def crc32c(data: bytes | bytearray | memoryview) -> int:
    """CRC-32C (Castagnoli) of ``data`` as an unsigned 32-bit int — the
    checksum persisted in FileBackend record headers and
    ObjectStoreBackend journal rows (DESIGN.md §13.1)."""
    if _crc32c_native is not None:
        return int(_crc32c_native(bytes(data)))
    return _crc32c_py(bytes(data))


class CorruptChunkError(IOError):
    """A payload failed its checksum on a verified read (§13.2).

    Subclasses ``IOError`` deliberately: the read engine already
    documents IOError for truncated records, so callers with a generic
    "this restore is damaged" path keep working while new callers can
    catch the typed error and read the forensics off it."""

    def __init__(self, cid: int, container: str,
                 expected: int, actual: int) -> None:
        super().__init__(
            f"corrupt chunk {cid}: payload crc32c {actual:#010x} != "
            f"stored {expected:#010x} ({container})")
        self.cid = int(cid)
        self.container = container
        self.expected = int(expected)
        self.actual = int(actual)


class CorruptJournalError(ValueError):
    """A recipe journal holds a malformed record *before* its final
    line. A torn tail (crash mid-append) is expected and truncated on
    open; damage in the middle of the file means the journal itself was
    corrupted and silently dropping everything after it would resurrect
    deleted streams — fail loudly instead (§13.2)."""

    def __init__(self, path: str, line_no: int, detail: str) -> None:
        super().__init__(f"corrupt journal {path}: line {line_no}: {detail}")
        self.path = str(path)
        self.line_no = int(line_no)
        self.detail = detail


@dataclasses.dataclass(frozen=True)
class ScrubReport:
    """What one fsck walk over the store found (DESIGN.md §13.3).

    ``corrupt`` holds chunks whose stored payload failed its checksum or
    could not be read at all; ``lost`` additionally closes over delta
    dependents (a patch whose base — at any depth — is corrupt can never
    decode, even though its own bytes are fine). ``missing`` are chunks
    a live recipe names but the backend no longer holds.
    ``blast_radius`` maps each corrupt chunk to the number of live
    streams transitively unrestorable because of it — the §13
    amplification number. ``unverifiable`` counts records that predate
    checksums (pre-RCL2 logs, pre-checksum journal rows): intact as far
    as anyone can tell, but unprovable.

    In repair mode ``quarantined``/``retired_streams`` record what was
    durably dropped: corrupt+lost chunks via the backend's quarantine
    journal entries, affected streams via the recovery-retire tombstone
    machinery — after which a fresh scrub of the store is clean.

    ``payload_requests`` counts the backend payload requests the walk
    actually issued; ``payload_requests_naive`` is what a per-chunk walk
    would have issued (one GET per indexed chunk). Backends exposing
    ``scrub_stream`` (the object store, §14.5) serve one streamed GET
    per container object, so the gap between the two is the scrub's
    request savings."""

    chunks: int
    bytes_checked: int
    verified: int
    unverifiable: int
    corrupt: tuple[int, ...]
    lost: tuple[int, ...]
    missing: tuple[int, ...]
    streams: int
    streams_lost: tuple[int, ...]
    blast_radius: dict[int, int]
    structural_errors: tuple[str, ...]
    repaired: bool
    quarantined: tuple[int, ...]
    retired_streams: tuple[int, ...]
    seconds: float
    payload_requests: int = 0
    payload_requests_naive: int = 0

    @property
    def clean(self) -> bool:
        """No corruption, nothing lost or missing, structure consistent."""
        return not (self.corrupt or self.lost or self.missing
                    or self.streams_lost or self.structural_errors)


def _record_source(backend: Any, cids: list[int]) -> Any:
    """Per-chunk payload source for backends without ``scrub_stream``:
    yields ``(cid, payload | None, note)`` straight off the containers
    via ``backend.record`` — a ``None`` payload is unreadable-or-corrupt,
    a non-None ``note`` additionally flags a structural finding."""
    for cid in cids:
        try:
            _, _, payload = backend.record(cid)
        except CorruptChunkError:
            # a verify_reads backend checked for us; trust its verdict
            yield cid, None, None
        except (OSError, KeyError, IndexError) as e:
            yield cid, None, f"unreadable ({e})"
        else:
            yield cid, payload, None


def _dependents_closure(seeds: set[int], base_of: dict[int, int]) -> set[int]:
    """``seeds`` plus every chunk whose base chain passes through one."""
    out = set(seeds)
    changed = True
    while changed:
        changed = False
        for cid, base in base_of.items():
            if base in out and cid not in out:
                out.add(cid)
                changed = True
    return out


def scrub(store: Any, repair: bool = False) -> ScrubReport:
    """Verify every stored record, recipe reachability and refcount
    consistency; optionally quarantine what is damaged (§13.3).

    Runs under the store's exclusive lifecycle lock (the caller —
    ``DedupStore.scrub`` — takes it), so no reads or commits are in
    flight while records are walked or, in repair mode, while recipes
    are retired and chunks quarantined.

    The walk reads every indexed payload straight off the containers —
    never the decode cache or disk tier — preferring the backend's
    ``scrub_stream`` (one streamed GET per container object, §14.5) over
    per-chunk ``backend.record`` calls, and checks each payload
    against the persisted checksum (``backend.checksum_of``). Records
    without one (pre-checksum formats) count as ``unverifiable``.
    Structural checks: every delta base resolves (no dangling chains, no
    cycles), every live recipe's chunks exist, and a refcount table
    rebuilt from durable state matches the store's in-memory one.

    Repair quarantines ``corrupt + lost`` chunks through the backend's
    durable quarantine journal entries and retires every affected live
    stream through the same durable tombstone machinery crash recovery
    uses, then rebinds the store's derived views (refcounts, digest
    table, layouts). Untouched streams survive byte-identical; a
    follow-up scrub reports clean."""
    t0 = time.perf_counter()
    backend = store.backend
    backend.flush()
    checksum_of = getattr(backend, "checksum_of", None)

    cids = sorted(backend.chunk_ids())
    base_of: dict[int, int] = {cid: backend.base_of(cid) for cid in cids}
    corrupt: list[int] = []
    structural: list[str] = []
    verified = unverifiable = 0
    bytes_checked = 0

    # payload source: backends that can stream one GET per container
    # object (scrub_stream, §14.5) beat the naive one-request-per-chunk
    # walk by the dedup factor; everything downstream is order-independent
    # so the stream may yield in container order, not cid order.
    payload_requests_naive = len(cids)
    stream_fn = getattr(backend, "scrub_stream", None)
    if stream_fn is not None:
        payload_requests, stream = stream_fn()
        source = ((cid, payload,
                   None if payload is not None
                   else "unreadable (missing or short container object)")
                  for cid, payload in stream)
    else:
        payload_requests = payload_requests_naive
        source = _record_source(backend, cids)

    walked: set[int] = set()
    for cid, payload, note in source:
        walked.add(cid)
        if payload is None:
            corrupt.append(cid)
            if note is not None:
                structural.append(f"chunk {cid}: {note}")
            continue
        bytes_checked += len(payload)
        expected = checksum_of(cid) if checksum_of is not None else None
        if expected is None:
            unverifiable += 1
        elif crc32c(payload) != expected:
            corrupt.append(cid)
        else:
            verified += 1
    for cid in cids:                # indexed but never yielded: unreadable
        if cid not in walked:
            corrupt.append(cid)
            structural.append(f"chunk {cid}: unreadable (not in scrub walk)")
    corrupt.sort()

    # structural: dangling bases and base-chain cycles
    held = set(cids)
    dangling: set[int] = set()
    for cid, base in base_of.items():
        if base >= 0 and base not in held:
            dangling.add(cid)
            structural.append(f"chunk {cid}: dangling base {base}")
    depth_ok: set[int] = set()
    for cid in cids:
        seen: list[int] = []
        cur = cid
        while cur >= 0 and cur not in depth_ok:
            if cur in seen:
                structural.append(f"chunk {cid}: base-chain cycle at {cur}")
                dangling.add(cid)
                break
            seen.append(cur)
            cur = base_of.get(cur, -1)
        else:
            depth_ok.update(seen)

    # blast radius: corrupt/unreadable chunks plus every transitive
    # delta dependent (a fine patch on a rotten base cannot decode)
    lost = _dependents_closure(set(corrupt) | dangling, base_of)

    live = backend.live_handles()
    missing: set[int] = set()
    streams_lost: list[int] = []
    recipes: dict[int, list[int]] = {}
    for h in live:
        recipe = backend.recipe(h)
        recipes[h] = recipe
        absent = [c for c in recipe if c not in held]
        missing.update(absent)
        if absent or any(c in lost for c in recipe):
            streams_lost.append(h)

    blast: dict[int, int] = {}
    for cid in corrupt:
        reach = _dependents_closure({cid}, base_of)
        blast[cid] = sum(1 for h in live
                         if any(c in reach for c in recipes[h]))

    # refcount consistency: the in-memory table must match one rederived
    # from durable state (drift means deletes/compactions went unrecorded)
    from repro.api.refcount import RefcountTable
    refs = getattr(store, "_refs", None)
    if refs is not None:
        fresh = RefcountTable.rebuild(backend)
        pairs = (("chunks", len(fresh), len(refs)),
                 ("live_bytes", fresh.live_bytes, refs.live_bytes),
                 ("pinned_bytes", fresh.pinned_bytes, refs.pinned_bytes),
                 ("dead_bytes", fresh.dead_bytes, refs.dead_bytes))
        for name, want, got in pairs:
            if want != got:
                structural.append(f"refcount drift: {name} durable={want} "
                                  f"in-memory={got}")

    quarantined: list[int] = []
    retired: list[int] = []
    if repair and (lost or missing or streams_lost):
        for h in streams_lost:
            backend.retire_recipe(h)    # durable tombstone (§10.6/§11.4)
            retired.append(h)
            getattr(store, "_layouts", {}).pop(h, None)
        drop = sorted(c for c in lost if c in held)
        drop_chunks = getattr(backend, "drop_chunks", None)
        if drop_chunks is not None:
            drop_chunks(drop)           # durable quarantine entries
            quarantined.extend(drop)
        else:                           # third-party backend: tombstones
            structural.append(          # alone still silence the streams
                "backend has no drop_chunks; corrupt records retired but "
                "not quarantined")
        backend.flush()
        from repro.api.lifecycle import rebind_store_views
        rebind_store_views(store)

    seconds = time.perf_counter() - t0
    report = ScrubReport(
        chunks=len(cids), bytes_checked=bytes_checked, verified=verified,
        unverifiable=unverifiable, corrupt=tuple(corrupt),
        lost=tuple(sorted(lost)), missing=tuple(sorted(missing)),
        streams=len(live), streams_lost=tuple(streams_lost),
        blast_radius=blast, structural_errors=tuple(structural),
        repaired=bool(repair and (quarantined or retired)),
        quarantined=tuple(quarantined), retired_streams=tuple(retired),
        seconds=seconds, payload_requests=payload_requests,
        payload_requests_naive=payload_requests_naive)
    _observe_scrub(store, report)
    return report


def _observe_scrub(store: Any, report: ScrubReport) -> None:
    """Record the walk into the store's registry/tracer (§12.3):
    duration, per-outcome chunk counts, corrupt/quarantine totals.
    Tolerates stores without an Observability (test doubles)."""
    obs = getattr(store, "observe", None)
    if obs is None:
        return
    from repro.api import observe as om
    m = obs.metrics
    m.histogram("repro_scrub_seconds", "Scrub walk duration (§13.3)",
                bounds=om.SECONDS_BUCKETS).observe(report.seconds)
    for outcome, n in (("verified", report.verified),
                       ("unverifiable", report.unverifiable),
                       ("corrupt", len(report.corrupt))):
        m.counter("repro_scrub_chunks_total",
                  "Scrubbed chunks by checksum outcome (§13.3)",
                  labels={"outcome": outcome}).inc(n)
    if report.repaired:
        m.counter("repro_scrub_quarantined_total",
                  "Chunks durably quarantined by scrub repair").inc(
                      len(report.quarantined))
        m.counter("repro_scrub_retired_streams_total",
                  "Streams retired by scrub repair").inc(
                      len(report.retired_streams))
    tr = obs.tracer
    if tr is not None:
        tr.record("scrub", report.seconds, chunks=report.chunks,
                  payload_requests=report.payload_requests,
                  verified=report.verified,
                  unverifiable=report.unverifiable,
                  corrupt=len(report.corrupt), lost=len(report.lost),
                  streams_lost=len(report.streams_lost),
                  repaired=report.repaired)
