"""Concurrency primitives for the serving engine (DESIGN.md §10, §15).

Small, dependency-free pieces shared by the store, the backends, and the
multi-tenant server:

    RWLock          writer-preferring shared/exclusive lock. Restores take
                    the shared side (many can run at once), lifecycle
                    mutations (delete / compact — they swap the chunk index
                    and reopen file handles) take the exclusive side.
                    Writer preference keeps a steady stream of restores
                    from starving a pending compaction. Both acquire sides
                    take an optional ``timeout`` and raise ``LockTimeout``
                    when it elapses.
    IoTelemetry     per-thread I/O counters that also aggregate to
                    store-lifetime totals. Under concurrent restores a
                    global counter delta would attribute other threads'
                    bytes/seconds to this call's RestoreReport; per-thread
                    counters make every report exact with no locking on the
                    hot path (each thread only ever writes its own slot).
    deadline_scope  thread-local end-to-end request deadline (§15.3). The
                    serving layer opens a scope per request; lock waits and
                    the restore/commit hot loops consult it cooperatively
                    via ``remaining_time()`` / ``check_deadline()`` and
                    fail with ``DeadlineExceededError`` instead of running
                    (or blocking) past the budget.

Locking rules (also DESIGN.md §10.4): per-shard cache locks and the
backend's append lock are leaves — no code path acquires another lock
while holding one, so lock ordering is trivial and deadlock-free.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager


class LockTimeout(TimeoutError):
    """An RWLock acquire gave up after its ``timeout`` elapsed. The lock
    state is untouched (nothing to release); the wait is still reported
    through the lock's observer so a wedged writer shows up in the
    ``repro_lock_wait_seconds`` histogram instead of starving readers
    silently."""

    def __init__(self, side: str, timeout: float) -> None:
        super().__init__(
            f"RWLock {side} acquisition timed out after {timeout:.3f}s")
        self.side = side
        self.timeout = timeout


class DeadlineExceededError(TimeoutError):
    """A request ran past its end-to-end deadline (DESIGN.md §15.3).
    Raised by the cooperative ``check_deadline`` probes and by
    deadline-aware lock acquisition — always *between* atomic units of
    work (never mid-write), so the store is left consistent and the
    request slot is freed instead of hanging."""

    def __init__(self, op: str = "request",
                 budget: float | None = None) -> None:
        detail = "" if budget is None else f" (budget {budget:.3f}s)"
        super().__init__(f"{op} exceeded its deadline{detail}")
        self.op = op
        self.budget = budget


_DEADLINE_TL = threading.local()


@contextmanager
def deadline_scope(timeout: float | None):
    """Bound everything inside to ``timeout`` seconds from now. Nested
    scopes keep the *tighter* absolute deadline (an outer 100 ms budget is
    not widened by an inner default of 1 s). ``None`` is a no-op scope, so
    callers can pass an optional per-request timeout straight through.

    The deadline is thread-local: it rides the request's own thread
    through store/backend code with zero plumbing, and deliberately does
    NOT leak into backend worker pools (prefetch/fetcher threads) — the
    request thread is the one doing the cooperative checks, and an
    expired deadline must never poison another tenant's request that
    happens to reuse a pool thread."""
    if timeout is None:
        yield
        return
    prev = getattr(_DEADLINE_TL, "at", None)
    prev_budget = getattr(_DEADLINE_TL, "budget", None)
    at = time.monotonic() + timeout
    budget = float(timeout)
    if prev is not None and prev < at:
        at, budget = prev, prev_budget
    _DEADLINE_TL.at = at
    _DEADLINE_TL.budget = budget
    try:
        yield
    finally:
        _DEADLINE_TL.at = prev
        _DEADLINE_TL.budget = prev_budget


def current_deadline() -> float | None:
    """Absolute ``time.monotonic()`` deadline of the innermost active
    scope on this thread, or None when unbounded."""
    return getattr(_DEADLINE_TL, "at", None)


def remaining_time() -> float | None:
    """Seconds left in the active deadline scope (may be negative once
    expired); None when unbounded."""
    at = getattr(_DEADLINE_TL, "at", None)
    return None if at is None else at - time.monotonic()


def check_deadline(op: str = "request") -> None:
    """Cooperative probe: raise ``DeadlineExceededError`` if this
    thread's deadline scope has expired; free (one getattr) when no
    scope is active, so unbounded callers pay ~nothing."""
    at = getattr(_DEADLINE_TL, "at", None)
    if at is not None and time.monotonic() >= at:
        raise DeadlineExceededError(op, getattr(_DEADLINE_TL, "budget",
                                                None))


class RWLock:
    """Shared/exclusive lock, writer-preferring, not reentrant.

    ``read()`` / ``write()`` are context managers. A thread must not
    nest acquisitions (a reader re-entering while a writer waits would
    deadlock under writer preference); callers keep critical sections
    leaf-shaped instead.

    ``observer`` (optional) is called as ``observer(side, seconds)``
    after every successful acquire with ``side`` in ``("read",
    "write")`` and the time the acquire took — the lock-contention
    signal (DESIGN.md §12.2 feeds it into the
    ``repro_lock_wait_seconds`` histogram). It runs outside the
    internal condition and must not acquire this lock. Without an
    observer the acquire paths don't even read the clock.
    """

    def __init__(self, observer=None) -> None:
        self._cond = threading.Condition()
        self._readers = 0
        self._writer_active = False
        self._writers_waiting = 0
        self._observer = observer

    # explicit acquire/release pairs for hot paths (a generator-based
    # contextmanager costs ~4µs per cycle, which ranged reads notice);
    # the read()/write() context managers below wrap these for callers
    # off the hot path

    def acquire_read(self, timeout: float | None = None) -> None:
        obs = self._observer
        t0 = (time.perf_counter()
              if obs is not None or timeout is not None else 0.0)
        try:
            with self._cond:
                while self._writer_active or self._writers_waiting:
                    remaining = None
                    if timeout is not None:
                        remaining = timeout - (time.perf_counter() - t0)
                        if remaining <= 0:
                            raise LockTimeout("read", timeout)
                    self._cond.wait(remaining)
                self._readers += 1
        except LockTimeout:
            # failed waits still feed the contention histogram — a wedged
            # writer must be visible, not just survivable
            if obs is not None:
                obs("read", time.perf_counter() - t0)
            raise
        if obs is not None:
            obs("read", time.perf_counter() - t0)

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if not self._readers and self._writers_waiting:
                self._cond.notify_all()

    def acquire_write(self, timeout: float | None = None) -> None:
        obs = self._observer
        t0 = (time.perf_counter()
              if obs is not None or timeout is not None else 0.0)
        try:
            with self._cond:
                acquired = False
                self._writers_waiting += 1
                try:
                    while self._writer_active or self._readers:
                        remaining = None
                        if timeout is not None:
                            remaining = timeout - (time.perf_counter() - t0)
                            if remaining <= 0:
                                raise LockTimeout("write", timeout)
                        self._cond.wait(remaining)
                    acquired = True
                finally:
                    self._writers_waiting -= 1
                    # a timed-out writer may be the only thing holding
                    # readers back (writer preference): wake them
                    if not acquired and not self._writers_waiting:
                        self._cond.notify_all()
                self._writer_active = True
        except LockTimeout:
            if obs is not None:
                obs("write", time.perf_counter() - t0)
            raise
        if obs is not None:
            obs("write", time.perf_counter() - t0)

    def release_write(self) -> None:
        with self._cond:
            self._writer_active = False
            self._cond.notify_all()

    @contextmanager
    def read(self, timeout: float | None = None):
        self.acquire_read(timeout)
        try:
            yield
        finally:
            self.release_read()

    @contextmanager
    def write(self, timeout: float | None = None):
        self.acquire_write(timeout)
        try:
            yield
        finally:
            self.release_write()


#: Field order of an I/O counter snapshot — shared by the backends that
#: produce them and the store that turns deltas into RestoreReports.
#: ``requests`` counts physical payload reads (preads for the file
#: backend, ranged GETs for object stores) — the metric a latency-bound
#: remote backend is optimized on (DESIGN.md §11.3).
COUNTER_FIELDS = ("read_seconds", "decode_seconds", "bytes_read",
                  "cache_hits", "cache_misses", "prefetch_bytes",
                  "requests")


class _Counters:
    """One thread's I/O counters (a plain mutable record)."""

    __slots__ = COUNTER_FIELDS

    def __init__(self) -> None:
        self.read_seconds = 0.0
        self.decode_seconds = 0.0
        self.bytes_read = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.prefetch_bytes = 0
        self.requests = 0

    def snapshot(self) -> tuple:
        return (self.read_seconds, self.decode_seconds, self.bytes_read,
                self.cache_hits, self.cache_misses, self.prefetch_bytes,
                self.requests)


def zero_deltas() -> list:
    """A fresh all-zero counter accumulator (COUNTER_FIELDS order)."""
    return [0] * len(COUNTER_FIELDS)


def accumulate(acc: list, deltas) -> None:
    """``acc[i] += deltas[i]`` over COUNTER_FIELDS positions."""
    for i, d in enumerate(deltas):
        acc[i] += d


class _Fold:
    """Thread-local anchor: folds its thread's counter record into the
    telemetry's dead-thread aggregate when the thread exits (CPython
    tears down thread-local storage then, dropping the last reference).
    Without this a thread-per-request server would pin one record per
    thread it ever ran, growing memory and ``totals()`` cost forever."""

    __slots__ = ("_tel", "_c")

    def __init__(self, tel: "IoTelemetry", c: "_Counters") -> None:
        self._tel = tel
        self._c = c

    def __del__(self) -> None:
        try:
            self._tel._fold(self._c)
        except Exception:       # interpreter teardown: nothing to save
            pass


class IoTelemetry:
    """Per-thread counters + lock-free hot path + aggregated totals.

    ``local()`` returns this thread's counter record (created on first
    use; creation is the only locked operation). ``totals()`` sums the
    dead-thread aggregate plus every live thread's record — totals drift
    only by in-flight increments, which is the same guarantee global
    ``+=`` counters had. Exited threads' records are folded into the
    aggregate (see ``_Fold``), so lifetime cost is O(live threads).

    Pooled executors whose threads never exit must not rely on the
    ``_Fold``/GC path: call ``fold_current()`` (or wrap the task in
    ``scoped()``) when a task finishes, so lifetime totals are exact
    under thread reuse instead of trailing by whatever the pool's
    threads still hold.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._live: list[_Counters] = []
        self._dead = _Counters()
        self._tl = threading.local()

    def local(self) -> _Counters:
        c = getattr(self._tl, "c", None)
        if c is None:
            c = _Counters()
            with self._lock:
                self._live.append(c)
            self._tl.c = c
            self._tl.fold = _Fold(self, c)
        return c

    def _fold(self, c: _Counters) -> None:
        with self._lock:
            try:
                self._live.remove(c)
            except ValueError:
                return              # already folded
            accumulate_to = self._dead
            snap = c.snapshot()
            for field, value in zip(COUNTER_FIELDS, snap):
                setattr(accumulate_to, field,
                        getattr(accumulate_to, field) + value)

    def fold_current(self) -> None:
        """Fold the calling thread's counter record into the dead
        aggregate now and detach it, without waiting for thread exit.
        Idempotent with the ``_Fold`` destructor (``_fold`` ignores an
        already-folded record); the next ``local()`` call on this
        thread starts a fresh record."""
        c = getattr(self._tl, "c", None)
        if c is None:
            return
        self._tl.c = None
        self._tl.fold = None        # disarm the GC-timed fold first
        self._fold(c)

    @contextmanager
    def scoped(self):
        """Context manager form of the explicit-fold contract: yields
        this thread's counter record, folds it on exit. For executor
        tasks: ``with telemetry.scoped() as c: ...``."""
        try:
            yield self.local()
        finally:
            self.fold_current()

    def totals(self) -> tuple:
        # snapshot under the lock: a thread exiting between a locked row
        # copy and an unlocked read would _fold its counters into _dead
        # while the copied live record is still summed too, over-reporting
        # by that thread's whole lifetime
        acc = zero_deltas()
        with self._lock:
            accumulate(acc, self._dead.snapshot())
            for c in self._live:
                accumulate(acc, c.snapshot())
        return tuple(acc)

    def total(self, field: str) -> float | int:
        return self.totals()[COUNTER_FIELDS.index(field)]
