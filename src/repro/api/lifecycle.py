"""Space reclamation: deletion, mark-sweep collection, compaction (DESIGN.md §7).

The store is append-only until something here runs. Three operations,
each delegated to by ``DedupStore``:

    delete_stream(store, handle)   retire a recipe; decref its chunks in
                                   the refcount table (repro.api.refcount)
                                   — a chunk another stream's patch still
                                   decodes against stays *pinned*, never
                                   collected out from under the patch;
    collect(store)                 mark-sweep accounting pass: classify
                                   every tracked chunk live/pinned/dead,
                                   refresh StoreStats and the delta
                                   chain-depth histogram; mutates no data;
    compact(store)                 rewrite the container with only
                                   recipe-live records. Live delta chunks
                                   whose base is *not* kept (it died, or
                                   is pinned-only and being evicted) are
                                   **rebased**: re-encoded against their
                                   nearest surviving ancestor, or
                                   materialized to raw — whichever is
                                   smaller. Safe because a patch decodes
                                   against the base's *materialized*
                                   bytes, which compaction never changes.

Whether a delete triggers compaction automatically is a pluggable
``ReclamationPolicy`` chosen via ``DedupConfig`` (registry key
``policy``): "eager" compacts whenever reclaimable bytes exist,
"threshold" when the reclaimable fraction of the container crosses a
ratio, "never" (the default) leaves it to explicit ``compact()`` calls.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Protocol, runtime_checkable

from repro.api import containers
from repro.api.refcount import RefcountTable
from repro.api.registry import register_policy
from repro.api.types import StoreStats
from repro.core import delta


@dataclasses.dataclass(frozen=True)
class CollectReport:
    """One mark-sweep pass over the refcount table (no data mutated)."""

    live_chunks: int
    pinned_chunks: int
    dead_chunks: int
    live_bytes: int
    pinned_bytes: int
    dead_bytes: int
    chain_depth_hist: dict[int, int]

    @property
    def reclaimable_bytes(self) -> int:
        """Logical payload bytes a compaction pass would drop (before any
        growth from rebasing pinned bases into their dependents)."""
        return self.pinned_bytes + self.dead_bytes


@dataclasses.dataclass(frozen=True)
class CompactionRun:
    """What one container rewrite did; ``reclaimed_bytes`` is the measured
    backend footprint shrink (``storage_bytes`` before minus after).

    ``skipped=True`` means the sizing pass found the rewrite would grow
    the container (rebase materialization outweighing the sweepable
    bytes) and nothing was mutated — ``reclaimed_bytes`` is 0, never
    negative (regression-pinned in tests/test_lifecycle.py)."""

    epoch: int
    live_chunks: int
    swept_chunks: int
    swept_bytes: int            # logical payload bytes of dropped records
    rebased_delta: int          # live patches re-encoded onto a live ancestor
    rebased_raw: int            # live patches materialized to raw instead
    bytes_before: int
    bytes_after: int
    reclaimed_bytes: int
    seconds: float
    skipped: bool = False


@runtime_checkable
class ReclamationPolicy(Protocol):
    name: str

    def should_compact(self, stats: StoreStats) -> bool:
        """Consulted by the store after every delete; ``stats.dead_bytes``
        already includes pinned-only bytes (what compaction can free)."""
        ...


@register_policy("eager")
class EagerPolicy:
    """Compact after every delete that left anything reclaimable."""

    name = "eager"

    def should_compact(self, stats: StoreStats) -> bool:
        return stats.dead_bytes > 0


@register_policy("threshold")
class ThresholdPolicy:
    """Compact once reclaimable bytes exceed `ratio` of the container."""

    name = "threshold"

    def __init__(self, ratio: float = 0.25) -> None:
        if not 0.0 < ratio <= 1.0:
            raise ValueError(f"ratio must be in (0, 1], got {ratio}")
        self.ratio = ratio

    def should_compact(self, stats: StoreStats) -> bool:
        total = stats.live_bytes + stats.dead_bytes
        return total > 0 and stats.dead_bytes / total >= self.ratio


@register_policy("never")
class NeverPolicy:
    """Reclaim only on explicit ``compact()`` calls (the default)."""

    name = "never"

    def should_compact(self, stats: StoreStats) -> bool:
        return False


def _observe_gc(store: Any, phase: str, seconds: float,
                counters: dict[str, int] | None = None,
                **labels) -> None:
    """Record one reclamation phase into the store's registry/tracer
    (DESIGN.md §12.3). ``counters`` increments
    ``repro_gc_<name>_total`` series; tolerates stores without an
    Observability (lifecycle functions also run against test doubles)."""
    obs = getattr(store, "observe", None)
    if obs is None:
        return
    from repro.api import observe as om
    m = obs.metrics
    m.histogram("repro_gc_phase_seconds",
                "Reclamation phase timings (§7)", labels={"phase": phase},
                bounds=om.SECONDS_BUCKETS).observe(seconds)
    if counters:
        for name, value in counters.items():
            m.counter(f"repro_gc_{name}_total",
                      "Reclamation outcome totals (§7)").inc(value)
    tr = obs.tracer
    if tr is not None:
        tr.record("gc." + phase, seconds, **labels)


def rebind_store_views(store: Any) -> None:
    """Rederive the store's in-memory views from durable backend state
    after the record set changed shape underneath them (compaction here,
    scrub repair in ``repro.api.integrity``): rebuild the refcount table,
    drop digests of records no longer held (future ingests must not dedup
    against vanished payloads), and refresh the lifecycle stats. Ranged-
    restore prefix sums (``store._layouts``) survive — chunk lengths are
    invariant under rebasing, and repair pops the layouts of the streams
    it retires itself."""
    backend = store.backend
    store._refs = RefcountTable.rebuild(backend)
    store._by_digest = {d: c for d, c in store._by_digest.items()
                        if backend.contains(c)}
    store._refresh_lifecycle_stats()
    store._compact_skipped_at = None    # state changed; sizing is fresh


def delete_stream(store: Any, handle: int) -> int:
    """Retire stream `handle` and release its chunk references. Returns
    the logical bytes the delete made reclaimable (dead + newly pinned).
    The payloads stay on disk until a compaction; until then a new ingest
    may dedup against them, which revives them (refcount goes back up).
    Raises KeyError for an already-retired handle (IndexError for one the
    store never issued)."""
    t0 = time.perf_counter()
    refs: RefcountTable = store._refs
    recipe = store.backend.recipe(handle)
    store.backend.retire_recipe(handle)     # durable backends fsync the
    store.backend.flush()                   # tombstone themselves
    getattr(store, "_layouts", {}).pop(handle, None)   # ranged-read sums
    before = refs.dead_bytes + refs.pinned_bytes
    for cid in recipe:
        refs.decref_recipe(cid)
    freed = (refs.dead_bytes + refs.pinned_bytes) - before
    store._refresh_lifecycle_stats()
    if store.policy is not None and store.policy.should_compact(store.stats):
        # a previous compact() skipped at this reclaimable level: the
        # sizing pass (get + delta.encode over every rebase candidate)
        # would reach the same verdict, so don't re-pay it until more
        # bytes have actually become reclaimable
        skip_at = getattr(store, "_compact_skipped_at", None)
        if skip_at is None or refs.dead_bytes + refs.pinned_bytes > skip_at:
            compact(store)
    _observe_gc(store, "delete", time.perf_counter() - t0,
                counters={"freed_bytes": freed},
                handle=handle, freed_bytes=freed)
    return freed


def collect(store: Any) -> CollectReport:
    """Mark-sweep accounting: classify chunks, refresh lifecycle stats."""
    t0 = time.perf_counter()
    refs: RefcountTable = store._refs
    live = refs.live_cids()
    pinned = refs.pinned_cids()
    dead = refs.dead_cids()
    hist = refs.chain_depth_hist()
    report = CollectReport(
        live_chunks=len(live), pinned_chunks=len(pinned),
        dead_chunks=len(dead), live_bytes=refs.live_bytes,
        pinned_bytes=refs.pinned_bytes, dead_bytes=refs.dead_bytes,
        chain_depth_hist=hist)
    store._refresh_lifecycle_stats()
    store.stats.chain_depth_hist = dict(hist)
    _observe_gc(store, "collect", time.perf_counter() - t0,
                live_chunks=report.live_chunks,
                dead_chunks=report.dead_chunks,
                reclaimable_bytes=report.reclaimable_bytes)
    return report


def _placement_order(keep: set[int], rebases: dict[int, tuple],
                     base_of: Any, heat: dict[int, int]) -> list[int]:
    """Heat-aware placement for the compaction rewrite (DESIGN.md §14.4).

    Group the live set by post-rebase delta-chain root (a rebase changes
    a patch's base, so placement must follow where the chain will point
    *after* the rewrite, not where it points now), write whole chains
    contiguously, and order chains by aggregate read heat (hottest
    first, root cid breaking ties for determinism). Within a chain,
    members go base-before-dependent in cid order — the order a pointed
    restore walks them. Cold stores (no heat) keep the plain sorted
    order so the rewrite stays byte-stable across otherwise-identical
    compactions."""
    if not heat:
        return sorted(keep)
    chain_root: dict[int, int] = {}

    def root_of(cid: int) -> int:
        seen: list[int] = []
        cur = cid
        while cur in keep and cur not in chain_root:
            seen.append(cur)
            hit = rebases.get(cur)
            base = hit[1] if hit is not None else base_of(cur)
            if base < 0 or base not in keep:
                break
            cur = base
        root = chain_root.get(cur, cur if cur in keep else seen[-1])
        for c in seen:
            chain_root[c] = root
        return root

    chains: dict[int, list[int]] = {}
    for cid in sorted(keep):        # sorted -> base precedes dependents
        chains.setdefault(root_of(cid), []).append(cid)
    ranked = sorted(chains, key=lambda r: (-sum(heat.get(c, 0)
                                                for c in chains[r]), r))
    return [cid for r in ranked for cid in chains[r]]


def compact(store: Any) -> CompactionRun:
    """Rewrite the container without dead/pinned records, rebasing live
    patches whose base is evicted; see module docstring. Backends that
    track read heat (``chunk_heat``) get hot delta chains placed
    contiguously at the front of the rewritten container (§14.4), so the
    coalescer turns a hot pointed restore into few long reads."""
    t0 = time.perf_counter()
    refs: RefcountTable = store._refs
    backend = store.backend
    keep = set(refs.live_cids())
    swept = [cid for cid in refs.chunk_ids() if cid not in keep]
    swept_bytes = sum(refs.size_of(cid) for cid in swept)

    # sizing pass: decide every rebase up front so a rewrite that would
    # *grow* the container (patch materialization outweighing the
    # sweepable bytes — BENCH_GC once measured reclaimed_mb < 0) can be
    # skipped before anything is mutated. Only re-encoded patches are
    # held (re-encoding is the expensive part); raw materializations are
    # re-read from the backend when streamed, so the extra working set is
    # the patch bytes, not the decoded container.
    rebased = {"delta": 0, "raw": 0}
    rebases: dict[int, tuple[int, int, bytes | None]] = {}
    growth = 0
    for cid in sorted(keep):
        base = backend.base_of(cid)
        if base < 0 or base in keep:
            continue
        # nearest surviving ancestor: materialized content is invariant
        # under compaction, so old patch semantics carry
        anc = refs.base_of(base)
        while anc >= 0 and anc not in keep:
            anc = refs.base_of(anc)
        raw = backend.get(cid)
        patch = delta.encode(raw, backend.get(anc)) if anc >= 0 else None
        if patch is not None and len(patch) < len(raw):
            rebases[cid] = (containers._KIND_DELTA, anc, patch)
            rebased["delta"] += 1
            growth += len(patch) - backend.payload_size(cid)
        else:
            rebases[cid] = (containers._KIND_RAW, -1, None)  # fetch later
            rebased["raw"] += 1
            growth += len(raw) - backend.payload_size(cid)

    sizing_seconds = time.perf_counter() - t0

    if growth > 0 and growth >= swept_bytes:
        # rewriting would enlarge the container: leave it append-only
        # until enough dead bytes accumulate to pay for the rebases
        # (delete_stream consults the marker before re-running sizing)
        store._compact_skipped_at = refs.dead_bytes + refs.pinned_bytes
        size = backend.storage_bytes()
        seconds = time.perf_counter() - t0
        _observe_gc(store, "compact", seconds, skipped=True, growth=growth)
        _observe_gc(store, "compact.sizing", sizing_seconds)
        return CompactionRun(
            epoch=backend.epoch, live_chunks=len(keep), swept_chunks=0,
            swept_bytes=0, rebased_delta=0, rebased_raw=0,
            bytes_before=size, bytes_after=size, reclaimed_bytes=0,
            seconds=seconds, skipped=True)

    heat_fn = getattr(backend, "chunk_heat", None)
    order = _placement_order(keep, rebases, backend.base_of,
                             heat_fn() if heat_fn is not None else {})

    def live_records():
        # streamed, not a list: the backend consumes one record at a time,
        # so compaction RAM is one payload (plus the re-encoded patches),
        # not the whole live container
        for cid in order:
            hit = rebases.get(cid)
            if hit is None:
                kind, base, payload = backend.record(cid)
            else:
                kind, base, payload = hit
                if payload is None:     # raw materialization, re-read
                    payload = backend.get(cid)
            yield cid, kind, base, payload

    bytes_before = backend.storage_bytes()
    backend.rewrite_live(live_records())
    bytes_after = backend.storage_bytes()
    rebased_delta, rebased_raw = rebased["delta"], rebased["raw"]

    # the durable state changed shape: rederive the refcount view from it
    # and forget digests of swept payloads so future ingests cannot dedup
    # against chunks that no longer exist. Ranged-restore prefix sums
    # (store._layouts) deliberately survive: rebasing rewrites *patches*,
    # never materialized bytes, so every live recipe's chunk lengths —
    # and the lengths persisted next to the recipes — are invariant
    # under compaction (pinned by tests/test_restore.py).
    rebind_store_views(store)
    store.stats.reclaimed_bytes += bytes_before - bytes_after

    seconds = time.perf_counter() - t0
    reclaimed = bytes_before - bytes_after
    _observe_gc(store, "compact", seconds,
                counters={"reclaimed_bytes": reclaimed,
                          "swept_chunks": len(swept)},
                reclaimed_bytes=reclaimed, swept_chunks=len(swept),
                rebased_delta=rebased_delta, rebased_raw=rebased_raw)
    _observe_gc(store, "compact.sizing", sizing_seconds)
    _observe_gc(store, "compact.rewrite", seconds - sizing_seconds)

    return CompactionRun(
        epoch=backend.epoch, live_chunks=len(keep), swept_chunks=len(swept),
        swept_bytes=swept_bytes, rebased_delta=rebased_delta,
        rebased_raw=rebased_raw, bytes_before=bytes_before,
        bytes_after=bytes_after, reclaimed_bytes=bytes_before - bytes_after,
        seconds=time.perf_counter() - t0)
