"""Pluggable container backends (DESIGN.md §2.3, lifecycle in §7).

A ``ContainerBackend`` owns the three persistent artifacts of the store:
chunk payloads (raw bytes or a delta patch + base reference), and stream
recipes (the ordered chunk-id list that reconstructs a stream). All store
*policy* — exact dedup, resemblance detection, delta-vs-raw decision,
accounting, and when to reclaim — stays above the backend in
``repro.api.store`` / ``repro.api.lifecycle``; backends only move bytes.

    InMemoryBackend   dict-based, keeps materialized bytes per chunk (the
                      v0 DedupStore behaviour: O(1) base lookup during
                      delta encoding at the cost of RAM);
    FileBackend       append-only chunk log + recipe journal on disk.
                      Stores what is *logically* stored (patch bytes for
                      delta chunks), materializes on read by resolving the
                      base chain, and can be reopened on an existing
                      directory for restore (byte-identical; tested).

Reclamation hooks (DESIGN.md §7): recipes are *retired* (tombstoned, the
handle slot survives so later handles stay stable) rather than removed;
``rewrite_live`` atomically replaces the stored record set with the
compacted one. ``FileBackend`` stamps a monotonically increasing
**compaction epoch** in the chunk-log header and the recipe journal
header so a reopen can tell a compacted directory from an append-only
one; the two files are replaced by separate renames, so after a crash
mid-compaction the epochs may disagree by one — both intermediate states
are consistent (the new recipe set only drops retired streams, and the
old log is a record superset of the new one), and the reopen adopts the
larger epoch.
"""
from __future__ import annotations

import itertools
import json
import os
import struct
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Iterable, Protocol, Sequence, runtime_checkable

from repro.api.concurrency import IoTelemetry, check_deadline
from repro.api.faults import register_crashpoint
from repro.api.integrity import (CorruptChunkError, CorruptJournalError,
                                 crc32c)
from repro.api.registry import register_backend
from repro.api.restore import (DEFAULT_CACHE_BYTES, DEFAULT_CACHE_POLICY,
                               DEFAULT_CACHE_SHARDS, ShardedDecodeCache,
                               coalesce_reads, plan_chains)
from repro.core import delta

_REC_HEADER = struct.Struct("<BqqQ")    # v1: kind, cid, base, payload length
_REC_HEADER2 = struct.Struct("<BqqQI")  # v2 (§13.1): ... + payload crc32c
_KIND_RAW = 0
_KIND_DELTA = 1

# get_many read coalescing (DESIGN.md §9): payload extents whose gap is at
# most _READ_MERGE_GAP bytes (record headers, the odd dead record) are
# fetched as ONE sequential read; runs are capped so a single slab never
# dwarfs the decode-cache budget.
_READ_MERGE_GAP = 1 << 12
_READ_MAX_RUN = 8 << 20

# chunk-log file header: magic + compaction epoch. Logs written before the
# header existed start directly with a record whose first byte is a kind
# (0 or 1), never the magic's 'R', so both parse unambiguously. RCL2
# (§13.1) appends a crc32c to every record header; RCL1 logs still open
# (their records scrub as ``unverifiable``) and keep appending v1 records
# so one file never mixes record formats — the first compaction rewrites
# the whole log as RCL2.
_LOG_MAGIC = b"RCL1"
_LOG_MAGIC2 = b"RCL2"
_LOG_HEADER = struct.Struct("<4sQ")

# serving-engine knobs (DESIGN.md §10): fds in the pread reader pool (=
# max payload reads in flight) and how many coalesced read runs the
# fetcher keeps in flight ahead of the decode loop (0 disables readahead)
DEFAULT_READER_FDS = 4
DEFAULT_READAHEAD = 2

# FileBackend crashpoints (DESIGN.md §13.4): every write/fsync/rename
# boundary a kill can land on. Backends call them only when a
# FaultInjector was threaded in via ``faults=``; the harness in
# repro.api.faults enumerates this registry as its crash matrix.
_CP_PUT_WRITTEN = register_crashpoint(
    "file.put_many.written",
    "after a group commit's buffered log append, before flush")
_CP_RECIPE_APPENDED = register_crashpoint(
    "file.recipe.appended",
    "after a recipe journal line is written, before the commit flush")
_CP_RETIRE_BEFORE_FSYNC = register_crashpoint(
    "file.retire.before_fsync",
    "after a retire tombstone is written, before its fsync")
_CP_FLUSH_BEFORE_FSYNC = register_crashpoint(
    "file.flush.before_fsync",
    "after both file flushes, before the optional commit fsync")
_CP_COMPACT_TMPS = register_crashpoint(
    "file.compact.tmps_written",
    "both compaction tmp files written+fsynced, before any rename")
_CP_COMPACT_RECIPES_RENAMED = register_crashpoint(
    "file.compact.recipes_renamed",
    "recipes renamed into place, chunk log still the old one")
_CP_COMPACT_DONE = register_crashpoint(
    "file.compact.done",
    "both renames durable, before in-memory state swaps")


class _Flight:
    """One in-flight cold decode (DESIGN.md §14.2).

    The owning plan sets ``data`` (or flags ``error``) and fires the
    event exactly once, right after the decoded bytes land in the cache;
    waiting plans block on the event instead of re-reading and
    re-decoding the same chain. Waiters hold a direct reference, so the
    owner may drop the flight from the shared table the moment it
    resolves."""

    __slots__ = ("event", "data", "error")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.data: bytes | None = None
        self.error = False


class _ReaderPool:
    """A fixed set of O_RDONLY fds over one file, consumed via ``os.pread``.

    ``pread`` is positionless — it never touches the fd offset — so every
    fd is usable from any thread with no locking; the pool exists so the
    kernel can keep several reads genuinely in flight (each ``os.pread``
    releases the GIL for the duration of the syscall). Dispatch is
    round-robin; fds are interchangeable.
    """

    def __init__(self, path: str | Path, size: int) -> None:
        self._path = os.fspath(path)
        self.size = max(1, int(size))
        self._fds = [os.open(self._path, os.O_RDONLY)
                     for _ in range(self.size)]
        self._rr = itertools.count()

    def pread(self, offset: int, length: int) -> bytes:
        """Read up to ``length`` bytes at ``offset``; shorter only at EOF
        (callers treat a short result as a truncated record)."""
        if length <= 0:
            return b""
        fd = self._fds[next(self._rr) % len(self._fds)]
        data = os.pread(fd, length, offset)
        if len(data) == length or not data:
            return data
        parts = [data]
        got = len(data)
        while got < length:       # regular files only short-read at EOF,
            more = os.pread(fd, length - got, offset + got)   # but be safe
            if not more:
                break
            parts.append(more)
            got += len(more)
        return b"".join(parts)

    def reopen(self) -> None:
        """Swap every fd for a fresh open of the (possibly replaced-by-
        rename) path — the compaction hook. Callers guarantee no reads
        are in flight (the store's exclusive lifecycle lock)."""
        old, self._fds = self._fds, [os.open(self._path, os.O_RDONLY)
                                     for _ in range(self.size)]
        for fd in old:
            os.close(fd)

    def close(self) -> None:
        old, self._fds = self._fds, []
        for fd in old:
            os.close(fd)


@runtime_checkable
class ContainerBackend(Protocol):
    """Byte storage behind the dedup store; see module docstring."""

    # compaction epoch: starts at 0, bumped by every rewrite_live; the
    # lifecycle layer reports it and reopen logic persists it
    epoch: int

    # fixed per-record storage overhead in bytes (headers etc.); the store
    # adds it to bytes_stored so per-stream DCR matches the real container
    # footprint. 0 for backends that store payloads bare.
    record_overhead: int

    def put_raw(self, cid: int, data: bytes) -> None: ...

    def put_delta(self, cid: int, base: int, patch: bytes,
                  data: bytes | None = None) -> None:
        """Store chunk `cid` as a patch against `base`. `data` is the
        already-materialized raw bytes — backends MAY cache it but must
        not count on it (restore-after-reopen has only the patch)."""
        ...

    def put_many(self, records: Sequence[tuple[int, int, bytes,
                                               bytes | None]]) -> None:
        """Group-commit a stream's new chunks in one batched write
        (DESIGN.md §8). Each record is ``(cid, base, payload, data)``:
        ``base < 0`` stores ``payload`` as raw bytes, ``base >= 0``
        stores it as a patch with optional materialized ``data``.
        Records arrive in stream order, so any same-stream base precedes
        its dependents. Durable backends should turn the whole batch into
        one buffered append; the store issues a single ``flush()`` after
        the recipe."""
        ...

    def get(self, cid: int) -> bytes:
        """Materialized raw bytes of a chunk (delta chains resolved)."""
        ...

    def get_many(self, cids: Sequence[int]) -> list[bytes]:
        """Materialized bytes for each requested chunk, in request order
        (duplicates allowed). The batched read primitive of the restore
        planner (DESIGN.md §9): backends may plan the whole batch —
        shared base chains decoded once, payload reads sorted/coalesced
        by container offset — instead of resolving each chunk
        independently. The store falls back to per-chunk ``get`` for
        third-party backends that never implement this."""
        ...

    def contains(self, cid: int) -> bool: ...

    def max_chunk_id(self) -> int:
        """Largest chunk id ever stored, -1 when empty — a store opened on
        an existing backend seeds its id counter past this so new chunks
        never collide with (and silently shadow) persisted ones."""
        ...

    def chunk_ids(self) -> Iterable[int]: ...

    def base_of(self, cid: int) -> int:
        """Base chunk id a stored patch decodes against; -1 for raw."""
        ...

    def payload_size(self, cid: int) -> int:
        """Logically stored bytes (patch size for delta chunks)."""
        ...

    def record(self, cid: int) -> tuple[int, int, bytes]:
        """The stored record as (kind, base, payload) — the payload is the
        patch for delta chunks, not the materialized bytes."""
        ...

    def add_recipe(self, chunk_ids: Sequence[int],
                   lengths: Sequence[int] | None = None) -> int:
        """Persist a stream recipe; returns the stream handle.
        ``lengths`` are the materialized chunk lengths per recipe slot —
        persisted so ranged restores can prefix-sum a reopened stream
        without decoding it (DESIGN.md §9.3)."""
        ...

    def recipe(self, handle: int) -> list[int]: ...

    def recipe_lengths(self, handle: int) -> list[int] | None:
        """Materialized chunk lengths per recipe slot, or None when the
        recipe predates length recording (the store then derives them by
        materializing the chunks once). Same errors as ``recipe``."""
        ...

    def retire_recipe(self, handle: int) -> None:
        """Tombstone a stream recipe. The handle slot survives (later
        handles stay stable); `recipe(handle)` raises KeyError after."""
        ...

    def num_streams(self) -> int:
        """Total handles ever issued, retired slots included."""
        ...

    def live_handles(self) -> list[int]: ...

    def storage_bytes(self) -> int:
        """Current on-disk/in-core container footprint (what compaction
        shrinks); durable backends must flush before measuring."""
        ...

    def rewrite_live(self, records: Iterable[tuple[int, int, int, bytes]]) -> None:
        """Atomically replace the record set with `records` (cid, kind,
        base, payload — consumed once, so callers may stream a generator
        and backends must not hold all payloads at once) and drop
        retired-recipe tombstones, bumping the compaction epoch. Callers
        guarantee every base referenced by a delta record is itself in
        `records`."""
        ...

    def flush(self) -> None: ...

    def close(self) -> None: ...


class PlannedChainReader:
    """Shared read-side engine for record-log backends (DESIGN.md §9–§10).

    Durable backends — ``FileBackend`` here and ``ObjectStoreBackend``
    in ``repro.api.objectstore`` — keep an in-memory index
    ``cid -> (kind, base, offset, length)`` over an append-only payload
    address space and serve reads through identical machinery: the §9
    chain planner, a byte-budgeted sharded decode cache, span reads
    coalesced with a backend-tunable gap, and §10.3 double-buffered
    readahead. This base class holds all of it; subclasses provide the
    storage primitives

        _read_span(offset, length)   raw payload-space read (``pread``
                                     on the file log; a ranged GET for
                                     object stores, whose offsets are
                                     virtual — see objectstore.py). A
                                     short result means truncation.
        _flush_if_dirty()            make buffered appends readable
        _fetch_width()               span reads usefully in flight
        _read_desc()                 human name for error messages

    plus the attributes ``_index``, ``_cache``, ``_telemetry``,
    ``_recipes``, ``_recipe_lens``, ``_max_recipe_cid``, ``_readahead``,
    ``_merge_gap``, ``_max_run``, ``_executor`` and ``_ex_lock``. The
    write surface (puts, recipes, compaction, durability) stays with
    each backend — only byte *reading* is shared.
    """

    # observability binding (DESIGN.md §12.3), class-level defaults so a
    # backend is fully usable before (or without) a store binding it
    _obs = None
    _h_run_bytes = None
    _h_run_extents = None
    _c_corrupt = None

    # integrity defaults (§13): subclasses overwrite per instance —
    # ``_crcs`` maps cid -> persisted payload crc32c (absent for records
    # that predate checksums), ``_verify_reads`` turns on read-path
    # verification, ``_faults`` threads a FaultInjector through the
    # write-path crashpoints
    _crcs: dict[int, int] = {}
    _verify_reads = False
    _faults = None

    # cold-decode singleflight + heat defaults (§14.2, §14.4): real
    # per-instance state comes from _init_read_engine_state(); the
    # class-level Nones keep a subclass that never calls it working
    # (singleflight off, no heat signal)
    _flights = None                 # cid -> _Flight, shared across plans
    _sf_lock = None
    _singleflight = False
    _sf_waits = 0                   # plans that parked on a foreign flight
    _sf_collapsed = 0               # chunks served from a foreign flight
    _heat = None                    # cid -> lifetime request count
    # local-disk chunk tier (§14.3): remote backends install one; the
    # get_many read path consults/fills it generically
    _tier = None
    #: chunks materialized from stored payloads over the backend's
    #: lifetime (raw reads + delta decodes) — the singleflight race test
    #: pins "each base decoded exactly once" against this
    decoded_chunks = 0

    def _init_read_engine_state(self, singleflight: bool = True) -> None:
        """Per-instance singleflight/heat state; durable subclasses call
        this from ``__init__`` (the class attributes above must never be
        mutated — they would be shared across every backend)."""
        self._flights = {}
        self._sf_lock = threading.Lock()
        self._singleflight = bool(singleflight)
        self._sf_waits = 0
        self._sf_collapsed = 0
        self._heat = {}
        self.decoded_chunks = 0

    def chunk_heat(self) -> dict[int, int]:
        """Lifetime request count per chunk id (targets of ``get`` /
        ``get_many``; §14.4). Compaction placement consumes this to lay
        hot chains contiguously. Snapshot copy — safe to iterate while
        restores proceed."""
        heat = self._heat
        if heat is None:
            return {}
        with self._sf_lock:
            return dict(heat)

    def _bump_heat(self, cids) -> None:
        heat = self._heat
        if heat is not None:
            with self._sf_lock:
                for cid in cids:
                    heat[cid] = heat.get(cid, 0) + 1

    def _count_decodes(self, n: int) -> None:
        if n:
            lock = self._sf_lock
            if lock is not None:
                with lock:
                    self.decoded_chunks += n
            else:
                self.decoded_chunks += n

    def _cp(self, point: str) -> None:
        faults = self._faults
        if faults is not None:
            faults.crashpoint(point)

    def checksum_of(self, cid: int) -> int | None:
        """Persisted crc32c of the stored payload, or None when the
        record predates checksums (scrub reports it unverifiable)."""
        if cid not in self._index:
            raise KeyError(cid)
        return self._crcs.get(cid)

    def _check_payload(self, cid: int, payload: bytes) -> None:
        """Raise ``CorruptChunkError`` when a payload read off the
        container does not match its persisted checksum; records without
        one pass (there is nothing to verify them against)."""
        expected = self._crcs.get(cid)
        if expected is None:
            return
        actual = crc32c(payload)
        if actual != expected:
            if self._c_corrupt is not None:
                self._c_corrupt.inc()
            raise CorruptChunkError(cid, self._read_desc(), expected, actual)

    def bind_observability(self, obs) -> None:
        """Attach a store's ``Observability`` (DESIGN.md §12): coalesced
        read-run shapes are recorded natively, and the reader's existing
        lifetime counters — ``IoTelemetry`` totals and the decode-cache
        tallies — are re-exported as snapshot-time derived views, never
        double-counted."""
        from repro.api import observe as om
        self._obs = obs
        m = obs.metrics
        self._h_run_bytes = m.histogram(
            "repro_reader_run_bytes",
            "Coalesced payload read-run width (one pread / ranged GET; "
            "§9.1, §11.3)", bounds=om.BYTES_BUCKETS)
        self._h_run_extents = m.histogram(
            "repro_reader_run_extents",
            "Records served by one coalesced read run",
            bounds=om.COUNT_BUCKETS)
        self._c_corrupt = m.counter(
            "repro_corrupt_chunks_total",
            "Payload checksum failures on the verified read path (§13.2)")
        tel, cache = self._telemetry, self._cache
        c_seconds = {p: m.counter("repro_reader_io_seconds_total",
                                  "Lifetime read vs. decode time",
                                  labels={"phase": p})
                     for p in ("read", "decode")}
        c_bytes = {d: m.counter("repro_reader_bytes_total",
                                "Payload bytes read / readahead-prefetched",
                                labels={"dir": d})
                   for d in ("read", "prefetch")}
        c_requests = m.counter("repro_reader_requests_total",
                               "Physical payload reads issued")
        c_cache = {k: m.counter("repro_reader_cache_lookups_total",
                                "Decode-cache probe outcomes (§9.2)",
                                labels={"outcome": k})
                   for k in ("hit", "miss")}
        g_cache = {k: m.gauge("repro_reader_cache_bytes",
                              "Decode-cache residency", labels={"kind": k})
                   for k in ("current", "peak")}
        c_ghost = m.counter(
            "repro_cache_ghost_hits_total",
            "Misses on recently-evicted chunks (the scan-resistance "
            "adaptation signal; §14.1)")
        c_evict = m.counter(
            "repro_cache_evictions_total",
            "Decode-cache evictions across every shard (§14.1)")
        c_sf = {e: m.counter(
                    "repro_singleflight_total",
                    "Cold-decode singleflight outcomes: plans parked on "
                    "a foreign in-flight decode / chunks served from one "
                    "(§14.2)", labels={"event": e})
                for e in ("wait", "collapsed")}

        def _export_reader_views() -> None:
            t = tel.totals()    # COUNTER_FIELDS order
            c_seconds["read"].set_total(t[0])
            c_seconds["decode"].set_total(t[1])
            c_bytes["read"].set_total(t[2])
            c_cache["hit"].set_total(t[3])
            c_cache["miss"].set_total(t[4])
            c_bytes["prefetch"].set_total(t[5])
            c_requests.set_total(t[6])
            g_cache["current"].set(cache.bytes)
            g_cache["peak"].set(cache.peak_bytes)
            c_ghost.set_total(getattr(cache, "ghost_hits", 0))
            c_evict.set_total(getattr(cache, "evictions", 0))
            c_sf["wait"].set_total(self._sf_waits)
            c_sf["collapsed"].set_total(self._sf_collapsed)

        m.register_callback(_export_reader_views)

    def fold_io_counters(self) -> None:
        """Fold the calling thread's telemetry record into the lifetime
        aggregate (the pooled-executor contract —
        ``IoTelemetry.fold_current``)."""
        self._telemetry.fold_current()

    # --- lifetime I/O totals (telemetry properties, DESIGN.md §9.4) ----------

    @property
    def read_seconds(self) -> float:
        return self._telemetry.total("read_seconds")

    @property
    def decode_seconds(self) -> float:
        return self._telemetry.total("decode_seconds")

    @property
    def bytes_read(self) -> int:
        return self._telemetry.total("bytes_read")

    @property
    def prefetch_bytes(self) -> int:
        return self._telemetry.total("prefetch_bytes")

    @property
    def read_requests(self) -> int:
        """Physical payload reads issued over the backend's lifetime
        (preads / ranged GETs, one per coalesced span; §11.3)."""
        return self._telemetry.total("requests")

    def io_counters(self) -> tuple:
        """This thread's I/O counter snapshot, in
        ``repro.api.concurrency.COUNTER_FIELDS`` order. The store diffs
        two snapshots around a restore for an exact per-call
        RestoreReport even while other threads restore concurrently."""
        return self._telemetry.local().snapshot()

    @property
    def cache_hits(self) -> int:
        return self._cache.hits

    @property
    def cache_misses(self) -> int:
        return self._cache.misses

    @property
    def cache_bytes(self) -> int:
        return self._cache.bytes

    @property
    def cache_peak_bytes(self) -> int:
        return self._cache.peak_bytes

    # --- reading ------------------------------------------------------------

    def _read_payload(self, offset: int, length: int) -> bytes:
        self._flush_if_dirty()
        tel = self._telemetry.local()
        tel.requests += 1
        data = self._read_span(offset, length)
        # count what actually came back, not what was asked for — and a
        # short read here is a truncated record (external truncation or
        # torn tail past the scan), which must fail loudly instead of
        # handing a short payload to delta.decode
        tel.bytes_read += len(data)
        if len(data) != length:
            raise IOError(
                f"truncated record: wanted {length} bytes at offset "
                f"{offset} of {self._read_desc()}, got {len(data)}")
        return data

    def get(self, cid: int) -> bytes:
        tel = self._telemetry.local()
        self._bump_heat((cid,))
        data = self._cache.get(cid)
        if data is not None:
            tel.cache_hits += 1
            return data
        tel.cache_misses += 1
        # walk the base chain down to a raw/cached ancestor, then apply
        # patches back up (iterative: delta chains can outgrow recursion).
        # Correctness never depends on cache retention: `data` is a local
        # strong reference, so a budget-pressed cache may evict behind us.
        # The walk seeds from the miss above — only *bases* are probed
        # inside the loop, so each chain node costs exactly one counted
        # cache lookup (re-probing `cid` would double-count the miss in
        # the §9.4 telemetry).
        chain: list[tuple[int, bytes]] = []
        verify = self._verify_reads
        tier = self._tier
        decoded = 0
        cur = cid
        while True:
            check_deadline("restore")   # per chain node: nothing held yet
            kind, base, offset, length = self._index[cur]  # KeyError
            payload = (tier.get(cur, self._crcs.get(cur))
                       if tier is not None else None)
            if payload is None:
                payload = self._read_payload(offset, length)   # before I/O
                if tier is not None:
                    tier.put(cur, payload, self._crcs.get(cur))
            if verify:
                self._check_payload(cur, payload)
            if kind == _KIND_RAW:
                data = payload
                decoded += 1
                self._cache.put(cur, data)
                break
            chain.append((cur, payload))
            cur = base
            data = self._cache.get(cur)
            if data is not None:
                tel.cache_hits += 1
                break
            tel.cache_misses += 1
        for c, patch in reversed(chain):
            data = delta.decode(patch, data)
            decoded += 1
            self._cache.put(c, data)
        self._count_decodes(decoded)
        return data

    def _reader_executor(self) -> ThreadPoolExecutor:
        ex = self._executor
        if ex is None:
            with self._ex_lock:
                if self._executor is None:
                    self._executor = ThreadPoolExecutor(
                        max_workers=self._fetch_width(),
                        thread_name_prefix="repro-readahead")
                ex = self._executor
        return ex

    def get_many(self, cids: Sequence[int]) -> list[bytes]:
        """Planned batch materialization (DESIGN.md §9, concurrent +
        double-buffered per §10): every requested chunk's base chain is
        decoded exactly once, payload reads are issued in ascending
        address order with adjacent records coalesced into sequential
        runs, and — when more than one run is scheduled — a background
        fetcher keeps up to ``readahead`` runs in flight while the
        decode loop chews the runs already fetched. Bases stay pinned in
        the decode cache only while a dependent patch of this plan still
        needs them. Safe to call from any number of threads: plans pin
        atomically (``try_pin``), so a concurrent plan's eviction
        pressure cannot invalidate this plan between planning and
        decoding."""
        if not cids:
            return []
        check_deadline("restore")
        cache = self._cache
        tel = self._telemetry.local()
        targets = list(dict.fromkeys(int(c) for c in cids))
        self._bump_heat(targets)
        # batched cache probe: one lock round-trip per shard, not per
        # chunk — this IS the warm restore (every target a hit)
        out = cache.get_present(targets)
        missing = [cid for cid in targets if cid not in out]
        tel.cache_hits += len(out)
        tel.cache_misses += len(missing)
        if missing:
            index = self._index
            for cid in missing:     # unknown cids: KeyError before any I/O
                index[cid]

            def entry(cid: int) -> tuple[int, int, int]:
                kind, base, offset, length = index[cid]
                return (base if kind == _KIND_DELTA else -1, offset, length)

            pinned: set[int] = set()
            pinned_data: dict[int, bytes] = {}
            use_sf = self._singleflight and self._flights is not None
            sf_lock = self._sf_lock
            flights = self._flights
            flights_won: dict[int, _Flight] = {}   # cids this plan decodes
            flights_wait: dict[int, _Flight] = {}  # foreign decodes parked on
            owned_unresolved: set[int] = set()

            def probe(cid: int) -> bool:
                # the planner's is_cached callback, made concurrency-safe:
                # pin-if-present is one atomic step, so another thread's
                # eviction cannot undo the answer between planning and
                # decoding (§10.2). At most one pin per cid per plan.
                if cid in pinned_data or cid in flights_wait:
                    return True
                data = cache.try_pin(cid)
                if data is not None:
                    pinned.add(cid)
                    pinned_data[cid] = data
                    return True
                if not use_sf:
                    return False
                # cold-decode singleflight (§14.2): claim the cid when
                # nobody is decoding it — this plan becomes the owner
                # and schedules the read — else park on the owner's
                # flight: the planner treats a foreign flight like a
                # cached chunk, so the chain walk stops here and this
                # plan never re-reads or re-decodes the shared suffix.
                with sf_lock:
                    fl = flights.get(cid)
                    if fl is None:
                        fl = _Flight()
                        flights[cid] = fl
                        flights_won[cid] = fl
                        owned_unresolved.add(cid)
                        return False
                    self._sf_waits += 1
                flights_wait[cid] = fl
                return True

            def resolve_flight(cid: int, data: bytes) -> None:
                # the decoded bytes are already in the cache; publish to
                # waiters and drop the table entry so later plans probe
                # the cache instead of a dead flight
                fl = flights_won.get(cid)
                if fl is None:
                    return
                fl.data = data
                fl.event.set()
                owned_unresolved.discard(cid)
                with sf_lock:
                    flights.pop(cid, None)

            def await_flight(fl) -> bytes | None:
                # §14.2 deadlock rule: a plan may block on a foreign
                # flight only while it owns no unresolved flight of its
                # own — two plans interleaved along one physical chain
                # could otherwise wait on each other forever. Owners
                # fall back to self.get instead (a rare duplicate
                # decode beats a deadlock).
                if not fl.event.is_set():
                    if owned_unresolved:
                        return None
                    fl.event.wait()
                return None if fl.error else fl.data

            try:
                plan = plan_chains(missing, entry, probe)
                wanted = set(plan.targets)

                payloads: dict[int, bytes] = {}
                verify = self._verify_reads
                tier = self._tier
                crcs = self._crcs
                reads = plan.reads
                if tier is not None and reads:
                    # disk-tier filter (§14.3): serve whatever the local
                    # tier holds (crc-verified inside), fetch the rest
                    # remotely. Tier bytes are local and free of the
                    # remote hop, so they stay out of bytes_read.
                    reads = []
                    for off, ln, cid in plan.reads:
                        payload = tier.get(cid, crcs.get(cid))
                        if payload is None:
                            reads.append((off, ln, cid))
                        else:
                            if verify:  # §13.2 contract holds tier or not
                                self._check_payload(cid, payload)
                            payloads[cid] = payload

                # coalesce the offset-sorted reads into sequential runs
                # (gap/cap are backend knobs — MB-scale for object
                # stores, KB-scale for the local log; §9.1, §11.3)
                runs = coalesce_reads(reads, self._merge_gap,
                                      self._max_run)
                h_run = self._h_run_bytes
                if h_run is not None:       # §12.3: run shapes, natively
                    h_ext = self._h_run_extents
                    for start, end, extents in runs:
                        h_run.observe(end - start)
                        h_ext.observe(len(extents))

                remaining = dict(plan.dependents)
                order = plan.decode_order
                decode_pos = 0

                def ingest_run(run: tuple, blob: bytes) -> None:
                    start, end, extents = run
                    tel.bytes_read += len(blob)
                    if len(blob) != end - start:    # truncated record(s)
                        raise IOError(
                            f"truncated record run: wanted {end - start} "
                            f"bytes at offset {start} of "
                            f"{self._read_desc()}, got {len(blob)}")
                    view = memoryview(blob)
                    for off, ln, cid in extents:
                        payload = bytes(view[off - start:off - start + ln])
                        if verify:      # per-chunk, coalesced span or not
                            self._check_payload(cid, payload)
                        payloads[cid] = payload
                        if tier is not None:
                            # crc-verified-on-fill (§14.3): put() drops
                            # fills that do not match the journaled crc
                            tier.put(cid, payload, crcs.get(cid))

                def decode_ready() -> None:
                    # decode the available prefix of the topological
                    # order; stops at the first chunk whose payload is
                    # still in flight (a later run)
                    nonlocal decode_pos
                    t0 = time.perf_counter()
                    decoded = 0
                    while decode_pos < len(order):
                        cid = order[decode_pos]
                        payload = payloads.pop(cid, None)
                        if payload is None:
                            break
                        decode_pos += 1
                        kind, base, _, _ = index[cid]
                        if kind == _KIND_RAW:
                            data = payload
                        else:
                            # plan-local refs first, then an uncounted
                            # peek: the base is pinned by this very plan,
                            # and counting it as a cache hit would
                            # inflate the telemetry on every cold chain
                            base_data = pinned_data.get(base)
                            if base_data is None:
                                base_data = cache.peek(base)
                            if base_data is None and flights_wait:
                                fl = flights_wait.get(base)
                                if fl is not None:
                                    base_data = await_flight(fl)
                                    if base_data is not None:
                                        with sf_lock:
                                            self._sf_collapsed += 1
                            if base_data is None:  # flight failed/deferred
                                base_data = self.get(base)
                            data = delta.decode(payload, base_data)
                            left = remaining.get(base)
                            if left is not None:
                                if left > 1:
                                    remaining[base] = left - 1
                                else:
                                    del remaining[base]
                                    # flight-waited bases were never
                                    # pinned by this plan — unpinning
                                    # them would steal the owner's pin
                                    if base in pinned:
                                        cache.unpin(base)
                                        pinned.discard(base)
                        decoded += 1
                        pin = cid in remaining
                        cache.put(cid, data, pin=pin)
                        if pin:
                            pinned.add(cid)
                        if flights_won:
                            resolve_flight(cid, data)
                        if cid in wanted:
                            out[cid] = data
                    tel.decode_seconds += time.perf_counter() - t0
                    self._count_decodes(decoded)

                self._flush_if_dirty()
                read_span = self._read_span

                def read_run(run: tuple) -> tuple[bytes, float]:
                    t0 = time.perf_counter()
                    blob = read_span(run[0], run[1] - run[0])
                    return blob, time.perf_counter() - t0

                if self._readahead > 0 and len(runs) > 1:
                    # double-buffered fetch (§10.3): the read of runs
                    # k+1..k+readahead overlaps the decode of run k
                    ex = self._reader_executor()
                    pending: deque = deque()
                    ri = 0
                    try:
                        while ri < len(runs) or pending:
                            # cooperative deadline probe (§15.3): raised
                            # here — at a run boundary — the error flows
                            # through the finally blocks below, which
                            # cancel in-flight reads, error-resolve owned
                            # flights, and unpin plan bases, so an
                            # over-deadline restore sheds cleanly
                            check_deadline("restore")
                            while (ri < len(runs)
                                   and len(pending) <= self._readahead):
                                pending.append((runs[ri],
                                                ex.submit(read_run,
                                                          runs[ri])))
                                ri += 1
                            run, fut = pending.popleft()
                            overlapped = fut.done() and run is not runs[0]
                            blob, secs = fut.result()
                            tel.read_seconds += secs
                            tel.requests += 1
                            if overlapped:  # fully hidden behind decode
                                tel.prefetch_bytes += len(blob)
                            ingest_run(run, blob)
                            decode_ready()
                    finally:
                        # an aborted plan (truncated record, corrupt
                        # patch) must not leave span reads in flight: a
                        # later compaction swaps the read substrate
                        # (_pool.reopen() / index flip) under the
                        # documented no-reads-in-flight precondition.
                        # Cancel what hasn't started and drain what has;
                        # no-op on the success path.
                        while pending:
                            _, fut = pending.popleft()
                            if not fut.cancel():
                                try:
                                    fut.result()
                                except Exception:
                                    pass
                else:                       # serial: one run, or disabled
                    for run in runs:
                        check_deadline("restore")   # same shed boundary
                        blob, secs = read_run(run)
                        tel.read_seconds += secs
                        tel.requests += 1
                        ingest_run(run, blob)
                    decode_ready()
                if decode_pos != len(order):    # every payload arrived,
                    decode_ready()              # so this always finishes
                if decode_pos != len(order):
                    raise RuntimeError(
                        f"restore plan incomplete: decoded {decode_pos} "
                        f"of {len(order)} chunks")

                # a target can become cached (by a concurrent restore)
                # between the fast-path miss and the planner probe; the
                # probe pinned it — or parked on the plan actually
                # decoding it — so serve it from the plan's own refs.
                # get_present already counted every one of these as a
                # miss, so the tally is corrected once the real outcome
                # is known (§14.2 hit-ratio fix): a flight-served target
                # was a concurrent decode (a hit for the report), and a
                # self.get fallback re-counts the lookup itself.
                for tgt in plan.targets:
                    if tgt in out:
                        continue
                    data = pinned_data.get(tgt)
                    if data is None and flights_wait:
                        fl = flights_wait.get(tgt)
                        if fl is not None:
                            data = await_flight(fl)
                            if data is not None:
                                with sf_lock:
                                    self._sf_collapsed += 1
                                tel.cache_misses -= 1
                                tel.cache_hits += 1
                    if data is None:
                        tel.cache_misses -= 1   # self.get counts its own
                        data = self.get(tgt)
                    out[tgt] = data
            finally:
                # a failed plan must not leave its claimed flights
                # unresolved — waiters would park forever. On success
                # every owned flight resolved during decode; anything
                # still pending here is flagged as an error and waiters
                # fall back to their own self.get.
                if flights_won:
                    with sf_lock:
                        for cid, fl in flights_won.items():
                            if not fl.event.is_set():
                                fl.error = True
                                fl.event.set()
                                flights.pop(cid, None)
                # a failed plan (corrupt patch, truncated read) must not
                # leak pins — leaked entries would be unevictable forever
                for cid in pinned:
                    cache.unpin(cid)
                pinned.clear()
        return [out[int(c)] for c in cids]

    # --- index / recipe read surface ----------------------------------------

    def contains(self, cid: int) -> bool:
        return cid in self._index

    def max_chunk_id(self) -> int:
        # covers cids named by recipe lines too (retired included): a
        # torn-tail recovery drops chunks from the index but their recipe
        # line survives in the journal, and reissuing those ids would
        # alias new content under an old recipe's cids (§10.6)
        return max(max(self._index, default=-1), self._max_recipe_cid)

    def chunk_ids(self) -> list[int]:
        return list(self._index)

    def base_of(self, cid: int) -> int:
        kind, base, _, _ = self._index[cid]
        return base if kind == _KIND_DELTA else -1

    def payload_size(self, cid: int) -> int:
        return self._index[cid][3]

    def record(self, cid: int) -> tuple[int, int, bytes]:
        kind, base, offset, length = self._index[cid]
        payload = self._read_payload(offset, length)
        if self._verify_reads:
            self._check_payload(cid, payload)
        return (kind, base if kind == _KIND_DELTA else -1, payload)

    def recipe(self, handle: int) -> list[int]:
        if not 0 <= handle < len(self._recipes):    # no negative aliasing
            raise IndexError(f"unknown stream handle {handle}")
        recipe = self._recipes[handle]
        if recipe is None:
            raise KeyError(f"stream {handle} retired")
        return recipe

    def recipe_lengths(self, handle: int) -> list[int] | None:
        self.recipe(handle)                 # raises on unknown/retired
        return self._recipe_lens.get(handle)

    def num_streams(self) -> int:
        return len(self._recipes)

    def live_handles(self) -> list[int]:
        return [h for h, r in enumerate(self._recipes) if r is not None]


@register_backend("memory")
class InMemoryBackend:
    """Everything in dicts; materialized bytes kept for every chunk."""

    name = "memory"
    record_overhead = 0     # payloads stored bare in dicts

    def __init__(self) -> None:
        self._kind: dict[int, tuple] = {}   # cid -> (RAW,) | (DELTA, base, patch)
        self._data: dict[int, bytes] = {}   # cid -> materialized bytes
        self._crcs: dict[int, int] = {}     # cid -> crc32c of stored payload
        self._recipes: list[list[int] | None] = []
        self._recipe_lens: dict[int, list[int]] = {}
        self.epoch = 0

    def put_raw(self, cid: int, data: bytes) -> None:
        self._kind[cid] = (_KIND_RAW,)
        self._data[cid] = data
        self._crcs[cid] = crc32c(data)

    def put_delta(self, cid: int, base: int, patch: bytes,
                  data: bytes | None = None) -> None:
        self._kind[cid] = (_KIND_DELTA, base, patch)
        self._crcs[cid] = crc32c(patch)
        if data is None:
            data = delta.decode(patch, self.get(base))
        self._data[cid] = data

    def put_many(self, records: Sequence[tuple[int, int, bytes,
                                               bytes | None]]) -> None:
        # dict stores have no batching win; delegate so subclasses that
        # override put_raw/put_delta (tests do) keep their behaviour
        for cid, base, payload, data in records:
            if base < 0:
                self.put_raw(cid, payload)
            else:
                self.put_delta(cid, base, payload, data=data)

    def get(self, cid: int) -> bytes:
        return self._data[cid]

    def get_many(self, cids: Sequence[int]) -> list[bytes]:
        # materialized bytes are already held per chunk; no planning win
        return [self._data[c] for c in cids]

    def contains(self, cid: int) -> bool:
        return cid in self._kind

    def max_chunk_id(self) -> int:
        return max(self._kind, default=-1)

    def chunk_ids(self) -> list[int]:
        return list(self._kind)

    def base_of(self, cid: int) -> int:
        rec = self._kind[cid]
        return rec[1] if rec[0] == _KIND_DELTA else -1

    def payload_size(self, cid: int) -> int:
        rec = self._kind[cid]
        return len(rec[2]) if rec[0] == _KIND_DELTA else len(self._data[cid])

    def record(self, cid: int) -> tuple[int, int, bytes]:
        rec = self._kind[cid]
        if rec[0] == _KIND_DELTA:
            return (_KIND_DELTA, rec[1], rec[2])
        return (_KIND_RAW, -1, self._data[cid])

    def checksum_of(self, cid: int) -> int | None:
        if cid not in self._kind:
            raise KeyError(cid)
        return self._crcs.get(cid)

    def drop_chunks(self, cids: Sequence[int]) -> None:
        """Quarantine: forget ``cids`` entirely (scrub --repair, §13.3).
        Callers guarantee no live recipe references them."""
        for cid in cids:
            self._kind.pop(int(cid), None)
            self._data.pop(int(cid), None)
            self._crcs.pop(int(cid), None)

    def add_recipe(self, chunk_ids: Sequence[int],
                   lengths: Sequence[int] | None = None) -> int:
        self._recipes.append([int(c) for c in chunk_ids])
        handle = len(self._recipes) - 1
        if lengths is not None:
            self._recipe_lens[handle] = [int(n) for n in lengths]
        return handle

    def recipe(self, handle: int) -> list[int]:
        # no negative aliasing: delete(-1) must never retire the newest
        if not 0 <= handle < len(self._recipes):
            raise IndexError(f"unknown stream handle {handle}")
        recipe = self._recipes[handle]
        if recipe is None:
            raise KeyError(f"stream {handle} retired")
        return recipe

    def recipe_lengths(self, handle: int) -> list[int] | None:
        self.recipe(handle)                 # raises on unknown/retired
        return self._recipe_lens.get(handle)

    def retire_recipe(self, handle: int) -> None:
        self.recipe(handle)                 # raises on unknown/retired
        self._recipes[handle] = None
        self._recipe_lens.pop(handle, None)

    def num_streams(self) -> int:
        return len(self._recipes)

    def live_handles(self) -> list[int]:
        return [h for h, r in enumerate(self._recipes) if r is not None]

    def storage_bytes(self) -> int:
        return sum(self.payload_size(cid) for cid in self._kind)

    def rewrite_live(self, records: Iterable[tuple[int, int, int, bytes]]) -> None:
        kept_data: dict[int, bytes] = {}
        kept_kind: dict[int, tuple] = {}
        kept_crcs: dict[int, int] = {}
        for cid, kind, base, payload in records:
            if kind == _KIND_DELTA:
                kept_kind[cid] = (_KIND_DELTA, base, payload)
            else:
                kept_kind[cid] = (_KIND_RAW,)
            kept_crcs[cid] = crc32c(payload)
            # materialized content is invariant under compaction
            kept_data[cid] = self._data[cid]
        self._kind = kept_kind
        self._data = kept_data
        self._crcs = kept_crcs
        self.epoch += 1

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


@register_backend("file")
class FileBackend(PlannedChainReader):
    """Append-only on-disk containers.

    Layout under `path`:
        chunks.log     [RCL2 epoch] then [kind cid base len crc32c]
                       [payload] records, appended (RCL1 / pre-magic
                       logs have no crc field and still open; §13.1)
        recipes.jsonl  {"epoch": N} header line, then one line per handle
                       slot: {"recipe": ids, "lens": lengths} (live
                       recipe with materialized chunk lengths for ranged
                       restores), a bare JSON array (live recipe written
                       before lengths existed), ``null`` (slot retired
                       before the last compaction), {"retire": h}
                       (tombstone appended by a delete), or
                       {"quarantine": [cids]} (scrub --repair drop,
                       §13.3)

    An index {cid -> (kind, base, offset, length)} is rebuilt by scanning
    the log on open, so a fresh FileBackend on an existing directory can
    serve restores immediately. Materialized chunks live in a
    byte-budgeted ``ShardedDecodeCache`` (DESIGN.md §9.2, sharded per
    §10.2) — restore working sets rotate LRU under ``cache_bytes``
    instead of accumulating the whole dataset in RAM. ``rewrite_live``
    (compaction, DESIGN.md §7.3) rewrites both files through temp-file +
    atomic rename with the epoch bumped; pre-header directories still
    open (epoch 0, records at offset 0).

    Concurrency contract (DESIGN.md §10.4): ``get``/``get_many``/
    ``record`` and the recipe read surface are safe from any number of
    threads at once (payload reads are positionless ``os.pread`` on a
    pooled fd set, the decode cache is sharded and internally locked,
    telemetry is per-thread). Writes (``put_*``, ``add_recipe``,
    ``retire_recipe``) may run concurrently with reads but not with each
    other, and ``rewrite_live``/``close`` require full exclusion — the
    store enforces both with its commit mutex and lifecycle RW lock.
    """

    name = "file"
    record_overhead = _REC_HEADER.size

    def __init__(self, path: str | Path, fsync_on_flush: bool = False,
                 cache_bytes: int | None = None,
                 cache_shards: int | None = None,
                 cache_policy: str | None = None,
                 reader_fds: int | None = None,
                 readahead: int | None = None,
                 coalesce_gap: int | None = None,
                 verify_reads: bool = False,
                 singleflight: bool = True,
                 faults=None) -> None:
        """``fsync_on_flush=True`` makes every ``flush()`` (one per
        committed stream — group commit, DESIGN.md §8) durable with a
        single fsync per file; the default keeps the historical
        buffered-only commits (deletes always fsync their tombstone).
        ``cache_bytes`` budgets the decode cache (DESIGN.md §9.2;
        default ``repro.api.restore.DEFAULT_CACHE_BYTES``) and
        ``cache_shards`` how many ways it stripes (§10.2).
        ``reader_fds`` sizes the pread pool (= payload reads in flight),
        ``readahead`` how many coalesced read runs the fetcher keeps in
        flight ahead of the decode loop (0 = strictly serial reads).
        ``coalesce_gap`` is the largest hole (bytes of unwanted data)
        two records may straddle and still be fetched in one pread
        (default 4 KiB — one page of waste; object stores use MB-scale
        gaps, §11.3). ``cache_policy`` names the decode-cache eviction
        policy ("lru"/"arc", §14.1); ``singleflight=False`` disables the
        §14.2 cold-decode collapse (benchmark A/B only).
        ``verify_reads`` checks every payload read off the
        log against its persisted crc32c (§13.2); ``faults`` threads a
        ``repro.api.faults.FaultInjector`` through the write-path
        crashpoints (tests only)."""
        self.path = Path(path)
        self._fsync_on_flush = fsync_on_flush
        self._verify_reads = bool(verify_reads)
        self._faults = faults
        self.path.mkdir(parents=True, exist_ok=True)
        self._log_path = self.path / "chunks.log"
        self._recipes_path = self.path / "recipes.jsonl"
        for stale in (self._log_path, self._recipes_path):
            tmp = stale.with_suffix(stale.suffix + ".tmp")
            if tmp.exists():        # abandoned mid-compaction; originals win
                tmp.unlink()
        self._index: dict[int, tuple[int, int, int, int]] = {}
        self._crcs: dict[int, int] = {}
        # one file never mixes record formats: fresh/empty logs start as
        # RCL2 (checksummed records), existing RCL1/pre-magic logs keep
        # appending v1 records until the first compaction rewrites them
        self._log_v2 = True
        self._cache = ShardedDecodeCache(
            cache_bytes if cache_bytes is not None else DEFAULT_CACHE_BYTES,
            shards=cache_shards if cache_shards is not None
            else DEFAULT_CACHE_SHARDS,
            policy=cache_policy if cache_policy is not None
            else DEFAULT_CACHE_POLICY)
        self._init_read_engine_state(singleflight)
        self._recipes: list[list[int] | None] = []
        self._recipe_lens: dict[int, list[int]] = {}
        # largest cid referenced by ANY recipe line ever seen — retired
        # and recovery-retired included. max_chunk_id() covers it so the
        # ids of a torn-away chunk (still named by its recipe line in the
        # journal) are never reissued to new content (§10.6).
        self._max_recipe_cid = -1
        # restore telemetry (DESIGN.md §9.4): per-thread counters so
        # concurrent restores attribute I/O exactly (§10.5); the
        # read_seconds/bytes_read/... properties expose lifetime totals
        self._telemetry = IoTelemetry()
        self._readahead = (DEFAULT_READAHEAD if readahead is None
                           else max(0, int(readahead)))
        self._merge_gap = (_READ_MERGE_GAP if coalesce_gap is None
                           else max(0, int(coalesce_gap)))
        self._max_run = _READ_MAX_RUN
        self.epoch = 0
        self._scan()
        self.record_overhead = (_REC_HEADER2.size if self._log_v2
                                else _REC_HEADER.size)
        self._log = open(self._log_path, "ab")
        if self._log.tell() == 0:
            self._log.write(_LOG_HEADER.pack(_LOG_MAGIC2, self.epoch))
        self._recipes_f = open(self._recipes_path, "a")
        if self._recipes_f.tell() == 0:
            self._recipes_f.write(json.dumps({"epoch": self.epoch}) + "\n")
        self._pool = _ReaderPool(self._log_path,
                                 reader_fds if reader_fds is not None
                                 else DEFAULT_READER_FDS)
        self._executor: ThreadPoolExecutor | None = None
        self._io_lock = threading.Lock()    # append handle + dirty flag
        self._ex_lock = self._io_lock       # guards lazy executor creation
        self._log_dirty = False

    # --- PlannedChainReader storage primitives (DESIGN.md §9/§10) ------------

    def _fetch_width(self) -> int:
        return self._pool.size

    def _read_span(self, offset: int, length: int) -> bytes:
        return self._pool.pread(offset, length)

    def _read_desc(self) -> str:
        return str(self._log_path)

    def _scan(self) -> None:
        # A kill -9 mid-ingest can tear the tail of either file; the torn
        # record belongs to a commit that never produced an IngestReport,
        # so dropping it loses nothing — but indexing it would serve short
        # reads (silent corruption) and a torn recipe line would make the
        # directory unopenable.
        log_epoch = recipes_epoch = 0
        if self._log_path.exists():
            size = self._log_path.stat().st_size
            good_end = 0
            with open(self._log_path, "rb") as f:
                head = f.read(_LOG_HEADER.size)
                if len(head) == _LOG_HEADER.size and head[:4] in (
                        _LOG_MAGIC, _LOG_MAGIC2):
                    log_epoch = _LOG_HEADER.unpack(head)[1]
                    good_end = _LOG_HEADER.size
                    self._log_v2 = head[:4] == _LOG_MAGIC2
                else:
                    f.seek(0)       # pre-epoch log: records start at 0
                    self._log_v2 = size == 0    # never mix record formats
                rec_header = _REC_HEADER2 if self._log_v2 else _REC_HEADER
                while True:
                    header = f.read(rec_header.size)
                    if len(header) < rec_header.size:
                        break
                    if self._log_v2:
                        kind, cid, base, length, crc = rec_header.unpack(
                            header)
                    else:
                        kind, cid, base, length = rec_header.unpack(header)
                        crc = None
                    if f.tell() + length > size:      # torn payload tail
                        break
                    self._index[cid] = (kind, base, f.tell(), length)
                    if crc is not None:
                        self._crcs[cid] = crc
                    f.seek(length, 1)
                    good_end = f.tell()
            if good_end < size:   # drop the torn bytes so later appends
                os.truncate(self._log_path, good_end)   # start on a boundary
        if self._recipes_path.exists():
            good_end = 0
            torn = False
            with open(self._recipes_path, "rb") as f:
                lines = f.readlines()
            for i, line in enumerate(lines):
                last = i == len(lines) - 1
                # an unterminated final line is torn even when it
                # parses — the next append would merge onto it
                if not line.endswith(b"\n"):
                    torn = True
                    break
                if line.strip():
                    try:
                        entry = json.loads(line)
                    except json.JSONDecodeError:
                        if last:            # torn recipe tail
                            torn = True
                            break
                        # a malformed line with durable lines AFTER it is
                        # not a torn tail — truncating here would silently
                        # drop committed streams (§13.3): fail loudly
                        raise CorruptJournalError(
                            self._recipes_path, i + 1,
                            "unparseable journal line before end of file")
                    if isinstance(entry, dict):
                        if i == 0 and "epoch" in entry:
                            recipes_epoch = int(entry["epoch"])
                        elif "retire" in entry:
                            h = int(entry["retire"])
                            if 0 <= h < len(self._recipes):
                                self._recipes[h] = None
                                self._recipe_lens.pop(h, None)
                        elif "quarantine" in entry:
                            # scrub --repair dropped these cids (§13.3):
                            # un-index them, but burn their ids so they
                            # are never reissued to new content
                            for cid in entry["quarantine"]:
                                cid = int(cid)
                                self._index.pop(cid, None)
                                self._crcs.pop(cid, None)
                                self._max_recipe_cid = max(
                                    self._max_recipe_cid, cid)
                        elif "recipe" in entry:
                            rec = entry["recipe"]
                            self._recipes.append(rec)
                            if rec:
                                self._max_recipe_cid = max(
                                    self._max_recipe_cid, max(rec))
                            lens = entry.get("lens")
                            if lens is not None:
                                self._recipe_lens[
                                    len(self._recipes) - 1] = lens
                    else:   # list = live recipe, null = retired slot
                        self._recipes.append(entry)
                        if entry:
                            self._max_recipe_cid = max(
                                self._max_recipe_cid, max(entry))
                good_end += len(line)
            if torn:
                os.truncate(self._recipes_path, good_end)
        # Joint-truncation hardening (DESIGN.md §10.6): the two files'
        # tails tear independently (commits are buffered, not fsync'd, so
        # the OS may persist a recipe line whose chunks never reached the
        # log). A live recipe referencing a chunk missing from the index
        # belongs to a commit that never produced an IngestReport —
        # retire it at scan time rather than crash the refcount rebuild
        # or serve KeyErrors later. The retirement must be DURABLE: the
        # recipe line itself survives in the journal, so without a
        # tombstone a later ingest that reused the torn cids would make
        # every referenced cid exist again on the next reopen and the
        # recipe would resurrect as live — serving another stream's
        # bytes. Committed streams are untouched (their chunks precede
        # their recipe line, and truncation is always a prefix of each
        # file).
        recovered: list[int] = []
        for h, recipe in enumerate(self._recipes):
            if recipe is not None and any(cid not in self._index
                                          for cid in recipe):
                self._recipes[h] = None
                self._recipe_lens.pop(h, None)
                recovered.append(h)
        if recovered:
            # fsync'd before __init__ returns, so no ingest can slip in
            # ahead of the tombstone; a crash right here just re-derives
            # the same retirement on the next open (no ids reused yet)
            with open(self._recipes_path, "a") as f:
                for h in recovered:
                    f.write(json.dumps({"retire": h}) + "\n")
                f.flush()
                os.fsync(f.fileno())
        # a crash between the two compaction renames leaves the epochs one
        # apart; both file states are consistent (see module docstring)
        self.epoch = max(log_epoch, recipes_epoch)

    def _pack_header(self, kind: int, cid: int, base: int,
                     payload: bytes) -> tuple[bytes, int | None]:
        if self._log_v2:
            crc = crc32c(payload)
            return (_REC_HEADER2.pack(kind, cid, base, len(payload), crc),
                    crc)
        return _REC_HEADER.pack(kind, cid, base, len(payload)), None

    def _append(self, kind: int, cid: int, base: int, payload: bytes) -> None:
        header, crc = self._pack_header(kind, cid, base, payload)
        with self._io_lock:
            self._log.write(header)
            offset = self._log.tell()
            self._log.write(payload)
            self._log_dirty = True
        self._index[cid] = (kind, base, offset, len(payload))
        if crc is not None:
            self._crcs[cid] = crc
        self._cp(_CP_PUT_WRITTEN)

    def put_raw(self, cid: int, data: bytes) -> None:
        self._append(_KIND_RAW, cid, -1, data)
        self._cache.put(cid, data)

    def put_delta(self, cid: int, base: int, patch: bytes,
                  data: bytes | None = None) -> None:
        self._append(_KIND_DELTA, cid, base, patch)
        if data is not None:
            self._cache.put(cid, data)

    def put_many(self, records: Sequence[tuple[int, int, bytes,
                                               bytes | None]]) -> None:
        """One buffered append for a whole stream's worth of records:
        headers and payloads are packed into a single buffer and written
        with one ``write()`` call, so a commit costs one syscall batch
        instead of two writes per chunk (DESIGN.md §8). Index/cache
        bookkeeping is identical to the per-chunk puts."""
        with self._io_lock:
            buf = bytearray()
            start = self._log.tell()
            entries = []
            for cid, base, payload, data in records:
                kind = _KIND_RAW if base < 0 else _KIND_DELTA
                if kind == _KIND_RAW:
                    data = payload
                header, crc = self._pack_header(kind, cid,
                                                base if kind else -1,
                                                payload)
                buf += header
                entries.append((cid, kind, base if kind else -1,
                                start + len(buf), len(payload), crc, data))
                buf += payload
            if not buf:
                return
            # index/cache only after the write is accepted — a failed write
            # must not leave phantom index entries at never-written offsets
            self._log.write(bytes(buf))
            self._log_dirty = True
        for cid, kind, base, offset, length, crc, data in entries:
            self._index[cid] = (kind, base, offset, length)
            if crc is not None:
                self._crcs[cid] = crc
            if data is not None:
                self._cache.put(cid, data)
        self._cp(_CP_PUT_WRITTEN)

    def _flush_if_dirty(self) -> None:
        # double-checked: readers skip the lock entirely once clean
        if self._log_dirty:
            with self._io_lock:
                if self._log_dirty:
                    self._log.flush()
                    self._log_dirty = False

    def add_recipe(self, chunk_ids: Sequence[int],
                   lengths: Sequence[int] | None = None) -> int:
        recipe = [int(c) for c in chunk_ids]
        self._recipes.append(recipe)
        if recipe:
            self._max_recipe_cid = max(self._max_recipe_cid, max(recipe))
        handle = len(self._recipes) - 1
        if lengths is None:
            self._recipes_f.write(json.dumps(recipe) + "\n")
        else:
            lens = [int(n) for n in lengths]
            self._recipe_lens[handle] = lens
            self._recipes_f.write(
                json.dumps({"recipe": recipe, "lens": lens}) + "\n")
        self._cp(_CP_RECIPE_APPENDED)
        return handle

    def retire_recipe(self, handle: int) -> None:
        self.recipe(handle)                 # raises on unknown/retired
        self._recipes[handle] = None
        self._recipe_lens.pop(handle, None)
        self._recipes_f.write(json.dumps({"retire": handle}) + "\n")
        # deletes are rare and irreversible-by-intent: fsync the tombstone
        # so a power loss cannot resurrect the stream (commits stay
        # flush-only; resurrecting a never-reported commit is harmless)
        self._cp(_CP_RETIRE_BEFORE_FSYNC)
        self._recipes_f.flush()
        os.fsync(self._recipes_f.fileno())

    def drop_chunks(self, cids: Sequence[int]) -> None:
        """Quarantine: durably un-index ``cids`` (scrub --repair, §13.3).
        A fsync'd ``{"quarantine": [...]}`` journal line records the drop
        — the records stay physically in the log (append-only) but are
        dead to the index on every future open, and their ids are burned
        so they can never be reissued. Callers guarantee no live recipe
        still references them and nothing deltas against them."""
        cids = sorted(int(c) for c in cids)
        if not cids:
            return
        self._recipes_f.write(json.dumps({"quarantine": cids}) + "\n")
        self._recipes_f.flush()
        os.fsync(self._recipes_f.fileno())
        dropped = set()
        for cid in cids:
            if self._index.pop(cid, None) is not None:
                dropped.add(cid)
            self._crcs.pop(cid, None)
            self._max_recipe_cid = max(self._max_recipe_cid, cid)
        self._cache.retain(lambda cid: cid not in dropped)

    def storage_bytes(self) -> int:
        self.flush()
        return (self._log_path.stat().st_size
                + self._recipes_path.stat().st_size)

    def _fsync_dir(self) -> None:
        fd = os.open(self.path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def rewrite_live(self, records: Iterable[tuple[int, int, int, bytes]]) -> None:
        """Compaction commit: stream `records` into fresh fsync'd files
        (epoch+1) next to the originals, then atomically rename each into
        place — recipes first, log second, with a directory fsync between
        so the ordering survives power loss (the new recipe set with the
        old log is restorable; a compacted log with pre-compaction recipes
        would reference swept chunks, so that state must never become
        durable). The old handles stay open until both renames succeed —
        a failed rename leaves the backend fully usable on the original
        files (the stale tmps are cleaned on the next open)."""
        new_epoch = self.epoch + 1
        new_index: dict[int, tuple[int, int, int, int]] = {}
        new_crcs: dict[int, int] = {}
        # compaction always writes the current format: an RCL1 log is
        # upgraded to RCL2 here, gaining checksums for every record
        log_tmp = self._log_path.with_suffix(".log.tmp")
        with open(log_tmp, "wb") as f:
            f.write(_LOG_HEADER.pack(_LOG_MAGIC2, new_epoch))
            for cid, kind, base, payload in records:
                crc = crc32c(payload)
                f.write(_REC_HEADER2.pack(kind, cid, base, len(payload),
                                          crc))
                new_index[cid] = (kind, base, f.tell(), len(payload))
                new_crcs[cid] = crc
                f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        recipes_tmp = self._recipes_path.with_suffix(".jsonl.tmp")
        with open(recipes_tmp, "w") as f:
            f.write(json.dumps({"epoch": new_epoch}) + "\n")
            for h, recipe in enumerate(self._recipes):
                lens = self._recipe_lens.get(h)
                if recipe is not None and lens is not None:
                    f.write(json.dumps({"recipe": recipe, "lens": lens})
                            + "\n")
                else:           # null keeps handle slots stable
                    f.write(json.dumps(recipe) + "\n")
            f.flush()
            os.fsync(f.fileno())

        self.flush()                        # don't lose buffered appends
        self._cp(_CP_COMPACT_TMPS)
        os.replace(recipes_tmp, self._recipes_path)
        try:
            self._fsync_dir()               # recipes durably renamed first
            self._cp(_CP_COMPACT_RECIPES_RENAMED)
            os.replace(log_tmp, self._log_path)
            self._fsync_dir()
        finally:
            # the recipes path changed identity above either way: rebind
            # the append handle so later commits/tombstones reach the file
            # on disk even if the log rename failed (new recipes + old log
            # is a consistent state; see module docstring)
            self._recipes_f.close()
            self._recipes_f = open(self._recipes_path, "a")

        self._cp(_CP_COMPACT_DONE)
        self._log.close()
        self.epoch = new_epoch
        self._index = new_index
        self._crcs = new_crcs
        self._log_v2 = True
        self.record_overhead = _REC_HEADER2.size
        self._cache.retain(new_index.__contains__)
        self._log = open(self._log_path, "ab")
        self._pool.reopen()     # fresh fds on the renamed-into-place log
        self._log_dirty = False

    def flush(self) -> None:
        with self._io_lock:
            self._log.flush()
            self._log_dirty = False
            self._recipes_f.flush()
            self._cp(_CP_FLUSH_BEFORE_FSYNC)
            if self._fsync_on_flush:
                os.fsync(self._log.fileno())
                os.fsync(self._recipes_f.fileno())

    def close(self) -> None:
        self.flush()
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
        self._log.close()
        self._pool.close()
        self._recipes_f.close()
