"""Pluggable container backends (DESIGN.md §2.3).

A ``ContainerBackend`` owns the three persistent artifacts of the store:
chunk payloads (raw bytes or a delta patch + base reference), and stream
recipes (the ordered chunk-id list that reconstructs a stream). All store
*policy* — exact dedup, resemblance detection, delta-vs-raw decision,
accounting — stays above the backend in ``repro.api.store``; backends only
move bytes.

    InMemoryBackend   dict-based, keeps materialized bytes per chunk (the
                      v0 DedupStore behaviour: O(1) base lookup during
                      delta encoding at the cost of RAM);
    FileBackend       append-only chunk log + recipe journal on disk.
                      Stores what is *logically* stored (patch bytes for
                      delta chunks), materializes on read by resolving the
                      base chain, and can be reopened on an existing
                      directory for restore (byte-identical; tested).
"""
from __future__ import annotations

import json
import os
import struct
from pathlib import Path
from typing import Protocol, Sequence, runtime_checkable

from repro.api.registry import register_backend
from repro.core import delta

_REC_HEADER = struct.Struct("<BqqQ")  # kind, cid, base, payload length
_KIND_RAW = 0
_KIND_DELTA = 1


@runtime_checkable
class ContainerBackend(Protocol):
    """Byte storage behind the dedup store; see module docstring."""

    def put_raw(self, cid: int, data: bytes) -> None: ...

    def put_delta(self, cid: int, base: int, patch: bytes,
                  data: bytes | None = None) -> None:
        """Store chunk `cid` as a patch against `base`. `data` is the
        already-materialized raw bytes — backends MAY cache it but must
        not count on it (restore-after-reopen has only the patch)."""
        ...

    def get(self, cid: int) -> bytes:
        """Materialized raw bytes of a chunk (delta chains resolved)."""
        ...

    def contains(self, cid: int) -> bool: ...

    def max_chunk_id(self) -> int:
        """Largest chunk id ever stored, -1 when empty — a store opened on
        an existing backend seeds its id counter past this so new chunks
        never collide with (and silently shadow) persisted ones."""
        ...

    def add_recipe(self, chunk_ids: Sequence[int]) -> int:
        """Persist a stream recipe; returns the stream handle."""
        ...

    def recipe(self, handle: int) -> list[int]: ...

    def num_streams(self) -> int: ...

    def flush(self) -> None: ...

    def close(self) -> None: ...


@register_backend("memory")
class InMemoryBackend:
    """Everything in dicts; materialized bytes kept for every chunk."""

    name = "memory"

    def __init__(self) -> None:
        self._kind: dict[int, tuple] = {}   # cid -> (RAW,) | (DELTA, base, patch)
        self._data: dict[int, bytes] = {}   # cid -> materialized bytes
        self._recipes: list[list[int]] = []

    def put_raw(self, cid: int, data: bytes) -> None:
        self._kind[cid] = (_KIND_RAW,)
        self._data[cid] = data

    def put_delta(self, cid: int, base: int, patch: bytes,
                  data: bytes | None = None) -> None:
        self._kind[cid] = (_KIND_DELTA, base, patch)
        if data is None:
            data = delta.decode(patch, self.get(base))
        self._data[cid] = data

    def get(self, cid: int) -> bytes:
        return self._data[cid]

    def contains(self, cid: int) -> bool:
        return cid in self._kind

    def max_chunk_id(self) -> int:
        return max(self._kind, default=-1)

    def add_recipe(self, chunk_ids: Sequence[int]) -> int:
        self._recipes.append([int(c) for c in chunk_ids])
        return len(self._recipes) - 1

    def recipe(self, handle: int) -> list[int]:
        return self._recipes[handle]

    def num_streams(self) -> int:
        return len(self._recipes)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


@register_backend("file")
class FileBackend:
    """Append-only on-disk containers.

    Layout under `path`:
        chunks.log     [header cid base len][payload] records, appended
        recipes.jsonl  one JSON array of chunk ids per committed stream

    An index {cid -> (kind, base, offset, length)} is rebuilt by scanning
    the log on open, so a fresh FileBackend on an existing directory can
    serve restores immediately. Materialized chunks are cached in memory
    (same RAM/speed trade as InMemoryBackend once warm); the cache fills
    lazily on reopen.
    """

    name = "file"

    def __init__(self, path: str | Path) -> None:
        self.path = Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self._log_path = self.path / "chunks.log"
        self._recipes_path = self.path / "recipes.jsonl"
        self._index: dict[int, tuple[int, int, int, int]] = {}
        self._cache: dict[int, bytes] = {}
        self._recipes: list[list[int]] = []
        self._scan()
        self._log = open(self._log_path, "ab")
        self._recipes_f = open(self._recipes_path, "a")
        self._log_read = open(self._log_path, "rb")
        self._log_dirty = False

    def _scan(self) -> None:
        # A kill -9 mid-ingest can tear the tail of either file; the torn
        # record belongs to a commit that never produced an IngestReport,
        # so dropping it loses nothing — but indexing it would serve short
        # reads (silent corruption) and a torn recipe line would make the
        # directory unopenable.
        if self._log_path.exists():
            size = self._log_path.stat().st_size
            good_end = 0
            with open(self._log_path, "rb") as f:
                while True:
                    header = f.read(_REC_HEADER.size)
                    if len(header) < _REC_HEADER.size:
                        break
                    kind, cid, base, length = _REC_HEADER.unpack(header)
                    if f.tell() + length > size:      # torn payload tail
                        break
                    self._index[cid] = (kind, base, f.tell(), length)
                    f.seek(length, 1)
                    good_end = f.tell()
            if good_end < size:   # drop the torn bytes so later appends
                os.truncate(self._log_path, good_end)   # start on a boundary
        if self._recipes_path.exists():
            good_end = 0
            torn = False
            with open(self._recipes_path, "rb") as f:
                for line in f:
                    # an unterminated final line is torn even when it
                    # parses — the next append would merge onto it
                    if not line.endswith(b"\n"):
                        torn = True
                        break
                    if line.strip():
                        try:
                            recipe = json.loads(line)
                        except json.JSONDecodeError:  # torn recipe tail
                            torn = True
                            break
                        self._recipes.append(recipe)
                    good_end += len(line)
            if torn:
                os.truncate(self._recipes_path, good_end)

    def _append(self, kind: int, cid: int, base: int, payload: bytes) -> None:
        self._log.write(_REC_HEADER.pack(kind, cid, base, len(payload)))
        offset = self._log.tell()
        self._log.write(payload)
        self._log_dirty = True
        self._index[cid] = (kind, base, offset, len(payload))

    def put_raw(self, cid: int, data: bytes) -> None:
        self._append(_KIND_RAW, cid, -1, data)
        self._cache[cid] = data

    def put_delta(self, cid: int, base: int, patch: bytes,
                  data: bytes | None = None) -> None:
        self._append(_KIND_DELTA, cid, base, patch)
        if data is not None:
            self._cache[cid] = data

    def _read_payload(self, offset: int, length: int) -> bytes:
        if self._log_dirty:
            self._log.flush()
            self._log_dirty = False
        self._log_read.seek(offset)
        return self._log_read.read(length)

    def get(self, cid: int) -> bytes:
        data = self._cache.get(cid)
        if data is not None:
            return data
        # walk the base chain down to a raw/cached ancestor, then apply
        # patches back up (iterative: delta chains can outgrow recursion)
        chain: list[tuple[int, bytes]] = []
        cur = cid
        while True:
            data = self._cache.get(cur)
            if data is not None:
                break
            kind, base, offset, length = self._index[cur]
            payload = self._read_payload(offset, length)
            if kind == _KIND_RAW:
                data = payload
                self._cache[cur] = data
                break
            chain.append((cur, payload))
            cur = base
        for c, patch in reversed(chain):
            data = delta.decode(patch, data)
            self._cache[c] = data
        return data

    def contains(self, cid: int) -> bool:
        return cid in self._index

    def max_chunk_id(self) -> int:
        return max(self._index, default=-1)

    def add_recipe(self, chunk_ids: Sequence[int]) -> int:
        recipe = [int(c) for c in chunk_ids]
        self._recipes.append(recipe)
        self._recipes_f.write(json.dumps(recipe) + "\n")
        return len(self._recipes) - 1

    def recipe(self, handle: int) -> list[int]:
        return self._recipes[handle]

    def num_streams(self) -> int:
        return len(self._recipes)

    def flush(self) -> None:
        self._log.flush()
        self._recipes_f.flush()

    def close(self) -> None:
        self.flush()
        self._log.close()
        self._log_read.close()
        self._recipes_f.close()
