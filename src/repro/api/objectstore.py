"""Object-store container backend, fault-injecting local fake, and a
``cp``/``ls``/``stat``/``verify`` CLI (DESIGN.md §11).

Three layers, top to bottom:

  CLI                 ``python -m repro.api.objectstore cp/ls/stat/verify``
                      — a deltaglider-style front door: copy local files
                      into a deduplicated object store, list logical vs
                      physical bytes, verify restores by SHA-256. A
                      store root is a directory holding ``catalog.json``
                      (names -> stream handles + SHAs + the pinned
                      DedupConfig) and ``objects/`` (the object tree).
  ObjectStoreBackend  a full ``ContainerBackend`` that keeps the chunk
                      log as immutable *container objects* and serves
                      restores through the shared §9/§10 read engine
                      (``containers.PlannedChainReader``): planned
                      chains, MB-scale range coalescing, a concurrent
                      fetch pool with double-buffered readahead, and
                      retry-with-backoff around every request. Commits
                      group into one container PUT + one journal PUT.
  LocalObjectStore    a directory-backed object API (``get_range`` /
                      ``put`` / ``list`` / ``head`` / ``delete_object``)
                      with injectable per-request latency, bandwidth
                      caps, and transient-error schedules — the fake
                      that lets tests and benchmarks model S3 without a
                      network. ``S3ObjectClient`` adapts real boto3 to
                      the same surface (gated: boto3 is optional).

Object layout under one backend root (all writes are whole-object PUTs,
which object stores apply atomically — there are no torn tails here,
only *missing* objects):

    manifest.json               {"epoch": N} — which epoch prefix is live;
                                rewriting it is the atomic compaction flip
    e{epoch:08d}/chunks/{seq:08d}
                                container objects: chunk payloads packed
                                back-to-back, no per-record headers
                                (``record_overhead = 0`` — the index
                                lives in the journal)
    e{epoch:08d}/journal/{seq:08d}.json
                                journal objects, each a JSON list of
                                entries replayed in order on open:
                                {"chunks": [[cid,kind,base,seq,off,len,
                                crc32c]..]} (pre-§13 rows lack the crc),
                                {"recipe": ids, "lens": lens},
                                {"retire": handle}, {"quarantine":
                                [cids]} (scrub --repair), and the
                                consolidated {"recipes": [...]} written
                                by compaction

Addressing: the index maps ``cid -> (kind, base, voff, length)`` where
``voff = seq << 40 | offset`` is a *virtual* offset. Chain plans sort
and coalesce on voff; because every coalesce gap is ≪ 2^40, a coalesced
run can never straddle two container objects, so the shared read engine
needs no object-awareness at all — ``_read_span`` just splits voff back
into (object, range) and issues one ranged GET.

Recovery (§11.4): a crash can lose the journal PUT of a commit whose
container PUT landed (the orders is container-then-journal), leaving an
orphan container object; it can never produce a journal that references
bytes that were not uploaded first. ``_scan`` replays the journals,
drops index entries whose container object is missing or too short
(plus their delta dependents), durably retires recipes referencing lost
chunks (journaled ``retire`` entries — same policy as the file
backend's torn-tail recovery), deletes orphan containers and any
stale-epoch leftovers of an interrupted compaction.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import random
import sys
import threading
import time
from pathlib import Path
from typing import Callable, Iterable, Sequence

from repro.api.concurrency import IoTelemetry
from repro.api.containers import (_KIND_DELTA, _KIND_RAW, DEFAULT_READAHEAD,
                                  PlannedChainReader)
# canonical home of the fault machinery is repro.api.faults (§13.4); the
# re-exports keep the historical import path working
from repro.api.faults import (FaultSchedule, RetryBudgetExceeded,  # noqa: F401
                              TransientError, register_crashpoint,
                              with_retries)
from repro.api.integrity import crc32c
from repro.api.registry import get_cache_policy, register_backend
from repro.api.restore import (DEFAULT_CACHE_BYTES, DEFAULT_CACHE_POLICY,
                               DEFAULT_CACHE_SHARDS, ShardedDecodeCache)

# voff = seq << _OBJ_SHIFT | offset-in-object. 2^40 per object is far
# beyond any real object size, and far beyond any coalesce gap — the
# invariant that keeps runs from straddling objects (module docstring).
_OBJ_SHIFT = 40
_OBJ_MASK = (1 << _OBJ_SHIFT) - 1

#: Default coalesce gap for object backends: with ~10 ms per request,
#: re-reading a 1 MiB hole costs less than a second round-trip on any
#: link faster than ~100 MB/s — the opposite trade from the file
#: backend's one-page gap (DESIGN.md §11.3).
DEFAULT_OBJSTORE_GAP = 1 << 20
DEFAULT_OBJSTORE_MAX_RUN = 32 << 20
#: Target container-object size; put_many rolls to a new object past it
#: (multipart-style part uploads for one group commit).
DEFAULT_OBJECT_BYTES = 8 << 20
DEFAULT_FETCHERS = 4            # concurrent ranged GETs in flight
DEFAULT_MAX_RETRIES = 4
DEFAULT_RETRY_BACKOFF = 0.05    # doubles per attempt: 50/100/200/400 ms
#: Default byte budget for the local-disk chunk tier (§14.3) when a
#: ``tier_path`` is given without an explicit ``tier_bytes``.
DEFAULT_TIER_BYTES = 256 << 20

_MANIFEST_KEY = "manifest.json"

# ObjectStoreBackend crashpoints (DESIGN.md §13.4): every PUT boundary a
# kill can land on. Fired only when a FaultInjector was threaded in via
# ``faults=``.
_CP_LOCALPUT_BEFORE_RENAME = register_crashpoint(
    "objstore.localput.before_rename",
    "LocalObjectStore PUT: tmp written+fsynced, before the rename")
_CP_FLUSH_BEFORE_CONTAINER = register_crashpoint(
    "objstore.flush.before_container_put",
    "commit flush entered, before the container object PUT")
_CP_FLUSH_BETWEEN_PUTS = register_crashpoint(
    "objstore.flush.between_puts",
    "container object PUT landed, journal PUT not yet issued")
_CP_FLUSH_AFTER_JOURNAL = register_crashpoint(
    "objstore.flush.after_journal_put",
    "journal PUT landed, before in-memory staging resets")
_CP_RETIRE_BEFORE_FLUSH = register_crashpoint(
    "objstore.retire.before_flush",
    "retire entry journaled in memory, before its durable flush PUT")
_CP_COMPACT_CONTAINERS_PUT = register_crashpoint(
    "objstore.compact.containers_put",
    "all new-epoch container objects PUT, journal not yet")
_CP_COMPACT_JOURNAL_PUT = register_crashpoint(
    "objstore.compact.journal_put",
    "new-epoch consolidated journal PUT, manifest not yet flipped")
_CP_COMPACT_MANIFEST_FLIPPED = register_crashpoint(
    "objstore.compact.manifest_flipped",
    "manifest flipped to the new epoch, old epoch not yet deleted")


class LocalObjectStore:
    """Directory-backed object API with injectable faults (§11.2).

    Keys are ``/``-separated paths under ``root``; objects are plain
    files, PUT atomically (tmp + rename) so a crashed writer can never
    leave a half-object — matching the whole-object atomicity real
    stores give. Every request first pays ``latency`` seconds, then an
    optional ``fault_hook(op, key, request_ordinal)`` may return an
    exception to raise (see ``FaultSchedule``); transfers additionally
    pay ``len / bandwidth_bps``. Request/byte counters are kept per op —
    benchmarks read them as ground truth for "how many GETs did that
    restore cost".

    Thread-safe: counters are locked, the filesystem does the rest.
    """

    def __init__(self, root: str | Path, latency: float = 0.0,
                 bandwidth_bps: float | None = None,
                 fault_hook: Callable[[str, str, int],
                                      Exception | None] | None = None,
                 faults=None) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.latency = float(latency)
        self.bandwidth_bps = bandwidth_bps
        self.fault_hook = fault_hook
        self.faults = faults    # FaultInjector for the PUT crashpoint
        self._lock = threading.Lock()
        self.requests = 0
        self.op_counts: dict[str, int] = {}
        self.bytes_put = 0
        self.bytes_got = 0

    def _path(self, key: str) -> Path:
        if ".." in key.split("/"):
            raise ValueError(f"bad object key {key!r}")
        return self.root / key

    def _begin(self, op: str, key: str) -> None:
        with self._lock:
            self.requests += 1
            n = self.requests
            self.op_counts[op] = self.op_counts.get(op, 0) + 1
        if self.latency > 0:
            time.sleep(self.latency)
        hook = self.fault_hook
        if hook is not None:
            exc = hook(op, key, n)
            if exc is not None:
                raise exc

    def _bill(self, op: str, nbytes: int) -> None:
        with self._lock:
            if op == "put":
                self.bytes_put += nbytes
            else:
                self.bytes_got += nbytes
        if self.bandwidth_bps and nbytes:
            time.sleep(nbytes / self.bandwidth_bps)

    def put(self, key: str, data: bytes) -> None:
        self._begin("put", key)
        path = self._path(key)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(path.name + ".tmp")
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        if self.faults is not None:
            self.faults.crashpoint(_CP_LOCALPUT_BEFORE_RENAME)
        os.replace(tmp, path)
        self._bill("put", len(data))

    def get(self, key: str) -> bytes:
        self._begin("get", key)
        try:
            data = self._path(key).read_bytes()
        except FileNotFoundError:
            raise KeyError(key) from None
        self._bill("get", len(data))
        return data

    def get_range(self, key: str, start: int, length: int) -> bytes:
        """Ranged GET: bytes [start, start+length), short at object end
        (callers treat short as truncation, like ``_ReaderPool.pread``)."""
        self._begin("get", key)
        try:
            with open(self._path(key), "rb") as f:
                f.seek(start)
                data = f.read(length)
        except FileNotFoundError:
            raise KeyError(key) from None
        self._bill("get", len(data))
        return data

    def head(self, key: str) -> int | None:
        """Object size in bytes, or None when the key is absent."""
        self._begin("head", key)
        try:
            return self._path(key).stat().st_size
        except FileNotFoundError:
            return None

    def list(self, prefix: str = "") -> list[tuple[str, int]]:
        """Sorted ``(key, size)`` pairs under ``prefix`` — one LIST
        request regardless of result count (real stores paginate; the
        request-count model here stays deliberately simple)."""
        self._begin("list", prefix)
        out = []
        for dirpath, _, files in os.walk(self.root):
            for fn in files:
                if fn.endswith(".tmp"):     # a torn PUT, never visible
                    continue
                p = Path(dirpath) / fn
                key = p.relative_to(self.root).as_posix()
                if key.startswith(prefix):
                    out.append((key, p.stat().st_size))
        out.sort()
        return out

    def delete_object(self, key: str) -> None:
        """Idempotent delete (matching S3: deleting a missing key is OK)."""
        self._begin("delete", key)
        try:
            self._path(key).unlink()
        except FileNotFoundError:
            pass


class S3ObjectClient:
    """boto3 adapter with the ``LocalObjectStore`` surface.

    Import of boto3 is deferred to construction — the dependency is
    optional and the rest of this module (backend, fake, CLI) must work
    without it. Select via ``DedupConfig(backend="s3", backend_args=
    {"bucket": ..., "prefix": ...})``. Untested in CI (no network, no
    boto3); it exists so the seam is real, not hypothetical.
    """

    def __init__(self, bucket: str, prefix: str = "",
                 client=None) -> None:
        if client is None:
            try:
                import boto3
            except ImportError as e:         # pragma: no cover
                raise RuntimeError(
                    "backend 's3' needs boto3, which is not installed; "
                    "use backend 'objectstore' (the local fake) instead"
                ) from e
            client = boto3.client("s3")      # pragma: no cover
        self._s3 = client
        self.bucket = bucket
        self.prefix = prefix.strip("/")

    def _key(self, key: str) -> str:
        return f"{self.prefix}/{key}" if self.prefix else key

    def _wrap(self, err) -> Exception:
        # 429/5xx and throttling codes are retryable; 404 maps to the
        # KeyError contract; anything else propagates untouched
        code = (getattr(err, "response", None) or {}).get(
            "ResponseMetadata", {}).get("HTTPStatusCode")
        if code in (429, 500, 502, 503, 504):
            return TransientError(code, str(err))
        return err

    def put(self, key: str, data: bytes) -> None:
        try:
            self._s3.put_object(Bucket=self.bucket, Key=self._key(key),
                                Body=data)
        except Exception as e:               # pragma: no cover
            raise self._wrap(e) from e

    def get(self, key: str) -> bytes:
        try:
            resp = self._s3.get_object(Bucket=self.bucket,
                                       Key=self._key(key))
            return resp["Body"].read()
        except Exception as e:
            if type(e).__name__ in ("NoSuchKey", "404"):
                raise KeyError(key) from None
            raise self._wrap(e) from e

    def get_range(self, key: str, start: int, length: int) -> bytes:
        try:
            resp = self._s3.get_object(
                Bucket=self.bucket, Key=self._key(key),
                Range=f"bytes={start}-{start + length - 1}")
            return resp["Body"].read()
        except Exception as e:
            if type(e).__name__ in ("NoSuchKey", "404"):
                raise KeyError(key) from None
            raise self._wrap(e) from e

    def head(self, key: str) -> int | None:
        try:
            resp = self._s3.head_object(Bucket=self.bucket,
                                        Key=self._key(key))
            return int(resp["ContentLength"])
        except Exception as e:
            code = (getattr(e, "response", None) or {}).get(
                "ResponseMetadata", {}).get("HTTPStatusCode")
            if code == 404:
                return None
            raise self._wrap(e) from e

    def list(self, prefix: str = "") -> list[tuple[str, int]]:
        out = []
        paginator = self._s3.get_paginator("list_objects_v2")
        full = self._key(prefix)
        strip = len(self.prefix) + 1 if self.prefix else 0
        for page in paginator.paginate(Bucket=self.bucket, Prefix=full):
            for obj in page.get("Contents", ()):
                out.append((obj["Key"][strip:], int(obj["Size"])))
        out.sort()
        return out

    def delete_object(self, key: str) -> None:
        try:
            self._s3.delete_object(Bucket=self.bucket, Key=self._key(key))
        except Exception as e:               # pragma: no cover
            raise self._wrap(e) from e


class DiskTierCache:
    """Byte-budgeted local-disk chunk tier in front of a remote object
    store (DESIGN.md §14.3).

    One plain file per cached chunk payload (``{cid & 0xff:02x}/{cid}``
    under the tier root, tmp+rename writes), no on-disk metadata —
    reopen rebuilds the in-memory book by scanning the directory, so
    the tier survives process restarts and tolerates losing any file at
    any time (a lost entry is just a miss).

    Coherence rules (§14.3):

      * **crc-verified on fill** — ``put`` computes crc32c over the
        payload and drops the fill unless it matches the journaled crc
        the backend passed in (chunks without a journaled crc are never
        tiered: there would be nothing to verify reads against);
      * **lazily re-verified on read** — the first ``get`` of an entry
        this process hasn't verified yet (every entry, after a reopen)
        recomputes the crc; a mismatch — bit rot, or a patch rebased by
        compaction — unlinks the file and reports a miss, so corruption
        is *refetched*, never served;
      * eviction ordering comes from the same pluggable
        :class:`repro.api.restore.CachePolicy` family as the decode
        cache ("arc" by default, so whole-store scans stream through
        without flushing hot chains).

    All operations serialize on one lock — tier file I/O is local and
    micro-seconds-scale against the remote hop it replaces, and the
    simplicity keeps the directory book exact.
    """

    def __init__(self, root: str | Path, budget_bytes: int,
                 policy: str = "arc") -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.budget_bytes = max(1, int(budget_bytes))
        self.policy_name = str(policy)
        self._policy = get_cache_policy(self.policy_name)(self.budget_bytes)
        self._lock = threading.Lock()
        self._sizes: dict[int, int] = {}
        self._verified: set[int] = set()
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.bytes_served = 0
        self.bytes_filled = 0
        self.dropped = 0        # crc-failed entries unlinked (bit rot or
        #                         post-compaction staleness) — §14.3
        with self._lock:
            self._scan_dir()

    def _path(self, cid: int) -> Path:
        return self.root / f"{cid & 0xff:02x}" / str(cid)

    def _scan_dir(self) -> None:
        # lock held. Torn fills (tmp files) are dropped; everything else
        # is adopted unverified — the first read re-checks its crc
        for sub in sorted(self.root.iterdir()):
            if not sub.is_dir():
                continue
            for f in sorted(sub.iterdir()):
                if f.name.endswith(".tmp"):
                    f.unlink(missing_ok=True)
                    continue
                try:
                    cid = int(f.name)
                except ValueError:
                    continue
                size = f.stat().st_size
                self._sizes[cid] = size
                self.bytes += size
                self._policy.on_insert(cid, size)
        self._evict_over_budget()

    def _evict_over_budget(self) -> None:
        # lock held; the tier has no pin concept, every entry is fair game
        while self.bytes > self.budget_bytes:
            victim = self._policy.victim(lambda c: False)
            if victim is None:
                break
            self._forget(victim)

    def _forget(self, cid: int) -> None:
        # lock held; policy bookkeeping is the caller's (victim() already
        # moved evictees to its ghost side; on_remove covers the rest)
        size = self._sizes.pop(cid, None)
        if size is not None:
            self.bytes -= size
        self._verified.discard(cid)
        self._path(cid).unlink(missing_ok=True)

    def get(self, cid: int, expected_crc: int | None) -> bytes | None:
        """Tiered payload bytes, or None (miss / dropped-as-bad)."""
        with self._lock:
            size = self._sizes.get(cid)
            if size is None:
                self.misses += 1
                return None
            try:
                data = self._path(cid).read_bytes()
            except OSError:
                data = None
            ok = (data is not None and len(data) == size
                  and (cid in self._verified or expected_crc is None
                       or crc32c(data) == expected_crc))
            if not ok:
                self._policy.on_remove(cid)
                self._forget(cid)
                self.misses += 1
                self.dropped += 1
                return None
            self._verified.add(cid)
            self.hits += 1
            self.bytes_served += len(data)
            self._policy.on_hit(cid)
            return data

    def put(self, cid: int, payload: bytes, expected_crc: int | None) -> None:
        """Fill from a coalesced-GET span; drops silently unless the
        payload matches the journaled crc (crc-verified-on-fill)."""
        if expected_crc is None or crc32c(payload) != expected_crc:
            return
        with self._lock:
            if cid in self._sizes:
                return
            path = self._path(cid)
            path.parent.mkdir(exist_ok=True)
            tmp = path.with_name(path.name + ".tmp")
            tmp.write_bytes(payload)
            os.replace(tmp, path)
            self._sizes[cid] = len(payload)
            self._verified.add(cid)
            self.bytes += len(payload)
            self.bytes_filled += len(payload)
            self._policy.on_insert(cid, len(payload))
            self._evict_over_budget()

    def retain(self, keep: Callable[[int], bool]) -> None:
        """Drop entries whose cid fails ``keep`` (compaction sweep /
        quarantine). Entries whose *payload* compaction rewrote (rebased
        patches) are caught lazily by the read-path crc check — which is
        why every surviving entry is demoted to unverified here: their
        expected crcs may have just changed under them."""
        with self._lock:
            for cid in [c for c in self._sizes if not keep(c)]:
                self._policy.on_remove(cid)
                self._forget(cid)
            self._verified.clear()

    def __len__(self) -> int:
        return len(self._sizes)


class ObjectStoreBackend(PlannedChainReader):
    """``ContainerBackend`` over an object API (module docstring, §11).

    The write path stages into RAM: ``put_many`` appends payloads to an
    open container buffer (rolled past ``max_object_bytes`` —
    multipart-style parts of one logical commit) and queues journal
    rows; ``flush()`` uploads the open buffer as one container object
    and everything journaled since the last flush as one journal
    object — so a committed stream costs O(stream/max_object_bytes)
    PUTs, not O(chunks). The container PUT always precedes its journal
    PUT: a crash between the two leaves an orphan container (cleaned on
    the next open), never a journal naming bytes that don't exist.

    Reads go through the inherited §9/§10 engine; every request is
    wrapped in retry-with-exponential-backoff on ``TransientError``
    (``max_retries``/``retry_backoff``), so an injected 429/timeout
    schedule below the retry budget is invisible to callers except in
    the client's request counters.

    Concurrency contract: identical to ``FileBackend`` (reads from any
    number of threads; writes serialized by the store's commit mutex;
    ``rewrite_live``/``close`` under full exclusion).
    """

    name = "objectstore"
    record_overhead = 0         # payloads packed bare; index in journal

    def __init__(self, path: str | Path | None = None, *,
                 client=None,
                 latency: float = 0.0,
                 bandwidth_bps: float | None = None,
                 fault_hook=None,
                 cache_bytes: int | None = None,
                 cache_shards: int | None = None,
                 cache_policy: str | None = None,
                 readahead: int | None = None,
                 coalesce_gap: int | None = None,
                 fetchers: int | None = None,
                 max_object_bytes: int | None = None,
                 max_retries: int | None = None,
                 retry_backoff: float | None = None,
                 retry_deadline: float | None = None,
                 verify_reads: bool = False,
                 singleflight: bool = True,
                 tier_path: str | Path | None = None,
                 tier_bytes: int | None = None,
                 faults=None) -> None:
        """Either ``path`` (a ``LocalObjectStore`` is built over it,
        forwarding ``latency``/``bandwidth_bps``/``fault_hook``) or an
        explicit ``client`` with the same surface. The serving knobs
        (``cache_bytes``/``cache_shards``/``readahead``/
        ``coalesce_gap``) mean what they do on ``FileBackend`` —
        ``coalesce_gap`` just defaults six orders of magnitude larger
        (§11.3). ``fetchers`` sizes the concurrent GET pool,
        ``max_retries``/``retry_backoff``/``retry_deadline`` the
        transient-failure budget (§13.5: backoff is decorrelated-jittered
        and total sleep per logical request is capped by the deadline).
        ``verify_reads`` checks every payload against its journaled
        crc32c (§13.2); ``faults`` threads a FaultInjector through the
        PUT-boundary crashpoints (tests only). ``cache_policy`` names
        the decode-cache eviction policy ("lru"/"arc", §14.1) and
        ``singleflight=False`` disables the §14.2 cold-decode collapse
        (benchmark A/B only). ``tier_path`` roots a local-disk chunk
        tier in front of the remote store (§14.3) budgeted by
        ``tier_bytes`` (default ``DEFAULT_TIER_BYTES``); the tier reuses
        the scan-resistant policy family and survives reopen."""
        if client is None:
            if path is None:
                raise ValueError("ObjectStoreBackend needs a path (local "
                                 "object root) or an explicit client")
            client = LocalObjectStore(path, latency=latency,
                                      bandwidth_bps=bandwidth_bps,
                                      fault_hook=fault_hook, faults=faults)
        self.client = client
        self._verify_reads = bool(verify_reads)
        self._faults = faults
        self._crcs: dict[int, int] = {}
        self._desc = f"objects at {getattr(client, 'root', None) or getattr(client, 'bucket', '?')}"
        self._max_object_bytes = (DEFAULT_OBJECT_BYTES
                                  if max_object_bytes is None
                                  else max(1, int(max_object_bytes)))
        self._max_retries = (DEFAULT_MAX_RETRIES if max_retries is None
                             else max(0, int(max_retries)))
        self._backoff = (DEFAULT_RETRY_BACKOFF if retry_backoff is None
                         else float(retry_backoff))
        # total seconds one logical request may spend ASLEEP across its
        # retries before RetryBudgetExceeded; None = attempts-only budget
        self._retry_deadline = (None if retry_deadline is None
                                else max(0.0, float(retry_deadline)))
        # decorrelated jitter needs a private RNG (never the global one —
        # tests seed that); the cap keeps one sleep bounded at what the
        # old deterministic schedule's final doubling would have been
        self._retry_rng = random.Random()
        self._backoff_cap = self._backoff * (1 << self._max_retries)
        self.retries = 0        # transient failures absorbed (lifetime)
        self._fetchers = (DEFAULT_FETCHERS if fetchers is None
                          else max(1, int(fetchers)))
        # --- PlannedChainReader state (base-class contract) ---
        self._index: dict[int, tuple[int, int, int, int]] = {}
        self._cache = ShardedDecodeCache(
            cache_bytes if cache_bytes is not None else DEFAULT_CACHE_BYTES,
            shards=cache_shards if cache_shards is not None
            else DEFAULT_CACHE_SHARDS,
            policy=cache_policy if cache_policy is not None
            else DEFAULT_CACHE_POLICY)
        self._init_read_engine_state(singleflight)
        if tier_path is not None:
            # the tier defaults to the scan-resistant policy even when
            # the in-RAM cache stays lru — scans must stream through the
            # disk tier too, and there is no compatibility reason to
            # rotate it (§14.3)
            self._tier = DiskTierCache(
                tier_path,
                tier_bytes if tier_bytes is not None else DEFAULT_TIER_BYTES,
                policy=cache_policy if cache_policy is not None else "arc")
        self._recipes: list[list[int] | None] = []
        self._recipe_lens: dict[int, list[int]] = {}
        self._max_recipe_cid = -1
        self._telemetry = IoTelemetry()
        self._readahead = (DEFAULT_READAHEAD if readahead is None
                           else max(0, int(readahead)))
        self._merge_gap = (DEFAULT_OBJSTORE_GAP if coalesce_gap is None
                           else max(0, int(coalesce_gap)))
        self._max_run = DEFAULT_OBJSTORE_MAX_RUN
        self._executor = None
        self._ex_lock = threading.Lock()
        # --- staging (guarded by _io_lock) ---
        self._io_lock = threading.Lock()
        self._pending = bytearray()     # open container object's payloads
        self._cur_seq = 0               # its sequence number
        self._chunk_rows: list[list[int]] = []   # journal rows not yet PUT
        self._journal_entries: list[dict] = []   # recipe/retire, in order
        self._next_journal = 0
        self._dirty = False
        self.epoch = 0
        self._scan()
        if self._manifest_missing:
            self._call(self.client.put, _MANIFEST_KEY,
                       json.dumps({"epoch": self.epoch}).encode())

    # --- observability binding (DESIGN.md §12.3) -----------------------------

    # class-level None defaults: _scan() issues client requests from
    # __init__, before any store can bind an Observability
    _h_req_seconds = None
    _h_get_bytes = None
    _c_backoff = None

    def bind_observability(self, obs) -> None:
        """Base binding (run shapes + reader views) plus the remote-store
        instruments: per-request latency histograms by op, ranged-GET
        response sizes, retry/backoff accounting. The client's own
        request/byte counters — every attempt, fault-injected ones
        included — are re-exported as derived views."""
        super().bind_observability(obs)
        from repro.api import observe as om
        m = obs.metrics
        self._h_req_seconds = {
            op: m.histogram("repro_objstore_request_seconds",
                            "Client request latency per attempt (§11.2)",
                            labels={"op": op}, bounds=om.SECONDS_BUCKETS)
            for op in ("put", "get", "head", "list", "delete")}
        self._h_get_bytes = m.histogram(
            "repro_objstore_get_bytes", "Ranged-GET response sizes (§11.3)",
            bounds=om.BYTES_BUCKETS)
        self._c_backoff = m.counter(
            "repro_objstore_backoff_seconds_total",
            "Time slept in the retry policy's exponential backoff")
        c_retries = m.counter("repro_objstore_retries_total",
                              "Transient failures absorbed by the retry "
                              "policy")
        client = self.client
        tier = self._tier
        c_tier = g_tier = None
        if tier is not None:
            c_tier = {
                "hit": m.counter("repro_tier_lookups_total",
                                 "Disk-tier probe outcomes (§14.3)",
                                 labels={"outcome": "hit"}),
                "miss": m.counter("repro_tier_lookups_total",
                                  "Disk-tier probe outcomes (§14.3)",
                                  labels={"outcome": "miss"}),
                "served": m.counter("repro_tier_bytes_total",
                                    "Bytes served from / filled into the "
                                    "disk tier", labels={"dir": "served"}),
                "filled": m.counter("repro_tier_bytes_total",
                                    "Bytes served from / filled into the "
                                    "disk tier", labels={"dir": "filled"}),
                "dropped": m.counter("repro_tier_dropped_total",
                                     "Tier entries unlinked on crc "
                                     "mismatch (bit rot or "
                                     "post-compaction staleness; §14.3)"),
            }
            g_tier = m.gauge("repro_tier_bytes", "Disk-tier residency")

        def _export_objstore_views() -> None:
            if c_tier is not None:
                c_tier["hit"].set_total(tier.hits)
                c_tier["miss"].set_total(tier.misses)
                c_tier["served"].set_total(tier.bytes_served)
                c_tier["filled"].set_total(tier.bytes_filled)
                c_tier["dropped"].set_total(tier.dropped)
                g_tier.set(tier.bytes)
            c_retries.set_total(self.retries)
            op_counts = getattr(client, "op_counts", None)
            if op_counts is not None:
                for op, n in list(op_counts.items()):
                    m.counter("repro_objstore_client_requests_total",
                              "Client requests by op, every attempt "
                              "counted", labels={"op": op}).set_total(n)
            for attr, d in (("bytes_put", "put"), ("bytes_got", "got")):
                v = getattr(client, attr, None)
                if v is not None:
                    m.counter("repro_objstore_client_bytes_total",
                              "Object bytes shipped to / from the store",
                              labels={"dir": d}).set_total(v)

        m.register_callback(_export_objstore_views)

    # client method name -> exported op label (§12.2 naming)
    _OP_LABELS = {"get_range": "get", "delete_object": "delete"}

    # --- request plumbing ----------------------------------------------------

    def _call(self, fn, *args):
        """Issue one client request with the retry policy (§11.2/§13.5):
        on ``TransientError`` sleep a decorrelated-jittered backoff
        (``uniform(base, 3 * previous_sleep)``, capped at
        ``backoff * 2^max_retries``) and reissue, up to ``max_retries``
        reissues AND at most ``retry_deadline`` total seconds asleep —
        whichever budget runs out first. Exhausting the attempt budget
        re-raises the last ``TransientError``; exhausting the deadline
        raises ``RetryBudgetExceeded`` carrying the attempt count and
        slept seconds. Every attempt — including failed ones — shows up
        in the client's own request counters; ``self.retries`` totals
        the absorbed faults. When an Observability is bound, every
        attempt also lands in the per-op latency histogram and each
        absorbed fault books its backoff into the counter (plus an
        ``objstore.retry`` span when tracing is on). The loop itself is
        ``faults.with_retries`` — the one audited backoff implementation
        (§13.5), shared with the §15 serving layer."""
        hists = self._h_req_seconds
        op = self._OP_LABELS.get(fn.__name__, fn.__name__)
        h = hists[op] if hists is not None else None
        on_attempt = ((lambda seconds, ok: h.observe(seconds))
                      if h is not None else None)

        def on_backoff(delay: float, attempt: int) -> None:
            self.retries += 1
            if self._c_backoff is not None:
                self._c_backoff.inc(delay)
                tr = self._obs.tracer
                if tr is not None:
                    tr.record("objstore.retry", delay, client_op=op,
                              attempt=attempt)

        return with_retries(fn, args, max_retries=self._max_retries,
                            backoff=self._backoff, cap=self._backoff_cap,
                            deadline=self._retry_deadline,
                            rng=self._retry_rng, on_attempt=on_attempt,
                            on_backoff=on_backoff)

    @staticmethod
    def _chunk_key(epoch: int, seq: int) -> str:
        return f"e{epoch:08d}/chunks/{seq:08d}"

    @staticmethod
    def _journal_key(epoch: int, seq: int) -> str:
        return f"e{epoch:08d}/journal/{seq:08d}.json"

    # --- PlannedChainReader storage primitives -------------------------------

    def _fetch_width(self) -> int:
        return self._fetchers

    def _read_span(self, offset: int, length: int) -> bytes:
        seq, off = offset >> _OBJ_SHIFT, offset & _OBJ_MASK
        key = self._chunk_key(self.epoch, seq)
        try:
            data = self._call(self.client.get_range, key, off, length)
        except KeyError:
            # surface as the truncation error class the engine documents
            raise IOError(f"container object {key} missing "
                          f"({self._desc})") from None
        if self._h_get_bytes is not None:
            self._h_get_bytes.observe(len(data))
        return data

    def _read_desc(self) -> str:
        return self._desc

    def _flush_if_dirty(self) -> None:
        # double-checked like FileBackend: readers skip the lock once clean
        if self._dirty:
            with self._io_lock:
                if self._dirty:
                    self._flush_locked()

    # --- write path ----------------------------------------------------------

    def _upload_pending_locked(self) -> None:
        if self._pending:
            self._call(self.client.put,
                       self._chunk_key(self.epoch, self._cur_seq),
                       bytes(self._pending))
            self._pending = bytearray()
            self._cur_seq += 1

    def _flush_locked(self) -> None:
        # container object first, journal second (module docstring: a
        # journal must never name bytes that were not uploaded before it)
        had_work = bool(self._pending or self._chunk_rows
                        or self._journal_entries)
        if had_work:
            self._cp(_CP_FLUSH_BEFORE_CONTAINER)
        self._upload_pending_locked()
        entries: list[dict] = []
        if self._chunk_rows:
            entries.append({"chunks": self._chunk_rows})
        entries.extend(self._journal_entries)
        if entries:
            self._cp(_CP_FLUSH_BETWEEN_PUTS)
            self._call(self.client.put,
                       self._journal_key(self.epoch, self._next_journal),
                       json.dumps(entries).encode())
            self._cp(_CP_FLUSH_AFTER_JOURNAL)
            self._next_journal += 1
            self._chunk_rows = []
            self._journal_entries = []
        self._dirty = False

    def _stage(self, cid: int, base: int, payload: bytes) -> tuple:
        crc = crc32c(payload)
        with self._io_lock:
            kind = _KIND_RAW if base < 0 else _KIND_DELTA
            if (self._pending and len(self._pending) + len(payload)
                    > self._max_object_bytes):
                self._upload_pending_locked()   # roll to the next part
            seq, off = self._cur_seq, len(self._pending)
            self._pending += payload
            self._chunk_rows.append([cid, kind, base if kind else -1,
                                     seq, off, len(payload), crc])
            self._dirty = True
        entry = (kind, base if kind else -1,
                 (seq << _OBJ_SHIFT) | off, len(payload))
        self._index[cid] = entry
        self._crcs[cid] = crc
        return entry

    def put_raw(self, cid: int, data: bytes) -> None:
        self._stage(cid, -1, data)
        self._cache.put(cid, data)

    def put_delta(self, cid: int, base: int, patch: bytes,
                  data: bytes | None = None) -> None:
        self._stage(cid, base, patch)
        if data is not None:
            self._cache.put(cid, data)

    def put_many(self, records: Sequence[tuple[int, int, bytes,
                                               bytes | None]]) -> None:
        for cid, base, payload, data in records:
            self._stage(cid, base, payload)
            if base < 0:
                data = payload
            if data is not None:
                self._cache.put(cid, data)

    def add_recipe(self, chunk_ids: Sequence[int],
                   lengths: Sequence[int] | None = None) -> int:
        recipe = [int(c) for c in chunk_ids]
        self._recipes.append(recipe)
        if recipe:
            self._max_recipe_cid = max(self._max_recipe_cid, max(recipe))
        handle = len(self._recipes) - 1
        entry: dict = {"recipe": recipe}
        if lengths is not None:
            lens = [int(n) for n in lengths]
            self._recipe_lens[handle] = lens
            entry["lens"] = lens
        with self._io_lock:
            self._journal_entries.append(entry)
            self._dirty = True
        return handle

    def retire_recipe(self, handle: int) -> None:
        self.recipe(handle)                 # raises on unknown/retired
        self._recipes[handle] = None
        self._recipe_lens.pop(handle, None)
        with self._io_lock:
            self._journal_entries.append({"retire": handle})
            self._dirty = True
            self._cp(_CP_RETIRE_BEFORE_FLUSH)
            # durable-tombstone parity with FileBackend's fsync: the PUT
            # completes before delete() returns, so a crash cannot
            # resurrect the stream
            self._flush_locked()

    def drop_chunks(self, cids: Sequence[int]) -> None:
        """Quarantine: durably un-index ``cids`` (scrub --repair, §13.3).
        The ``{"quarantine": [...]}`` journal entry is flushed (PUT)
        before this returns, so every later open agrees; the payload
        bytes stay in their container objects until the next compaction
        sweeps them. Callers guarantee no live recipe still references
        the cids and nothing deltas against them."""
        cids = sorted(int(c) for c in cids)
        if not cids:
            return
        with self._io_lock:
            self._journal_entries.append({"quarantine": cids})
            self._dirty = True
            self._flush_locked()
        dropped = set()
        for cid in cids:
            if self._index.pop(cid, None) is not None:
                dropped.add(cid)
            self._crcs.pop(cid, None)
            self._max_recipe_cid = max(self._max_recipe_cid, cid)
        self._cache.retain(lambda cid: cid not in dropped)
        if self._tier is not None:
            self._tier.retain(lambda cid: cid not in dropped)

    def storage_bytes(self) -> int:
        self.flush()
        return sum(size for _, size
                   in self._call(self.client.list, f"e{self.epoch:08d}/"))

    def rewrite_live(self, records: Iterable[tuple[int, int, int,
                                                   bytes]]) -> None:
        """Compaction commit (§11.4): stream the live set into fresh
        ``e{epoch+1}/`` container objects plus one consolidated journal,
        then flip ``manifest.json`` — the single atomic PUT that makes
        the new epoch the one ``_scan`` will replay — then delete the
        old epoch's objects. A crash before the flip leaves stale
        new-epoch objects (cleaned on next open); after it, stale
        old-epoch objects (ditto). Runs under the store's exclusive
        lifecycle lock, so no reads are in flight across the index swap."""
        with self._io_lock:
            self._flush_locked()    # nothing buffered crosses the flip
        old_epoch, new_epoch = self.epoch, self.epoch + 1
        new_index: dict[int, tuple[int, int, int, int]] = {}
        new_crcs: dict[int, int] = {}
        rows: list[list[int]] = []
        buf = bytearray()
        seq = 0
        for cid, kind, base, payload in records:
            if buf and len(buf) + len(payload) > self._max_object_bytes:
                self._call(self.client.put,
                           self._chunk_key(new_epoch, seq), bytes(buf))
                buf = bytearray()
                seq += 1
            off = len(buf)
            buf += payload
            crc = crc32c(payload)
            rows.append([cid, kind, base, seq, off, len(payload), crc])
            new_index[cid] = (kind, base, (seq << _OBJ_SHIFT) | off,
                              len(payload))
            new_crcs[cid] = crc
        if buf:
            self._call(self.client.put, self._chunk_key(new_epoch, seq),
                       bytes(buf))
            seq += 1
        self._cp(_CP_COMPACT_CONTAINERS_PUT)
        # consolidated recipe table: retired slots collapse to null
        # (tombstones dropped, handles stay stable — protocol contract)
        recipes_entry = {"recipes": [
            None if r is None else [r, self._recipe_lens.get(h)]
            for h, r in enumerate(self._recipes)]}
        self._call(self.client.put, self._journal_key(new_epoch, 0),
                   json.dumps([{"chunks": rows}, recipes_entry]).encode())
        self._cp(_CP_COMPACT_JOURNAL_PUT)
        self._call(self.client.put, _MANIFEST_KEY,
                   json.dumps({"epoch": new_epoch}).encode())     # the flip
        self._cp(_CP_COMPACT_MANIFEST_FLIPPED)
        for key, _ in self._call(self.client.list, f"e{old_epoch:08d}/"):
            self._call(self.client.delete_object, key)
        self.epoch = new_epoch
        self._index = new_index
        self._crcs = new_crcs
        self._cache.retain(new_index.__contains__)
        if self._tier is not None:
            # swept cids leave the tier now; entries whose payload the
            # rebase rewrote fail their next crc re-check and drop then
            self._tier.retain(new_index.__contains__)
        self._cur_seq = seq
        self._next_journal = 1
        self._dirty = False

    def scrub_stream(self):
        """Streaming scrub source (§14.5): ``(payload_requests, iter)``
        where the iterator yields ``(cid, payload | None)`` for every
        indexed chunk and ``payload_requests`` counts the client GETs it
        will cost — **one full GET per container object** instead of one
        ranged GET per chunk (the §13 scrub's per-record path). ``None``
        means the chunk's bytes are unreadable (container object missing
        or too short); scrub classifies those. Bypasses the decode cache
        and the disk tier by design — scrub verifies what the *store*
        holds, not what a cache holds."""
        self.flush()
        by_seq: dict[int, list[tuple[int, int, int]]] = {}
        for cid, (kind, base, voff, length) in self._index.items():
            by_seq.setdefault(voff >> _OBJ_SHIFT, []).append(
                (voff & _OBJ_MASK, length, cid))

        def stream():
            for seq in sorted(by_seq):
                key = self._chunk_key(self.epoch, seq)
                try:
                    blob = self._call(self.client.get, key)
                except (KeyError, OSError):
                    blob = None
                extents = sorted(by_seq[seq])
                if blob is None:
                    for _, _, cid in extents:
                        yield cid, None
                    continue
                view = memoryview(blob)
                for off, length, cid in extents:
                    if off + length > len(blob):
                        yield cid, None     # short object: torn record
                    else:
                        yield cid, bytes(view[off:off + length])

        return len(by_seq), stream()

    def flush(self) -> None:
        with self._io_lock:
            self._flush_locked()

    def close(self) -> None:
        self.flush()
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
        close = getattr(self.client, "close", None)
        if close is not None:
            close()

    # --- open-time recovery --------------------------------------------------

    def _scan(self) -> None:
        cl = self.client
        try:
            manifest = json.loads(self._call(cl.get, _MANIFEST_KEY))
        except KeyError:
            manifest = None
        self._manifest_missing = manifest is None
        all_objects = self._call(cl.list, "")
        if manifest is None:
            # a crash before the very first manifest PUT: whatever
            # landed was never addressable — start clean
            self.epoch = 0
            for key, _ in all_objects:
                if key != _MANIFEST_KEY:
                    self._call(cl.delete_object, key)
            return
        self.epoch = int(manifest["epoch"])
        prefix = f"e{self.epoch:08d}/"
        chunk_prefix = prefix + "chunks/"
        journal_prefix = prefix + "journal/"
        sizes: dict[int, int] = {}
        journals: list[tuple[int, str]] = []
        stale: list[str] = []
        for key, size in all_objects:
            if key == _MANIFEST_KEY:
                continue
            if key.startswith(chunk_prefix):
                sizes[int(key[len(chunk_prefix):])] = size
            elif key.startswith(journal_prefix):
                journals.append((int(key[len(journal_prefix):-len(".json")]),
                                 key))
            else:       # another epoch: an interrupted compaction's debris
                stale.append(key)
        journals.sort()
        self._next_journal = journals[-1][0] + 1 if journals else 0
        for _, key in journals:
            for entry in json.loads(self._call(cl.get, key)):
                self._replay(entry)
        # drop index entries whose container object vanished or is too
        # short to hold them, then their delta dependents (a patch with
        # a lost base can never decode)
        lost = set()
        for cid, (kind, base, voff, length) in self._index.items():
            size = sizes.get(voff >> _OBJ_SHIFT)
            if size is None or (voff & _OBJ_MASK) + length > size:
                lost.add(cid)
        changed = bool(lost)
        while changed:
            changed = False
            for cid, (kind, base, _, _) in self._index.items():
                if kind == _KIND_DELTA and base in lost and cid not in lost:
                    lost.add(cid)
                    changed = True
        for cid in lost:
            del self._index[cid]
            self._crcs.pop(cid, None)
        # recovery-retire recipes naming chunks we no longer hold; the
        # retires are journaled durably so every later open agrees
        # (exactly the file backend's torn-tail policy, §10.6 — the ids
        # stay burned via _max_recipe_cid, never reissued)
        retired = []
        for h, recipe in enumerate(self._recipes):
            if recipe is not None and any(c not in self._index
                                          for c in recipe):
                self._recipes[h] = None
                self._recipe_lens.pop(h, None)
                retired.append(h)
        if retired:
            self._call(cl.put, self._journal_key(self.epoch,
                                                 self._next_journal),
                       json.dumps([{"retire": h} for h in retired]).encode())
            self._next_journal += 1
        # orphan containers (uploaded, journal PUT never landed) and
        # stale-epoch debris are unreachable: delete both
        referenced = {voff >> _OBJ_SHIFT
                      for _, _, voff, _ in self._index.values()}
        for seq in sorted(set(sizes) - referenced):
            self._call(cl.delete_object, self._chunk_key(self.epoch, seq))
        for key in stale:
            self._call(cl.delete_object, key)
        self._cur_seq = max(sizes, default=-1) + 1

    def _replay(self, entry: dict) -> None:
        if "chunks" in entry:
            for row in entry["chunks"]:
                # pre-§13 journals have 6-element rows (no crc); those
                # records replay fine and scrub as ``unverifiable``
                cid, kind, base, seq, off, length = (int(v)
                                                     for v in row[:6])
                self._index[cid] = (kind, base,
                                    (seq << _OBJ_SHIFT) | off, length)
                if len(row) > 6:
                    self._crcs[cid] = int(row[6])
        elif "quarantine" in entry:
            # scrub --repair dropped these cids (§13.3): un-index them
            # and burn their ids so they are never reissued
            for cid in entry["quarantine"]:
                cid = int(cid)
                self._index.pop(cid, None)
                self._crcs.pop(cid, None)
                self._max_recipe_cid = max(self._max_recipe_cid, cid)
        elif "recipe" in entry:
            recipe = [int(c) for c in entry["recipe"]]
            self._recipes.append(recipe)
            if recipe:
                self._max_recipe_cid = max(self._max_recipe_cid,
                                           max(recipe))
            if entry.get("lens") is not None:
                self._recipe_lens[len(self._recipes) - 1] = [
                    int(n) for n in entry["lens"]]
        elif "retire" in entry:
            h = int(entry["retire"])
            if 0 <= h < len(self._recipes):
                self._recipes[h] = None
                self._recipe_lens.pop(h, None)
        elif "recipes" in entry:            # consolidated (compaction)
            self._recipes = []
            self._recipe_lens = {}
            for slot in entry["recipes"]:
                if slot is None:
                    self._recipes.append(None)
                    continue
                recipe, lens = slot
                h = len(self._recipes)
                self._recipes.append([int(c) for c in recipe])
                if recipe:
                    self._max_recipe_cid = max(self._max_recipe_cid,
                                               max(recipe))
                if lens is not None:
                    self._recipe_lens[h] = [int(n) for n in lens]


def _s3_backend(bucket: str, prefix: str = "", **kwargs):
    """Registry factory for ``DedupConfig(backend="s3")``: a real boto3
    client behind the same ObjectStoreBackend (boto3 gated at call time)."""
    return ObjectStoreBackend(client=S3ObjectClient(bucket, prefix),
                              **kwargs)


# When executed as ``python -m repro.api.objectstore`` this module first
# loads under the name ``__main__``; the registry will import it again
# under its real name, and double registration is a hard error — so only
# the canonical import registers (the __main__ stanza at the bottom
# defers to the canonical module for everything).
if __name__ != "__main__":
    register_backend("objectstore")(ObjectStoreBackend)
    register_backend("s3")(_s3_backend)


# --- CLI: cp / ls / stat / verify over one store root (§11.6) ----------------

_CATALOG = "catalog.json"
_URL_SCHEME = "obj://"


def _human(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024 or unit == "TiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} TiB"       # pragma: no cover


def _split_obj_url(url: str) -> tuple[Path, str | None]:
    """``obj://ROOT`` or ``obj://ROOT/NAME`` -> (root, name|None).

    Resolution: a trailing slash, an existing directory, or a path with
    no surrounding catalog is the store *root*; a path whose parent
    holds ``catalog.json`` is ROOT/NAME. So ``cp f.bin obj://backups``
    names the object ``f.bin`` inside ``backups`` whether or not the
    store exists yet, and ``obj://backups/f.bin`` picks one object of
    an existing store."""
    rest = url[len(_URL_SCHEME):]
    if not rest:
        raise SystemExit(f"bad object URL {url!r}: empty path")
    if rest.endswith("/"):
        return Path(rest.rstrip("/")), None
    p = Path(rest)
    if (p / _CATALOG).is_file() or p.is_dir():
        return p, None
    if (p.parent / _CATALOG).is_file():
        return p.parent, p.name
    return p, None              # a store root that does not exist yet


class _CliStore:
    """One CLI invocation's session over a store root: the catalog plus
    a DedupStore built from the catalog's pinned config.

    The catalog persists what the in-memory store cannot recover from
    the backend alone: object names -> (stream handle, SHA-256, sizes)
    and the exact-dedup digest table (``DedupStore.digest_seeds``), so
    a later ``cp`` into the same root still dedups byte-identical
    chunks across invocations. Detector *resemblance* state is not
    persisted — a reopened store delta-compresses only against chunks
    it sees in its own invocation (documented limitation, §11.6)."""

    def __init__(self, root: Path, detector: str = "finesse",
                 chunk_size: int | None = None,
                 create: bool = False, latency: float = 0.0,
                 verify_reads: bool = False) -> None:
        # local import: config imports the store; keeping it out of
        # module scope keeps backend-only users import-light
        from repro.api.config import DedupConfig, build_store
        self.root = Path(root)
        self._cat_path = self.root / _CATALOG
        if self._cat_path.is_file():
            self.cat = json.loads(self._cat_path.read_text())
        elif create:
            self.root.mkdir(parents=True, exist_ok=True)
            chunker_args = ({"avg_size": int(chunk_size)}
                            if chunk_size else {})
            self.cat = {"config": {"detector": detector,
                                   "chunker": "fastcdc",
                                   "chunker_args": chunker_args,
                                   "backend": "objectstore",
                                   "backend_args": {"path": "objects"}},
                        "files": {}, "digests": {}}
        else:
            raise SystemExit(f"no object store at {self.root} "
                             f"(missing {_CATALOG})")
        cfg_dict = json.loads(json.dumps(self.cat["config"]))  # deep copy
        args = cfg_dict.setdefault("backend_args", {})
        # the catalog stores the object root relative to itself so the
        # whole store directory stays relocatable
        args["path"] = str(self.root / args.get("path", "objects"))
        if latency:
            args["latency"] = latency
        if verify_reads:
            cfg_dict["verify_reads"] = True
        self.cfg = DedupConfig.from_dict(cfg_dict)
        self.store = build_store(self.cfg)
        self._fitted = False
        seeds = {bytes.fromhex(k): int(v)
                 for k, v in self.cat.get("digests", {}).items()}
        if seeds:
            self.store.seed_digests(seeds)

    @property
    def files(self) -> dict:
        return self.cat["files"]

    def ingest(self, src: Path, name: str | None) -> tuple[str, dict]:
        data = src.read_bytes()
        name = name or src.name
        if self.cat["config"]["detector"] == "card" and not self._fitted:
            # CARD's context model needs an offline fit; train it on the
            # first incoming file of this invocation (§5)
            self.store.fit([data])
            self._fitted = True
        old = self.files.get(name)
        if old is not None:     # cp over an existing name replaces it
            self.store.delete(old["handle"])
        with self.store.open_stream() as s:
            s.write(data)
        rep = s.report
        entry = {"handle": rep.handle,
                 "sha256": hashlib.sha256(data).hexdigest(),
                 "bytes": rep.bytes_in, "stored": rep.bytes_stored,
                 "chunks": rep.chunks, "dup_chunks": rep.dup_chunks,
                 "delta_chunks": rep.delta_chunks}
        self.files[name] = entry
        return name, entry

    def save(self) -> None:
        self.store.backend.flush()
        self.cat["digests"] = {dig.hex(): cid for dig, cid
                               in self.store.digest_seeds().items()}
        tmp = self._cat_path.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(self.cat, indent=1))
        os.replace(tmp, self._cat_path)

    def close(self) -> None:
        self.store.close()


def _cmd_cp(args) -> int:
    srcs, dst = list(args.src), args.dst
    to_store = dst.startswith(_URL_SCHEME)
    from_store = any(s.startswith(_URL_SCHEME) for s in srcs)
    if to_store == from_store:
        raise SystemExit("cp needs exactly one obj:// side "
                         "(local -> store or store -> local)")
    if to_store:
        root, name = _split_obj_url(dst)
        if name is not None and len(srcs) > 1:
            raise SystemExit(f"cannot copy {len(srcs)} files onto the "
                             f"single object name {name!r}")
        st = _CliStore(root, detector=args.detector,
                       chunk_size=args.chunk_size, create=True)
        try:
            for s in srcs:
                src = Path(s)
                n, e = st.ingest(src, name)
                print(f"{src} -> {_URL_SCHEME}{root}/{n}  "
                      f"{_human(e['bytes'])} logical, "
                      f"{_human(e['stored'])} stored  "
                      f"(dcr {e['bytes'] / max(1, e['stored']):.2f})")
            st.save()
        finally:
            st.close()
        return 0
    if len(srcs) != 1:
        raise SystemExit("store -> local cp takes exactly one source")
    root, name = _split_obj_url(srcs[0])
    if name is None:
        raise SystemExit(f"source {srcs[0]!r} must name one object "
                         f"({_URL_SCHEME}ROOT/NAME)")
    st = _CliStore(root)
    try:
        entry = st.files.get(name)
        if entry is None:
            raise SystemExit(f"no object {name!r} in {root} "
                             f"(see: ls {_URL_SCHEME}{root})")
        data = st.store.restore(entry["handle"])
        if hashlib.sha256(data).hexdigest() != entry["sha256"]:
            raise SystemExit(f"restore of {name!r} failed its SHA-256 "
                             "check; not writing corrupt output")
        out = Path(args.dst)
        if out.is_dir():
            out = out / name
        out.write_bytes(data)
        print(f"{srcs[0]} -> {out}  {_human(len(data))} (sha256 ok)")
    finally:
        st.close()
    return 0


def _cmd_ls(args) -> int:
    root, _ = _split_obj_url(args.url)
    cat_path = root / _CATALOG
    if not cat_path.is_file():
        raise SystemExit(f"no object store at {root} (missing {_CATALOG})")
    files = json.loads(cat_path.read_text())["files"]
    print(f"{'LOGICAL':>12}  {'STORED':>12}  {'DCR':>6}  NAME")
    tot_in = tot_st = 0
    for name in sorted(files):
        e = files[name]
        tot_in += e["bytes"]
        tot_st += e["stored"]
        print(f"{_human(e['bytes']):>12}  {_human(e['stored']):>12}  "
              f"{e['bytes'] / max(1, e['stored']):>6.2f}  {name}")
    print(f"{_human(tot_in):>12}  {_human(tot_st):>12}  "
          f"{tot_in / max(1, tot_st):>6.2f}  ({len(files)} objects)")
    return 0


def _cmd_stat(args) -> int:
    root, _ = _split_obj_url(args.url)
    cat_path = root / _CATALOG
    if not cat_path.is_file():
        raise SystemExit(f"no object store at {root} (missing {_CATALOG})")
    cat = json.loads(cat_path.read_text())
    files = cat["files"]
    logical = sum(e["bytes"] for e in files.values())
    # physical truth from the object tree itself, not the catalog: this
    # is what a bucket bill would charge
    objects = LocalObjectStore(root / cat["config"]["backend_args"]
                               .get("path", "objects"))
    listing = objects.list("")
    physical = sum(size for _, size in listing)
    chunks = sum(1 for key, _ in listing if "/chunks/" in key)
    journals = sum(1 for key, _ in listing if "/journal/" in key)
    print(f"store root      {root}")
    print(f"objects (files) {len(files)}")
    print(f"logical bytes   {logical} ({_human(logical)})")
    print(f"physical bytes  {physical} ({_human(physical)})")
    print(f"space saved     {100.0 * (1 - physical / max(1, logical)):.1f}%"
          f"  (dcr {logical / max(1, physical):.2f})")
    print(f"container objs  {chunks}")
    print(f"journal objs    {journals}")
    print(f"detector        {cat['config']['detector']}")
    return 0


def _cmd_verify(args) -> int:
    from repro.api.integrity import CorruptChunkError
    root, name = _split_obj_url(args.url)
    st = _CliStore(root, verify_reads=True)
    failed = 0
    try:
        names = args.names or ([name] if name else sorted(st.files))
        for n in names:
            entry = st.files.get(n)
            if entry is None:
                print(f"FAIL  {n}  (not in catalog)")
                failed += 1
                continue
            try:
                data = st.store.restore(entry["handle"])
            except CorruptChunkError as e:
                # the per-record crc32c caught it before SHA could (§13.2)
                print(f"FAIL  {n}  ({e})")
                failed += 1
                continue
            ok = (len(data) == entry["bytes"] and
                  hashlib.sha256(data).hexdigest() == entry["sha256"])
            rep = st.store.last_restore
            detail = (f"{_human(len(data))}, {rep.requests} reads, "
                      f"{_human(rep.bytes_read)} fetched")
            if ok:
                print(f"ok    {n}  ({detail})")
            else:
                print(f"FAIL  {n}  (restored bytes do not match the "
                      f"recorded SHA-256; {detail})")
                failed += 1
    finally:
        st.close()
    print(f"{len(names) - failed}/{len(names)} objects verified")
    return 1 if failed else 0


def _cmd_scrub(args) -> int:
    root, _ = _split_obj_url(args.url)
    st = _CliStore(root)
    try:
        report = st.store.scrub(repair=args.repair)
        print(f"chunks          {report.chunks} "
              f"({report.verified} verified, "
              f"{report.unverifiable} unverifiable)")
        print(f"bytes checked   {_human(report.bytes_checked)}")
        naive = report.payload_requests_naive
        if naive and report.payload_requests < naive:
            saved = naive - report.payload_requests
            print(f"GET requests    {report.payload_requests} streamed "
                  f"(vs {naive} per-chunk: {saved} saved, "
                  f"{100.0 * saved / naive:.0f}%)")
        print(f"streams         {report.streams}")
        if report.corrupt:
            print(f"CORRUPT chunks  {list(report.corrupt)}")
            for cid, n in sorted(report.blast_radius.items()):
                print(f"  cid {cid}: blast radius {n} stream(s)")
        if report.missing:
            print(f"MISSING chunks  {list(report.missing)}")
        if report.streams_lost:
            print(f"streams lost    {list(report.streams_lost)}")
        for err in report.structural_errors:
            print(f"structural      {err}")
        if report.repaired:
            print(f"repaired: quarantined {len(report.quarantined)} "
                  f"chunk(s), retired {len(report.retired_streams)} "
                  f"stream(s)")
            post = st.store.scrub()
            print(f"post-repair     {'clean' if post.clean else 'DIRTY'}")
            return 0 if post.clean else 1
        print("clean" if report.clean else "DIRTY (rerun with --repair "
              "to quarantine)")
        return 0 if report.clean else 1
    finally:
        st.close()


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api.objectstore",
        description="Deduplicated object-store front door (DESIGN.md "
                    "§11.6): copy files into a chunk-deduplicated, "
                    "delta-compressed object tree and restore them "
                    "SHA-verified. Store URLs look like obj://DIR or "
                    "obj://DIR/NAME.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    cp = sub.add_parser("cp", help="copy local files into a store, or "
                                   "one object back out")
    cp.add_argument("src", nargs="+",
                    help="local file(s), or one obj://ROOT/NAME source")
    cp.add_argument("dst", help="obj://ROOT[/NAME], or a local path")
    cp.add_argument("--detector", default="finesse",
                    help="resemblance detector for a NEW store "
                         "(finesse/card/dedup-only; default finesse — "
                         "card additionally trains its context model on "
                         "the first file)")
    cp.add_argument("--chunk-size", type=int, default=None,
                    help="average CDC chunk size for a NEW store (bytes)")
    ls = sub.add_parser("ls", help="list objects: logical vs stored "
                                   "bytes and per-file DCR")
    ls.add_argument("url", help="obj://ROOT")
    st = sub.add_parser("stat", help="whole-store accounting (logical "
                                     "vs physical bytes, object counts)")
    st.add_argument("url", help="obj://ROOT")
    vf = sub.add_parser("verify", help="restore object(s) with verified "
                                       "reads (per-chunk crc32c) and "
                                       "check SHA-256 against the catalog")
    vf.add_argument("url", help="obj://ROOT or obj://ROOT/NAME")
    vf.add_argument("names", nargs="*",
                    help="object names (default: every object)")
    sc = sub.add_parser("scrub", help="fsck the store: verify every "
                                      "record checksum, recipe "
                                      "reachability, refcounts; exit 1 "
                                      "when dirty")
    sc.add_argument("url", help="obj://ROOT")
    sc.add_argument("--repair", action="store_true",
                    help="quarantine corrupt chunks and retire dependent "
                         "streams (exit reflects the post-repair scrub)")
    args = ap.parse_args(argv)
    return {"cp": _cmd_cp, "ls": _cmd_ls, "stat": _cmd_stat,
            "verify": _cmd_verify, "scrub": _cmd_scrub}[args.cmd](args)


if __name__ == "__main__":      # pragma: no cover - thin; logic is main()
    # defer to the canonical module so backends register exactly once
    from repro.api import objectstore as _canonical
    sys.exit(_canonical.main(sys.argv[1:]))
