from repro.data.workloads import (  # noqa: F401
    WorkloadConfig,
    make_workload,
    sql_dump_versions,
    vmdk_versions,
    kernel_versions,
)
from repro.data.tokens import TokenPipeline, TokenPipelineConfig  # noqa: F401
