"""Synthetic versioned-backup workloads reproducing the paper's datasets.

The paper evaluates on (1) a SQL-dump backup series, (2) VMDK image backups,
(3) Linux-kernel source trees. Those traces aren't shipped, so we generate
version chains with the *edit statistics* each one exhibits:

  * sql_dump: record-structured text; each version appends rows and applies
    localized in-place edits to a small fraction of rows (backup-with-growth
    pattern — mostly appends, light churn).
  * vmdk: block-structured binary; each version rewrites randomly scattered
    blocks (random-modification pattern the paper calls out in §5.2).
  * kernel: many small structured files; each version inserts/deletes lines
    in a subset of files (shift-heavy pattern — the case that breaks
    content-only features, paper §3).

All generators are deterministic in `seed`.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    base_size: int = 4 << 20   # bytes per version (approx)
    versions: int = 6
    seed: int = 1234


def _record(rng: np.random.Generator, width: int = 96) -> bytes:
    """One structured text 'row' (CSV-ish, compressible like a SQL dump)."""
    rid = rng.integers(0, 10**9)
    name = bytes(rng.integers(97, 123, size=12, dtype=np.uint8))
    blob = bytes(rng.integers(32, 127, size=width, dtype=np.uint8))
    return b"INSERT INTO t VALUES (%d,'%s','%s');\n" % (rid, name, blob)


def sql_dump_versions(cfg: WorkloadConfig = WorkloadConfig()) -> list[bytes]:
    rng = np.random.Generator(np.random.PCG64(cfg.seed))
    rows = []
    size = 0
    while size < cfg.base_size:
        r = _record(rng)
        rows.append(r)
        size += len(r)
    versions = []
    for _ in range(cfg.versions):
        versions.append(b"".join(rows))
        # churn: modify ~0.5% of rows in place, append ~2% new rows
        n = len(rows)
        for idx in rng.integers(0, n, size=max(1, n // 200)):
            rows[int(idx)] = _record(rng)
        for _ in range(max(1, n // 50)):
            rows.append(_record(rng))
    return versions


def vmdk_versions(cfg: WorkloadConfig = WorkloadConfig()) -> list[bytes]:
    rng = np.random.Generator(np.random.PCG64(cfg.seed + 1))
    block = 4096
    nblocks = cfg.base_size // block
    # half the image is low-entropy (zeros / repeated fs metadata), half random
    img = np.zeros((nblocks, block), dtype=np.uint8)
    data_blocks = rng.permutation(nblocks)[: nblocks // 2]
    img[data_blocks] = rng.integers(0, 256, size=(len(data_blocks), block), dtype=np.uint8)
    versions = []
    for _ in range(cfg.versions):
        versions.append(img.tobytes())
        # rewrite ~1% of blocks at random positions (random edit pattern)
        touch = rng.permutation(nblocks)[: max(1, nblocks // 100)]
        img = img.copy()
        img[touch] = rng.integers(0, 256, size=(len(touch), block), dtype=np.uint8)
    return versions


def _source_file(rng: np.random.Generator, lines: int) -> list[bytes]:
    out = []
    for _ in range(lines):
        indent = b" " * int(rng.integers(0, 12))
        body = bytes(rng.integers(97, 123, size=int(rng.integers(8, 60)), dtype=np.uint8))
        out.append(indent + body + b"();\n")
    return out


def kernel_versions(cfg: WorkloadConfig = WorkloadConfig()) -> list[bytes]:
    """Tar-like concatenation of many small files; line insert/delete churn.

    Line edits SHIFT all following bytes — the modification pattern that
    breaks content-only sub-chunk features (paper §3, Chunk_H case).
    """
    rng = np.random.Generator(np.random.PCG64(cfg.seed + 2))
    nfiles = max(8, cfg.base_size // (16 << 10))
    files = [_source_file(rng, int(rng.integers(100, 500))) for _ in range(nfiles)]
    versions = []
    for _ in range(cfg.versions):
        stream = bytearray()
        for i, f in enumerate(files):
            stream += b"==== file %d ====\n" % i
            for line in f:
                stream += line
        versions.append(bytes(stream))
        # edit ~10% of files: insert/delete/modify a few lines each
        for idx in rng.permutation(nfiles)[: max(1, nfiles // 10)]:
            f = files[int(idx)]
            for _ in range(int(rng.integers(1, 6))):
                op = rng.integers(0, 3)
                pos = int(rng.integers(0, max(1, len(f))))
                if op == 0 and f:            # delete
                    del f[pos % len(f)]
                elif op == 1:                 # insert
                    f.insert(pos, _source_file(rng, 1)[0])
                elif f:                       # modify
                    f[pos % len(f)] = _source_file(rng, 1)[0]
    return versions


_GENERATORS = {
    "sql_dump": sql_dump_versions,
    "vmdk": vmdk_versions,
    "kernel": kernel_versions,
}


def make_workload(name: str, cfg: WorkloadConfig | None = None) -> list[bytes]:
    if name not in _GENERATORS:
        raise KeyError(f"unknown workload {name!r}; options: {sorted(_GENERATORS)}")
    return _GENERATORS[name](cfg or WorkloadConfig())
