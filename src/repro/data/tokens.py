"""Deterministic synthetic LM token pipeline.

Production shape: each data-parallel host owns a disjoint shard of the
global batch, derived purely from (step, shard_index) — so a restarted or
elastically rescheduled worker regenerates exactly its shard (no shared
state, no coordination; DESIGN.md §6 straggler/restart story). Real
deployments swap `_tokens_for` with a tokenized-corpus reader keeping the
same (step, shard) -> batch contract.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    shards: int = 1
    seed: int = 17


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        assert cfg.global_batch % cfg.shards == 0
        self.cfg = cfg
        self.per_shard = cfg.global_batch // cfg.shards

    def _tokens_for(self, step: int, shard: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.Generator(
            np.random.PCG64(((cfg.seed * 1_000_003 + step) << 16) | shard))
        # zipf-ish marginals so the loss curve is non-trivial
        z = rng.zipf(1.3, size=(self.per_shard, cfg.seq_len + 1))
        return np.minimum(z - 1, cfg.vocab_size - 1).astype(np.int32)

    def batch(self, step: int, shard: int = 0) -> dict[str, np.ndarray]:
        toks = self._tokens_for(step, shard)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        parts = [self.batch(step, s) for s in range(self.cfg.shards)]
        return {k: np.concatenate([p[k] for p in parts], axis=0) for k in parts[0]}
