from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    LM_SHAPES,
    InputShape,
    ModelConfig,
    cells,
    get_config,
    get_shape,
)
