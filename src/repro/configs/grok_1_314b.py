"""grok-1 314B [hf:xai-org/grok-1; unverified] — MoE 8e top-2, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072,
    num_experts=8, experts_per_token=2, moe_layer_period=1,
    moe_ffn_shards=2,  # 16 virtual half-width experts -> EP on a 16-way axis
    act="gelu",  # grok uses gelu experts
)
