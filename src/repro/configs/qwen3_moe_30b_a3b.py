"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B; hf] — MoE 128e top-8, GQA kv=4.

d_ff=768 is the per-expert intermediate size (the config as assigned).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151936,
    num_experts=128, experts_per_token=8, moe_layer_period=1,
    rope_theta=1e6,
)
