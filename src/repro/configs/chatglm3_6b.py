"""ChatGLM3-6B [arXiv:2406.12793; hf] — 2D/partial RoPE, GQA kv=2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    rope_fraction=0.5,  # rotary applied to half of each head (RoPE-2d)
)
