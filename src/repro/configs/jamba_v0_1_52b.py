"""Jamba-v0.1 52B [arXiv:2403.19887; hf] — Mamba+attention 1:7 interleave,
MoE 16e top-2 on every other layer."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    num_experts=16, experts_per_token=2, moe_layer_period=2,
    ssm_state=16, ssm_head_dim=64, ssm_conv_width=4,
    attn_layer_period=8,  # 1 attention layer per 8 (1:7 mamba:attn)
)
