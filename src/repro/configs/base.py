"""Model / input-shape configuration schema + registry.

Every assigned architecture ships as `src/repro/configs/<id>.py` exposing
`CONFIG` (exact numbers from the assignment) and registers here. Reduced
configs for CPU smoke tests come from `ModelConfig.reduced()`.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_layer_period: int = 1   # layer % period == period-1 is MoE
    capacity_factor: float = 1.25
    # virtual-expert split: store each expert as `moe_ffn_shards` half-width
    # experts ([E*s, D, F/s]). Exact for gated/elementwise FFNs (the hidden
    # units are independent), and it turns wide-FFN few-expert models
    # (grok-1: 8e on a 16-way axis) into true EP with all_to_all dispatch
    # instead of replicated-TP compute (EXPERIMENTS.md §Perf).
    moe_ffn_shards: int = 1
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    attn_layer_period: int = 0  # hybrid: one attention layer per period
    # --- position encoding ---
    rope_theta: float = 1e4
    rope_fraction: float = 1.0  # chatglm applies rotary to half the head dim
    # --- VLM ---
    cross_attn_period: int = 0  # one cross-attn-augmented layer per period
    num_image_tokens: int = 0
    # --- enc-dec (audio) ---
    encoder_layers: int = 0
    num_audio_frames: int = 0
    # --- misc ---
    norm_eps: float = 1e-5
    act: str = "swiglu"         # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    @property
    def head_dim(self) -> int:
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic sequence mixers."""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        period = 1
        for per in (self.moe_layer_period if self.num_experts else 1,
                    self.attn_layer_period or 1,
                    self.cross_attn_period or 1):
            period = period * per // __import__("math").gcd(period, per)
        layers = period * max(1, 4 // period)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, layers),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=(min(4, max(1, self.num_kv_heads * 4 // self.num_heads))
                          if self.num_heads else 0),
            d_ff=256,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            num_image_tokens=min(self.num_image_tokens, 16),
            encoder_layers=min(self.encoder_layers, 2),
            num_audio_frames=min(self.num_audio_frames, 32),
        )

    def param_count(self) -> int:
        """Analytic parameter count (used by roofline MODEL_FLOPS)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab_size, self.head_dim
        h, kv = self.num_heads, self.num_kv_heads
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d      # q, k+v, o
        if self.act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        total = 0
        for layer in range(self.num_layers):
            if self.family == "ssm":
                total += self._ssm_layer_params()
                continue
            if self.family == "hybrid":
                is_attn = (self.attn_layer_period and
                           layer % self.attn_layer_period == self.attn_layer_period - 1)
                total += attn if is_attn else self._ssm_layer_params()
            else:
                total += attn
            if self.cross_attn_period and layer % self.cross_attn_period == self.cross_attn_period - 1:
                total += attn
            is_moe = (self.num_experts and
                      layer % self.moe_layer_period == self.moe_layer_period - 1)
            total += (self.num_experts * mlp + d * self.num_experts) if is_moe else mlp
            total += 2 * d  # norms
        total += v * d                       # embed
        if not self.tie_embeddings:
            total += v * d                   # lm_head
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp + 2 * d)
            total += self.num_layers * attn  # decoder cross-attn
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp = 3 * d * f if self.act == "swiglu" else 2 * d * f
        moe_layers = sum(
            1 for layer in range(self.num_layers)
            if layer % self.moe_layer_period == self.moe_layer_period - 1)
        dense_total = self.param_count() - moe_layers * self.num_experts * mlp
        return dense_total + moe_layers * self.experts_per_token * mlp

    def _ssm_layer_params(self) -> int:
        d, n = self.d_model, self.ssm_state
        d_inner = 2 * d
        heads = d_inner // self.ssm_head_dim
        in_proj = d * (2 * d_inner + 2 * n + heads)
        return in_proj + self.ssm_conv_width * (d_inner + 2 * n) + d_inner * d + heads


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


LM_SHAPES = (
    InputShape("train_4k", "train", 4_096, 256),
    InputShape("prefill_32k", "prefill", 32_768, 32),
    InputShape("decode_32k", "decode", 32_768, 128),
    InputShape("long_500k", "decode", 524_288, 1),
)

ARCH_IDS = (
    "grok-1-314b",
    "qwen3-moe-30b-a3b",
    "llama-3.2-vision-11b",
    "granite-8b",
    "chatglm3-6b",
    "phi3-medium-14b",
    "granite-3-8b",
    "mamba2-130m",
    "jamba-v0.1-52b",
    "whisper-base",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; options: {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_shape(name: str) -> InputShape:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


def cells(arch: str) -> list[InputShape]:
    """The dry-run cells for one architecture (skips recorded as absent)."""
    cfg = get_config(arch)
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue  # pure full-attention arch: N/A per assignment
        out.append(s)
    return out
