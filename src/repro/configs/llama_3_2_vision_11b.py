"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Text backbone with cross-attention image layers every 5th layer; the vision
tower is a stub — input_specs() provides precomputed patch embeddings
(DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    cross_attn_period=5, num_image_tokens=1601,
    rope_theta=5e5,
)
