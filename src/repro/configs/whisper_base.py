"""Whisper-base [arXiv:2212.04356; unverified] — enc-dec; conv audio
frontend is a stub (input_specs() provides precomputed frame embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    encoder_layers=6, num_audio_frames=1500,
    act="gelu",
)
