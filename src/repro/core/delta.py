"""xdelta-style byte delta codec.

Greedy COPY/ADD encoding of `target` against `base`:
  * index sampled BLOCK-byte windows of `base` by hash (sorted-array map);
  * scan `target` jumping between hash-hit candidates (vectorized lookup,
    so cost is O(#candidates + #ops), not O(n) python steps); on a verified
    hit, extend the match forwards/backwards with numpy compares and emit
    COPY(base_off, len); bytes between matches become ADD ops.

Wire format (varint = LEB128):
  0x00 <varint len> <bytes>            ADD
  0x01 <varint base_off> <varint len>  COPY

Byte-identical reconstruction is property-tested (hypothesis) in
tests/test_delta.py. Delta encoding stays on host by design — it is
pointer-chasing storage-side work with no TPU analogue (DESIGN.md §3).
"""
from __future__ import annotations

import bisect

import numpy as np

BLOCK = 16
_ADD, _COPY = 0, 1


def _write_varint(out: bytearray, v: int) -> None:
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def _read_varint(buf: bytes, pos: int) -> tuple[int, int]:
    shift = v = 0
    while True:
        b = buf[pos]
        pos += 1
        v |= (b & 0x7F) << shift
        if not b & 0x80:
            return v, pos
        shift += 7


_POLY_P = np.uint32(0x01000193)        # FNV prime, odd => invertible mod 2^32
_POLY_P_INV = np.uint32(pow(int(_POLY_P), -1, 1 << 32))
_pow_cache = np.ones(1, np.uint32)     # p^0..; grown on demand
_ipow_cache = np.full(1, _POLY_P_INV)  # p^-1, p^-2, ...


def _powers(n: int) -> tuple[np.ndarray, np.ndarray]:
    """(p^0..p^{n-1}, p^-1..p^-n) with wraparound, cached across calls."""
    global _pow_cache, _ipow_cache
    if len(_pow_cache) < n:
        m = max(n, 2 * len(_pow_cache))
        _pow_cache = np.cumprod(np.full(m, _POLY_P, np.uint32),
                                dtype=np.uint32) * _POLY_P_INV  # p^0..p^{m-1}
        _ipow_cache = np.cumprod(np.full(m, _POLY_P_INV, np.uint32),
                                 dtype=np.uint32)               # p^-1..p^-m
    return _pow_cache[:n], _ipow_cache[:n]


def _block_hashes(buf: np.ndarray) -> np.ndarray:
    """Polynomial hash of every BLOCK-byte window (stride 1), by prefix
    sums: S_i = sum_{j<i} b_j p^{-(j+1)}, hash(l, l+B) = (S_{l+B} - S_l)
    * p^{l+B} — three vectorized passes instead of one per window byte
    (this runs twice per delta encode on the ingest hot path)."""
    n = len(buf)
    if n < BLOCK:
        return np.zeros(0, np.uint32)
    pows, ipows = _powers(n + 1)
    s = np.zeros(n + 1, np.uint32)
    np.cumsum(buf.astype(np.uint32) * ipows[:n], dtype=np.uint32, out=s[1:])
    return (s[BLOCK:] - s[:-BLOCK]) * pows[BLOCK:]


def _first_mismatch(a: np.ndarray, b: np.ndarray) -> int:
    """Length of the common prefix of two equal-length uint8 arrays."""
    neq = a != b
    if not neq.any():
        return len(a)
    return int(np.argmax(neq))


def encode(target: bytes, base: bytes) -> bytes:
    """Delta of `target` against `base` (COPY/ADD stream)."""
    t = np.frombuffer(target, dtype=np.uint8)
    b = np.frombuffer(base, dtype=np.uint8)
    n = len(t)
    out = bytearray()

    cand_pos = np.zeros(0, np.int64)
    cand_off = np.zeros(0, np.int64)
    if len(b) >= BLOCK and n >= BLOCK:
        bh = _block_hashes(b)
        samp = np.arange(0, len(bh), BLOCK)
        keys = bh[samp]
        order = np.argsort(keys, kind="stable")
        keys_sorted = keys[order]
        offs_sorted = samp[order]
        # keep first offset per duplicate key
        first = np.concatenate([[True], keys_sorted[1:] != keys_sorted[:-1]])
        keys_u, offs_u = keys_sorted[first], np.minimum.reduceat(
            offs_sorted, np.flatnonzero(first))
        th = _block_hashes(t)
        # 16-bit bitmap prefilter: the binary search over every target
        # position was ~half of encode wall time; one gather drops the
        # non-candidates (~<1% survive) before searchsorted runs
        bitmap = np.zeros(1 << 16, bool)
        bitmap[keys_u & 0xFFFF] = True
        maybe = np.flatnonzero(bitmap[th & 0xFFFF])
        idx = np.searchsorted(keys_u, th[maybe])
        idx = np.clip(idx, 0, len(keys_u) - 1)
        hit = keys_u[idx] == th[maybe]
        cand_pos = maybe[hit]
        cand_off = offs_u[idx[hit]]

    add_start = 0

    def flush_add(end: int) -> None:
        if end > add_start:
            out.append(_ADD)
            _write_varint(out, end - add_start)
            out.extend(target[add_start:end])

    i = 0
    ci = 0  # cursor into candidate arrays
    nc = len(cand_pos)
    # python ints + bytes slices in the scan loop: the per-candidate numpy
    # calls (searchsorted/array_equal on tiny arrays) were pure dispatch
    # overhead — ~30% of encode wall time on the ingest path
    cand_pos_l = cand_pos.tolist()
    cand_off_l = cand_off.tolist()
    while ci < nc:
        # jump to the next candidate at or after i
        ci = bisect.bisect_left(cand_pos_l, i, ci)
        if ci >= nc:
            break
        pos = cand_pos_l[ci]
        off = cand_off_l[ci]
        ci += 1
        if target[pos:pos + BLOCK] != base[off:off + BLOCK]:
            continue  # hash collision
        # extend forward
        ext_max = min(n - (pos + BLOCK), len(b) - (off + BLOCK))
        fwd = _first_mismatch(t[pos + BLOCK:pos + BLOCK + ext_max],
                              b[off + BLOCK:off + BLOCK + ext_max]) if ext_max > 0 else 0
        # extend backward into the pending ADD region
        back_max = min(pos - add_start, off)
        if back_max > 0:
            ta = t[pos - back_max:pos][::-1]
            ba = b[off - back_max:off][::-1]
            bwd = _first_mismatch(ta, ba)
        else:
            bwd = 0
        ts, bs = pos - bwd, off - bwd
        tl = pos + BLOCK + fwd
        flush_add(ts)
        out.append(_COPY)
        _write_varint(out, bs)
        _write_varint(out, tl - ts)
        add_start = tl
        i = tl
    flush_add(n)
    return bytes(out)


def decode(delta: bytes, base: bytes) -> bytes:
    # restore hot loop (DESIGN.md §9): varints are parsed inline (a
    # _read_varint call per op was ~40% of decode wall time), ops become
    # zero-copy memoryview slices, and the single b"".join is the only
    # data movement — one exact-size allocation instead of bytearray
    # growth. ~1.9x over the seed decode on real patch streams.
    src = memoryview(base)
    ops = memoryview(delta)
    pieces = []
    pos = 0
    n = len(delta)
    while pos < n:
        op = delta[pos]
        if op > _COPY:      # validate before consuming varint bytes
            raise ValueError(f"bad delta opcode {op}")
        v = delta[pos + 1]
        pos += 2
        if v & 0x80:
            v &= 0x7F
            shift = 7
            while True:
                b = delta[pos]
                pos += 1
                v |= (b & 0x7F) << shift
                if not b & 0x80:
                    break
                shift += 7
        if op == _ADD:
            pieces.append(ops[pos:pos + v])
            pos += v
        else:
            ln = delta[pos]
            pos += 1
            if ln & 0x80:
                ln &= 0x7F
                shift = 7
                while True:
                    b = delta[pos]
                    pos += 1
                    ln |= (b & 0x7F) << shift
                    if not b & 0x80:
                        break
                    shift += 7
            pieces.append(src[v:v + ln])
    return b"".join(pieces)


def delta_size(target: bytes, base: bytes) -> int:
    return len(encode(target, base))
