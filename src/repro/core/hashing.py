"""Hashing substrate for CARD.

All rolling hashes used by the paper (Gear for FastCDC, Rabin-style window
fingerprints for N-transform/Finesse, polynomial sub-chunk LSH) are *linear*
in the input bytes over Z/2^32:

    serial:   h = (h << 1) + gear[b]          (Gear)
              h = h * p + b                   (polynomial / Rabin-style)

    windowed: h_i = sum_k  w_k * g_{i-k}      (mod 2^32)

so every position's windowed hash is a k-tap weighted correlation that can be
evaluated fully in parallel — the TPU-native replacement for the paper's
serial CPU loops (see DESIGN.md §3). This module holds the tables/constants,
numpy host implementations, and jnp implementations used as kernel oracles.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------------
# Deterministic tables / constants
# ----------------------------------------------------------------------------

_GEAR_SEED = 0xC0FFEE
GEAR_WINDOW = 32  # uint32: shifts >= 32 vanish, so the effective window is 32B

# Odd multiplier for polynomial hashes (invertible mod 2^32).
POLY_P = np.uint32(0x01000193)  # FNV prime, odd
RABIN_WINDOW = 48

_rng = np.random.Generator(np.random.PCG64(_GEAR_SEED))
GEAR_TABLE = _rng.integers(0, 2**32, size=256, dtype=np.uint32)


def _u32(x) -> np.ndarray:
    return np.asarray(x).astype(np.uint32)


def modinv_pow2(a: int, bits: int = 32) -> int:
    """Inverse of odd `a` modulo 2**bits (Newton iteration)."""
    assert a % 2 == 1
    x = a  # correct mod 2^3
    for _ in range(6):
        x = (x * (2 - a * x)) % (1 << bits)
    return x % (1 << bits)


POLY_P_INV = np.uint32(modinv_pow2(int(POLY_P)))


def poly_powers(n: int, p: np.uint32 = POLY_P) -> np.ndarray:
    """[p^0, p^1, ..., p^{n-1}] as uint32 (wrapping)."""
    out = np.empty(n, dtype=np.uint32)
    acc = np.uint32(1)
    for i in range(n):
        out[i] = acc
        acc = np.uint32((int(acc) * int(p)) & 0xFFFFFFFF)
    return out


POLY_POW_RABIN = poly_powers(RABIN_WINDOW)
GEAR_WEIGHTS = (np.uint32(1) << np.arange(GEAR_WINDOW, dtype=np.uint32))

# ----------------------------------------------------------------------------
# numpy host implementations (ground truth for tests & host-side fallback)
# ----------------------------------------------------------------------------


def gear_hashes_np(data: np.ndarray) -> np.ndarray:
    """Windowed gear hash at every position of a byte stream.

    h_i == the serial FastCDC gear hash after consuming byte i, provided at
    least GEAR_WINDOW bytes precede i (exact match beyond the warm-up run —
    FastCDC only inspects positions >= min_size >> 32, see chunking.py).

    Window-doubling evaluation: a width-w partial hash extends to width 2w
    via ``h_2w(i) = h_w(i) + h_w(i-w) << w``, so the 32-tap correlation is
    5 vectorized passes instead of 31 (the ingest scan is on the hot path,
    DESIGN.md §8). All arithmetic is uint32: shifted-out high bits vanish
    mod 2^32 exactly as in the serial ``h = (h << 1) + gear[b]`` loop.
    """
    data = np.asarray(data, dtype=np.uint8)
    h = GEAR_TABLE[data].copy()
    n = len(h)
    w = 1
    while w < min(GEAR_WINDOW, n):
        nh = h.copy()
        nh[w:] += h[: n - w] << np.uint32(w)
        h = nh
        w *= 2
    return h


def gear_hashes_serial_np(data: np.ndarray) -> np.ndarray:
    """Bit-exact serial reference: h = (h << 1) + gear[b] mod 2^32."""
    data = np.asarray(data, dtype=np.uint8)
    out = np.empty(len(data), dtype=np.uint32)
    h = 0
    for i, b in enumerate(data):
        h = ((h << 1) + int(GEAR_TABLE[b])) & 0xFFFFFFFF
        out[i] = h
    return out


def rabin_fps_np(data: np.ndarray, window: int = RABIN_WINDOW) -> np.ndarray:
    """Windowed polynomial (Rabin-style) fingerprints at every position.

    fp_i = sum_{k=0..w-1} b_{i-k} * p^k  (mod 2^32); positions < w-1 cover a
    shorter (warm-up) window, matching a serial rolling implementation that
    starts from 0.
    """
    data = np.asarray(data, dtype=np.uint8).astype(np.uint64)
    n = len(data)
    pows = poly_powers(window).astype(np.uint64)
    h = np.zeros(n, dtype=np.uint64)
    for k in range(min(window, n)):
        if k == 0:
            h += data * pows[0]
        else:
            h[k:] += data[: n - k] * pows[k]
    return (h & 0xFFFFFFFF).astype(np.uint32)


def poly_hash_np(data: np.ndarray) -> int:
    """Whole-buffer polynomial hash: h = h*p + b (uint32). Sub-chunk LSH."""
    h = 0
    p = int(POLY_P)
    for b in np.asarray(data, dtype=np.uint8):
        h = (h * p + int(b)) & 0xFFFFFFFF
    return h


def segment_poly_hashes_np(data: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    """Polynomial hash of each segment [bounds[i], bounds[i+1]).

    Prefix-sum formulation (exactly poly_hash of each segment):
        S_i = sum_{j<i} b_j * p^{-(j+1)}           (mod 2^32)
        hash(l, r) = (S_r - S_l) * p^r             (mod 2^32)
    """
    data = np.asarray(data, dtype=np.uint8).astype(np.uint64)
    n = len(data)
    pinv = int(POLY_P_INV)
    # p^{-(j+1)} for j = 0..n-1
    ipows = np.empty(n, dtype=np.uint64)
    acc = pinv
    for j in range(n):
        ipows[j] = acc
        acc = (acc * pinv) & 0xFFFFFFFF
    contrib = (data * ipows) & 0xFFFFFFFF
    S = np.zeros(n + 1, dtype=np.uint64)
    np.cumsum(contrib, out=S[1:])
    S &= 0xFFFFFFFF
    pows = poly_powers(n + 1).astype(np.uint64)
    b = np.asarray(bounds, dtype=np.int64)
    seg = ((S[b[1:]] - S[b[:-1]]) & 0xFFFFFFFF) * pows[b[1:]]
    return (seg & 0xFFFFFFFF).astype(np.uint32)


# ----------------------------------------------------------------------------
# jnp implementations (oracles for the Pallas kernels; also usable directly)
# ----------------------------------------------------------------------------

GEAR_TABLE_J = jnp.asarray(GEAR_TABLE)


def windowed_weighted_sum_j(g: jax.Array, weights: np.ndarray) -> jax.Array:
    """h_i = sum_k weights[k] * g_{i-k} (uint32 wraparound), pure jnp.

    `g` is any uint32 stream ([n] or [..., n]); `weights` a host-side uint32
    vector of taps. This is the shared oracle for both the gear-hash and the
    rabin-fingerprint kernels.
    """
    g = g.astype(jnp.uint32)
    n = g.shape[-1]
    h = jnp.zeros_like(g)
    for k, w in enumerate(np.asarray(weights, dtype=np.uint32)):
        term = g * jnp.uint32(w)
        if k:
            pad = [(0, 0)] * (g.ndim - 1) + [(k, 0)]
            term = jnp.pad(term, pad)[..., :n]
        h = h + term
    return h


def gear_hashes_j(data: jax.Array) -> jax.Array:
    g = GEAR_TABLE_J[data.astype(jnp.int32)]
    return windowed_weighted_sum_j(g, GEAR_WEIGHTS)


def rabin_fps_j(data: jax.Array, window: int = RABIN_WINDOW) -> jax.Array:
    return windowed_weighted_sum_j(data.astype(jnp.uint32), poly_powers(window))


# Multiply-shift universal hashing (used by shingle feature embedding).
_MS_SEED = 0xD00DFEED


def multiply_shift_params(m: int, seed: int = _MS_SEED) -> tuple[np.ndarray, np.ndarray]:
    """M pairs (a, b): h_i(x) = a_i * x + b_i (uint32, high bits are best)."""
    rng = np.random.Generator(np.random.PCG64(seed))
    a = rng.integers(1, 2**32, size=m, dtype=np.uint32) | np.uint32(1)  # odd
    b = rng.integers(0, 2**32, size=m, dtype=np.uint32)
    return a, b


def multiply_shift_unit_j(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Map uint32 x [..., 1] through M hash funcs -> float32 in [-1, 1).

    out[..., i] = int32(a_i * x + b_i) / 2^31
    """
    h = x[..., None] * a + b  # uint32 wraparound
    return h.astype(jnp.int32).astype(jnp.float32) * jnp.float32(2.0**-31)
