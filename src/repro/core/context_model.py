"""BP-neural-network chunk-context aware model (paper §4.3).

Word2vec-CBOW-shaped two-matrix linear network:

    Formula 1:  h_i       = (1/2K) * (sum of 2K context features) @ W     [D]
    Formula 2:  out_i     = (1/2K) * h_i @ U                              [M]
    Formula 3:  vector'_j = 2K * vector_j @ pinv(U)                       [D]

The paper trains with "hierarchical softmax"; its labels are continuous
M-dim feature vectors, so we implement the continuous reading — cosine+MSE
regression of `out_i` against the target chunk's initial feature — and an
optional sampled-softmax over LSH-bucketed chunk ids (DESIGN.md §1).
`pinv` replaces the paper's U^{-1} (U is D x M, non-square).

Training is plain JAX and pjit-shardable (batch -> data axis, D -> model
axis); for the chunk volumes in the paper's experiments a single host is
plenty, but the same step function runs on the production mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim


@dataclasses.dataclass(frozen=True)
class ContextModelConfig:
    m: int = 64           # initial feature dim (paper M)
    d: int = 50           # context-aware feature dim (paper D; 40..80 in Tab.1)
    k: int = 2            # context half width -> 2K surrounding chunks
    lr: float = 3e-3
    steps: int = 300
    batch_size: int = 256
    mse_weight: float = 1.0
    cos_weight: float = 1.0
    seed: int = 0


class ContextModelParams(NamedTuple):
    w: jax.Array  # [M, D]
    u: jax.Array  # [D, M]


def init_params(cfg: ContextModelConfig) -> ContextModelParams:
    kw, ku = jax.random.split(jax.random.PRNGKey(cfg.seed))
    scale_w = 1.0 / np.sqrt(cfg.m)
    scale_u = 1.0 / np.sqrt(cfg.d)
    return ContextModelParams(
        w=jax.random.normal(kw, (cfg.m, cfg.d), jnp.float32) * scale_w,
        u=jax.random.normal(ku, (cfg.d, cfg.m), jnp.float32) * scale_u,
    )


def forward(params: ContextModelParams, ctx_mean: jax.Array) -> jax.Array:
    """ctx_mean [B, M] (already the 1/2K-scaled context sum) -> out [B, M]."""
    h = ctx_mean @ params.w                    # Formula 1
    return h @ params.u                        # Formula 2 (1/2K folded in)


def loss_fn(params: ContextModelParams, ctx_mean: jax.Array,
            target: jax.Array, cfg: ContextModelConfig) -> jax.Array:
    out = forward(params, ctx_mean)
    mse = jnp.mean(jnp.sum(jnp.square(out - target), axis=-1))
    tn = target / (jnp.linalg.norm(target, axis=-1, keepdims=True) + 1e-9)
    on = out / (jnp.linalg.norm(out, axis=-1, keepdims=True) + 1e-9)
    cos = jnp.mean(1.0 - jnp.sum(tn * on, axis=-1))
    return cfg.mse_weight * mse + cfg.cos_weight * cos


def make_training_pairs(features: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """(ctx_mean [T, M], target [T, M]) from the stream-ordered feature seq.

    Context of chunk i = the k chunks before and k after, edge-truncated
    (mean over however many neighbours exist); this matches "the surrounding
    co-occurring 2K chunks" with the 1/2K scale of Formulas 1-2.
    """
    t, m = features.shape
    ctx_sum = np.zeros((t, m), np.float32)
    ctx_cnt = np.zeros((t, 1), np.float32)
    for off in range(1, k + 1):
        ctx_sum[off:] += features[:-off]
        ctx_cnt[off:] += 1
        ctx_sum[:-off] += features[off:]
        ctx_cnt[:-off] += 1
    return ctx_sum / np.maximum(ctx_cnt, 1.0), features.astype(np.float32)


@functools.partial(jax.jit, static_argnames=("cfg", "tx"))
def _train_step(params, opt_state, ctx, tgt, cfg, tx):
    loss, grads = jax.value_and_grad(loss_fn)(params, ctx, tgt, cfg)
    deltas, opt_state = tx.update(grads, opt_state, params)
    params = optim.apply_updates(params, deltas)
    return params, opt_state, loss


class ContextModel:
    """Train-then-predict wrapper used by the dedup pipeline."""

    def __init__(self, cfg: ContextModelConfig | None = None):
        self.cfg = cfg or ContextModelConfig()
        self.params: ContextModelParams | None = None
        self._u_pinv: jax.Array | None = None
        self.losses: list[float] = []

    def fit(self, stream_features: np.ndarray) -> "ContextModel":
        cfg = self.cfg
        ctx, tgt = make_training_pairs(np.asarray(stream_features, np.float32), cfg.k)
        params = init_params(cfg)
        tx = optim.adamw(cfg.lr, weight_decay=0.0)
        opt_state = tx.init(params)
        rng = np.random.Generator(np.random.PCG64(cfg.seed))
        n = ctx.shape[0]
        bs = min(cfg.batch_size, n)
        ctx_j, tgt_j = jnp.asarray(ctx), jnp.asarray(tgt)
        for step in range(cfg.steps):
            idx = jnp.asarray(rng.integers(0, n, size=bs))
            params, opt_state, loss = _train_step(
                params, opt_state, ctx_j[idx], tgt_j[idx], cfg, tx)
            self.losses.append(float(loss))
        self.params = params
        # Formula 3's U^{-1}: Moore-Penrose with small singular values
        # truncated — raw pinv amplifies feature noise along rarely-used
        # output directions, destroying similarity (rtol chosen by the
        # sweep in benchmarks/bench_ablation.py).
        self._u_pinv = jnp.linalg.pinv(params.u, rtol=0.1)  # [M, D]
        return self

    def transform(self, features: np.ndarray | jax.Array) -> np.ndarray:
        """Formula 3: initial feature [*, M] -> context-aware feature [*, D].

        Output is L2-normalized (search runs on cosine similarity).
        """
        assert self.params is not None, "fit() first"
        f = jnp.asarray(features, jnp.float32)
        v = (2 * self.cfg.k) * (f @ self._u_pinv)
        v = v / (jnp.linalg.norm(v, axis=-1, keepdims=True) + 1e-12)
        return np.asarray(v)
