"""FastCDC content-defined chunking with a parallel candidate scan.

The paper (and Finesse / N-transform) all sit on top of FastCDC
[Xia et al., ATC'16]. Serial FastCDC walks the stream updating
``h = (h << 1) + gear[b]`` and cuts when ``h & mask == 0`` (a harder mask
before the normal size, an easier one after — "normalized chunking").

TPU adaptation (DESIGN.md §3): the gear hash is linear, so we evaluate the
windowed hash at *every* position in parallel (kernels/gear_hash, oracle in
core/hashing.py), producing two boundary-candidate bitmaps. Only the greedy
min/normal/max-size selection walks the stream on host, and it touches just
the (sparse) candidate positions. Boundaries are bit-identical to serial
FastCDC-with-reset whenever min_size >= 32 (the uint32 gear window), because
every inspected position is >= min_size past the chunk start, where the
32-byte window lies entirely inside the current chunk.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterator, Sequence

import numpy as np

from repro.core import hashing


@dataclasses.dataclass(frozen=True)
class ChunkerConfig:
    avg_size: int = 16 * 1024
    min_factor: float = 0.25           # min_size = avg * min_factor
    max_factor: float = 4.0            # max_size = avg * max_factor
    norm_level: int = 2                # FastCDC normalization (mask +- bits)

    @property
    def min_size(self) -> int:
        return max(64, int(self.avg_size * self.min_factor))

    @property
    def max_size(self) -> int:
        return int(self.avg_size * self.max_factor)

    @property
    def mask_bits(self) -> int:
        return int(np.log2(self.avg_size))

    @property
    def mask_s(self) -> int:  # harder mask: used before avg_size
        return (1 << (self.mask_bits + self.norm_level)) - 1

    @property
    def mask_l(self) -> int:  # easier mask: used after avg_size
        return (1 << (self.mask_bits - self.norm_level)) - 1


@dataclasses.dataclass(frozen=True)
class Chunk:
    offset: int
    length: int
    data: bytes

    @property
    def digest(self) -> bytes:
        return hashlib.blake2b(self.data, digest_size=20).digest()


def candidate_bitmaps(
    data: np.ndarray, cfg: ChunkerConfig, hashes: np.ndarray | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """(cand_s, cand_l) boolean maps of positions satisfying each mask."""
    if hashes is None:
        hashes = hashing.gear_hashes_np(np.frombuffer(data, dtype=np.uint8)
                                        if isinstance(data, (bytes, bytearray))
                                        else data)
    cand_s = (hashes & np.uint32(cfg.mask_s)) == 0
    cand_l = (hashes & np.uint32(cfg.mask_l)) == 0
    return cand_s, cand_l


def select_boundaries(
    n: int, cand_s: np.ndarray, cand_l: np.ndarray, cfg: ChunkerConfig
) -> np.ndarray:
    """Greedy FastCDC boundary selection from candidate bitmaps.

    Returns boundary offsets including 0 and n. A cut at position i means the
    chunk ends *after* byte i (chunk = data[start : i + 1]).
    """
    bounds = [0]
    start = 0
    min_s, avg_s, max_s = cfg.min_size, cfg.avg_size, cfg.max_size
    while start < n:
        if n - start <= min_s:
            bounds.append(n)
            break
        # Region 1: [start+min, start+avg) against the hard mask.
        lo = start + min_s
        hi = min(start + avg_s, n)
        cut = -1
        if lo < hi:
            w = cand_s[lo:hi]
            idx = np.flatnonzero(w)
            if idx.size:
                cut = lo + int(idx[0])
        if cut < 0:
            # Region 2: [start+avg, start+max) against the easy mask.
            lo2 = max(lo, min(start + avg_s, n))
            hi2 = min(start + max_s, n)
            if lo2 < hi2:
                w = cand_l[lo2:hi2]
                idx = np.flatnonzero(w)
                if idx.size:
                    cut = lo2 + int(idx[0])
        if cut < 0:
            cut = min(start + max_s, n) - 1
        bounds.append(cut + 1)
        start = cut + 1
    if bounds[-1] != n:
        bounds.append(n)
    return np.asarray(bounds, dtype=np.int64)


def chunks_from_bounds(raw: bytes, bounds: np.ndarray) -> list[Chunk]:
    """Materialize Chunk objects from boundary offsets (shared by the
    host path below and the device-scan path in repro.api.store)."""
    return [
        Chunk(offset=int(a), length=int(b - a), data=raw[a:b])
        for a, b in zip(bounds[:-1], bounds[1:])
    ]


def chunk_stream(
    data: bytes | np.ndarray,
    cfg: ChunkerConfig | None = None,
    hashes: np.ndarray | None = None,
) -> list[Chunk]:
    """Chunk a byte stream; `hashes` may be precomputed (e.g. by the kernel)."""
    cfg = cfg or ChunkerConfig()
    buf = np.frombuffer(data, dtype=np.uint8) if isinstance(data, (bytes, bytearray)) else np.asarray(data, dtype=np.uint8)
    raw = buf.tobytes()
    n = len(buf)
    if n == 0:
        return []
    cand_s, cand_l = candidate_bitmaps(buf, cfg, hashes)
    bounds = select_boundaries(n, cand_s, cand_l, cfg)
    return chunks_from_bounds(raw, bounds)


def chunk_boundaries_serial(data: bytes, cfg: ChunkerConfig) -> np.ndarray:
    """Bit-exact serial FastCDC (reset hash at each chunk start) — test oracle."""
    buf = np.frombuffer(data, dtype=np.uint8)
    n = len(buf)
    bounds = [0]
    start = 0
    gear = hashing.GEAR_TABLE
    while start < n:
        if n - start <= cfg.min_size:
            bounds.append(n)
            break
        h = 0
        cut = -1
        end1 = min(start + cfg.avg_size, n)
        end2 = min(start + cfg.max_size, n)
        i = start
        # warm up to min_size (serial FastCDC hashes from the chunk start)
        while i < start + cfg.min_size:
            h = ((h << 1) + int(gear[buf[i]])) & 0xFFFFFFFF
            i += 1
        while i < end1:
            h = ((h << 1) + int(gear[buf[i]])) & 0xFFFFFFFF
            if (h & cfg.mask_s) == 0:
                cut = i
                break
            i += 1
        if cut < 0:
            while i < end2:
                h = ((h << 1) + int(gear[buf[i]])) & 0xFFFFFFFF
                if (h & cfg.mask_l) == 0:
                    cut = i
                    break
                i += 1
        if cut < 0:
            cut = end2 - 1
        bounds.append(cut + 1)
        start = cut + 1
    if bounds[-1] != n:
        bounds.append(n)
    return np.asarray(bounds, dtype=np.int64)
