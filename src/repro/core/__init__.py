"""CARD core: the paper's contribution as a composable library.

  chunking       FastCDC with a parallel gear-hash candidate scan
  features       N-sub-chunk shingle initial features (Algorithm 1)
  context_model  BP-NN (CBOW) chunk-context aware model (§4.3)
  baselines      N-transform + Finesse super-features (§2/§3)
  similarity     cosine / banded-LSH resemblance indexes
  delta          COPY/ADD byte delta codec
  pipeline       the full dedup + delta-compression store (§5)
"""
from repro.core.chunking import Chunk, ChunkerConfig, chunk_stream  # noqa: F401
from repro.core.features import FeatureConfig, FeatureExtractor  # noqa: F401
from repro.core.context_model import ContextModel, ContextModelConfig  # noqa: F401
from repro.core.pipeline import (  # noqa: F401
    CARDDetector,
    DedupStore,
    NullDetector,
    StoreStats,
    finesse_detector,
    ntransform_detector,
    run_workload,
)
