"""N-sub-chunk shingles initial feature extraction (paper Algorithm 1).

Pipeline (per chunk, batched over chunks):

  1. split the chunk into K equal sub-chunks (last one ragged);
  2. LSH each sub-chunk. Default (`lsh="maxgear"`): the max windowed gear
     hash inside the sub-chunk — locality-sensitive (edits only perturb
     windows they overlap; boundary shifts only move a few edge windows)
     and *free*, because the FastCDC chunker already produced the gear-hash
     array for the whole stream (DESIGN.md §3). `lsh="poly"` is an exact
     polynomial hash of the sub-chunk bytes, kept as an ablation — it is
     NOT locality-sensitive and collapses under insertions (see
     benchmarks/bench_ablation.py for the measured gap).
  3. shingles: for r = 1..N, the combined hash of every window of r+1
     consecutive sub-chunk hashes, in order ("the hash and its surrounding
     r hash values in order") — this encodes the chunk's internal
     structure;
  4. keep the set of unique shingles (sort + neighbour-mask, jnp);
  5. map each unique shingle through M multiply-shift hash functions into
     an M-dim sub-vector in [-1, 1), L2-normalize it, and average the
     sub-vectors -> the M-dim initial feature (kernels/shingle_embed is the
     Pallas fast path; oracle in kernels/ref.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

SHINGLE_Q = np.uint32(0x9E3779B1)  # odd golden-ratio multiplier


@dataclasses.dataclass(frozen=True)
class FeatureConfig:
    k: int = 32         # number of sub-chunks per chunk (paper: K)
    m: int = 64         # initial feature dimension (paper: M)
    n: int = 2          # max shingle radius (paper: N)
    lsh: str = "maxgear"  # sub-chunk LSH: "maxgear" | "poly" (ablation)
    normalize: bool = True

    @property
    def num_shingles(self) -> int:
        return sum(self.k - r for r in range(1, self.n + 1))


# -----------------------------------------------------------------------------
# Step 1+2: sub-chunk LSH values
# -----------------------------------------------------------------------------

def _bounds(n: int, k: int) -> np.ndarray:
    """Equal-split segment bounds b_j = floor(j*n/k), exact integer math.

    (Formerly ``linspace(0, n, k+1).astype(int64)``, whose float rounding
    disagreed with the integer position->segment maps used by the jnp and
    fused device paths at boundaries where k does not divide n; every
    path now shares the exact-floor convention, so np/jnp/fused are
    bit-identical by construction, not by luck.)
    """
    return (np.arange(k + 1, dtype=np.int64) * n) // k


_WARMUP = hashing.GEAR_WINDOW - 1  # positions whose 32B window crosses the
# chunk start; masked so stream-scan reuse and per-chunk hashing agree exactly


def subchunk_maxgear_np(gear_hashes: np.ndarray, k: int) -> np.ndarray:
    """[L] gear hashes of one chunk -> [K] max per equal sub-chunk.

    The first GEAR_WINDOW-1 positions are excluded from the max: on the
    stream path their windows reach into the previous chunk, on the
    per-chunk path they are warm-up partial windows — masking both makes
    the two paths bit-identical (tests/test_features.py).
    """
    n = len(gear_hashes)
    b = _bounds(n, k)
    starts = b[:-1].copy()
    # reduceat needs strictly valid starts; empty segments (tiny chunks) get 0
    starts = np.minimum(starts, max(n - 1, 0))
    out = np.maximum.reduceat(gear_hashes, starts) if n else np.zeros(k, np.uint32)
    empty = b[1:] <= b[:-1]
    out[empty] = 0
    # re-derive maxes for segments overlapping the warm-up region
    warm = np.flatnonzero(b[:-1] < min(_WARMUP, n))
    for i in warm:
        lo, hi = max(int(b[i]), _WARMUP), int(b[i + 1])
        out[i] = gear_hashes[lo:hi].max() if hi > lo else 0
    return out.astype(np.uint32)


def subchunk_poly_np(data: bytes, k: int) -> np.ndarray:
    """[K] exact polynomial hashes of the K sub-chunks (ablation path)."""
    buf = np.frombuffer(data, dtype=np.uint8)
    return hashing.segment_poly_hashes_np(buf, _bounds(len(buf), k))


def batch_subchunk_lsh_np(chunks: list[bytes], cfg: FeatureConfig,
                          stream_hashes: np.ndarray | None = None,
                          offsets: np.ndarray | None = None) -> np.ndarray:
    """[B, K] sub-chunk LSH values.

    With `stream_hashes` + `offsets` (chunk start offsets into the stream the
    hashes were computed over), the maxgear path reuses the chunker's scan
    and does no per-byte work at all.
    """
    if cfg.lsh == "poly":
        return np.stack([subchunk_poly_np(c, cfg.k) for c in chunks])
    if stream_hashes is not None and offsets is not None:
        out = np.empty((len(chunks), cfg.k), np.uint32)
        for i, (c, off) in enumerate(zip(chunks, offsets)):
            out[i] = subchunk_maxgear_np(stream_hashes[off:off + len(c)], cfg.k)
        return out
    return np.stack([
        subchunk_maxgear_np(hashing.gear_hashes_np(np.frombuffer(c, np.uint8)), cfg.k)
        for c in chunks])


@functools.partial(jax.jit, static_argnames=("k",))
def batch_subchunk_maxgear_j(gear: jax.Array, lengths: jax.Array, k: int) -> jax.Array:
    """jnp path: gear hashes [B, Lmax] + lengths [B] -> [B, K] segment maxes."""
    b, lmax = gear.shape
    pos = jnp.arange(lmax)
    # segment id of each position: the exact inverse of the _bounds floor
    # convention (pos in [floor(j*n/k), floor((j+1)*n/k)) <=> j ==
    # floor((pos*k + k - 1) / n)); warm-up positions and padding -> K
    # (dropped), matching subchunk_maxgear_np
    valid = (pos[None, :] < lengths[:, None]) & (pos[None, :] >= _WARMUP)
    seg = jnp.where(valid, (pos[None, :] * k + (k - 1))
                    // jnp.maximum(lengths[:, None], 1), k)
    seg = jnp.clip(seg, 0, k)

    def one(g_row, seg_row):
        return jax.ops.segment_max(g_row, seg_row, num_segments=k + 1,
                                   indices_are_sorted=True)[:k]
    out = jax.vmap(one)(gear, seg)
    return jnp.maximum(out, 0).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("k",))
def batch_subchunk_poly_j(data: jax.Array, lengths: jax.Array, k: int) -> jax.Array:
    """jnp ablation path over a padded byte batch: [B, Lmax] u8 -> [B, K]."""
    b, lmax = data.shape
    j = jnp.arange(lmax, dtype=jnp.uint32)
    ipows = _pow_table(hashing.POLY_P_INV, lmax) * jnp.uint32(hashing.POLY_P_INV)
    pows = _pow_table(hashing.POLY_P, lmax + 1)
    valid = (j[None, :] < lengths[:, None].astype(jnp.uint32))
    contrib = jnp.where(valid, data.astype(jnp.uint32) * ipows[None, :], 0)
    s = jnp.cumsum(contrib.astype(jnp.uint32), axis=1)
    s = jnp.concatenate([jnp.zeros((b, 1), jnp.uint32), s], axis=1)
    i = jnp.arange(k + 1, dtype=jnp.uint32)
    bounds = (i[None, :] * lengths[:, None].astype(jnp.uint32)) // jnp.uint32(k)
    s_at = jnp.take_along_axis(s, bounds.astype(jnp.int32), axis=1)
    seg = (s_at[:, 1:] - s_at[:, :-1]) * pows[bounds[:, 1:].astype(jnp.int32)]
    return seg.astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("n",))
def _pow_table_impl(base: jax.Array, n: int) -> jax.Array:
    def body(carry, _):
        return carry * base, carry
    _, out = jax.lax.scan(body, jnp.uint32(1), None, length=n)
    return out


def _pow_table(base: np.uint32, n: int) -> jax.Array:
    return _pow_table_impl(jnp.uint32(base), n)


# -----------------------------------------------------------------------------
# Step 3+4: shingle ids + uniquification
# -----------------------------------------------------------------------------

def shingle_ids(sub_hashes: jax.Array, n: int) -> jax.Array:
    """[B, K] uint32 -> [B, S] combined shingle hashes (S = sum_r (K-r)).

    shingle(j, r) = sum_t sub_hashes[j + t] * Q^t  for t in 0..r — an
    order-sensitive polynomial combination of r+1 consecutive sub-chunk
    hashes.
    """
    k = sub_hashes.shape[-1]
    out = []
    q = jnp.uint32(SHINGLE_Q)
    for r in range(1, n + 1):
        acc = sub_hashes[..., : k - r].astype(jnp.uint32)
        mult = q
        for t in range(1, r + 1):
            acc = acc + sub_hashes[..., t : k - r + t] * mult
            mult = mult * q
        out.append(acc)
    return jnp.concatenate(out, axis=-1)


def unique_mask(ids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sort each row; mask[i]=True for the first occurrence of each value."""
    s = jnp.sort(ids, axis=-1)
    first = jnp.concatenate(
        [jnp.ones_like(s[..., :1], dtype=bool), s[..., 1:] != s[..., :-1]], axis=-1)
    return s, first


# -----------------------------------------------------------------------------
# Step 5: embed (jnp version; the Pallas kernel lives in kernels/shingle_embed)
# -----------------------------------------------------------------------------

def embed_shingles_j(ids: jax.Array, mask: jax.Array, a: jax.Array,
                     b: jax.Array, normalize: bool = True) -> jax.Array:
    """[B, S] ids + [B, S] mask -> [B, M] features (pure jnp oracle)."""
    v = hashing.multiply_shift_unit_j(ids, a, b)           # [B, S, M]
    norm = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True)) + 1e-12
    v = v / norm
    v = jnp.where(mask[..., None], v, 0.0)
    cnt = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1).astype(jnp.float32)
    feat = jnp.sum(v, axis=-2) / cnt
    if normalize:
        feat = feat / (jnp.linalg.norm(feat, axis=-1, keepdims=True) + 1e-12)
    return feat


def bucket_pow2(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor) — THE bucketing rule every
    jit boundary shares (embed batch, fused stream/B/Lmax buckets,
    context-model rows; DESIGN.md §8.2)."""
    return max(floor, 1 << max(0, int(n) - 1).bit_length())


class FeatureExtractor:
    """End-to-end Algorithm 1. Host API over `bytes`, jnp math underneath.

    Batches are padded to power-of-two sizes so the jitted embed path
    compiles once per bucket instead of once per batch size.

    With ``fused=True`` (the default) and the chunker's stream scan
    available, the whole LSH -> shingle -> embed pipeline runs as one
    jitted device program per stream (kernels/ingest, DESIGN.md §8);
    ``fused=False`` keeps the per-chunk numpy path — the oracle the fused
    program is property-tested against, and the pre-fusion baseline
    benchmarks/bench_ingest.py measures speedups over.
    """

    def __init__(self, cfg: FeatureConfig | None = None, use_kernel: bool = True,
                 fused: bool = True):
        self.cfg = cfg or FeatureConfig()
        a, b = hashing.multiply_shift_params(self.cfg.m)
        self._a = jnp.asarray(a)
        self._b = jnp.asarray(b)
        self._use_kernel = use_kernel
        self.fused = fused

    def _embed(self, ids: jax.Array, mask: jax.Array) -> jax.Array:
        if self._use_kernel:
            from repro.kernels import ops as kops
            return kops.shingle_embed(ids, mask, self._a, self._b,
                                      normalize=self.cfg.normalize)
        return embed_shingles_j(ids, mask, self._a, self._b, self.cfg.normalize)

    def features_from_subhashes(self, sub_hashes) -> np.ndarray:
        sub = np.asarray(sub_hashes)
        bsz = sub.shape[0]
        pad = bucket_pow2(bsz, 16) - bsz
        if pad:
            sub = np.pad(sub, ((0, pad), (0, 0)))
        ids = shingle_ids(jnp.asarray(sub), self.cfg.n)
        ids, mask = unique_mask(ids)
        return np.asarray(self._embed(ids, mask))[:bsz]

    @staticmethod
    def _fused_stream_limit() -> int:
        # lazy: features is a leaf module, kernels.ingest imports it
        from repro.kernels.ingest import FUSED_STREAM_LIMIT
        return FUSED_STREAM_LIMIT

    def features_from_stream(self, stream_hashes: np.ndarray,
                             offsets: np.ndarray, lengths: np.ndarray,
                             lmax_floor: int = 0) -> np.ndarray:
        """Fused fast path: one device program over the chunker's scan.

        ``lmax_floor`` (the chunker's max chunk size, wired through
        ``CARDDetector.fit``) pins the Lmax bucket so steady-state
        streams of one chunker config never retrace just because their
        observed longest chunk straddles a pow2 boundary."""
        from repro.kernels import ingest as kingest
        return kingest.extract_stream(
            stream_hashes, offsets, lengths, self._a, self._b,
            k=self.cfg.k, n=self.cfg.n, normalize=self.cfg.normalize,
            use_kernel=self._use_kernel, lmax_floor=lmax_floor)

    def __call__(self, chunks: list[bytes],
                 stream_hashes: np.ndarray | None = None,
                 offsets: np.ndarray | None = None,
                 lmax_floor: int = 0) -> np.ndarray:
        """[B, M] float32 initial features for a list of chunk payloads."""
        if not chunks:
            return np.zeros((0, self.cfg.m), np.float32)
        if (self.fused and self.cfg.lsh == "maxgear"
                and stream_hashes is not None and offsets is not None
                # the fused program indexes with int32; oversized streams
                # take the per-chunk host path instead
                and len(stream_hashes) <= self._fused_stream_limit()):
            lengths = np.asarray([len(c) for c in chunks], np.int64)
            return self.features_from_stream(stream_hashes,
                                             np.asarray(offsets), lengths,
                                             lmax_floor=lmax_floor)
        sub = batch_subchunk_lsh_np(chunks, self.cfg, stream_hashes, offsets)
        return self.features_from_subhashes(sub)
