"""The paper's comparison targets: N-transform and Finesse resemblance
detection (super-feature schemes), implemented over the same parallel
window-fingerprint scan as CARD (kernels/gear_hash generalizes to any
tap-weight vector — DESIGN.md §3).

Both schemes map a chunk to `sf_count` super-features; two chunks are
treated as similar if ANY super-feature matches, and the first match wins
("FirstFit", as in Finesse/FAST'19 and paper §3).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import hashing

_FNV64_OFFSET = 0xCBF29CE484222325
_FNV64_PRIME = 0x100000001B3


def _fnv64(values: np.ndarray) -> int:
    h = _FNV64_OFFSET
    for v in np.asarray(values, dtype=np.uint64):
        h ^= int(v)
        h = (h * _FNV64_PRIME) & 0xFFFFFFFFFFFFFFFF
    return h


@dataclasses.dataclass(frozen=True)
class SuperFeatureConfig:
    features_per_sf: int = 4
    sf_count: int = 3
    window: int = hashing.RABIN_WINDOW

    @property
    def total_features(self) -> int:
        return self.features_per_sf * self.sf_count


class NTransform:
    """Shilane et al.: N linear transforms of all window fingerprints.

    feature_i = max_pos ((m_i * fp_pos + a_i) mod 2^32); super-feature j =
    hash of its group of `features_per_sf` consecutive features.
    """

    def __init__(self, cfg: SuperFeatureConfig | None = None, seed: int = 7):
        self.cfg = cfg or SuperFeatureConfig()
        rng = np.random.Generator(np.random.PCG64(seed))
        n = self.cfg.total_features
        self._m = (rng.integers(1, 2**32, n, dtype=np.uint64) | np.uint64(1))
        self._a = rng.integers(0, 2**32, n, dtype=np.uint64)

    def super_features(self, data: bytes) -> tuple[int, ...]:
        buf = np.frombuffer(data, dtype=np.uint8)
        fps = hashing.rabin_fps_np(buf, self.cfg.window).astype(np.uint64)  # [L]
        # N linear transforms, max over positions: [N]
        t = (fps[None, :] * self._m[:, None] + self._a[:, None]) & np.uint64(0xFFFFFFFF)
        feats = t.max(axis=1)
        g = self.cfg.features_per_sf
        return tuple(_fnv64(feats[j * g:(j + 1) * g])
                     for j in range(self.cfg.sf_count))


class Finesse:
    """Zhang et al. FAST'19: fine-grained feature locality.

    Split the chunk into `total_features` sub-chunks; feature of each =
    max window fingerprint inside it. Group consecutive sub-chunk features
    into `features_per_sf`-sized groups, sort within each group, and build
    SF_j from the j-th ranked value of every group (rank-based grouping,
    paper Fig. 2).
    """

    def __init__(self, cfg: SuperFeatureConfig | None = None):
        self.cfg = cfg or SuperFeatureConfig()

    def super_features(self, data: bytes) -> tuple[int, ...]:
        buf = np.frombuffer(data, dtype=np.uint8)
        n = len(buf)
        fps = hashing.rabin_fps_np(buf, self.cfg.window).astype(np.uint64)
        t = self.cfg.total_features
        bounds = np.linspace(0, n, t + 1).astype(np.int64)
        feats = np.zeros(t, dtype=np.uint64)
        for i in range(t):
            lo, hi = bounds[i], bounds[i + 1]
            feats[i] = fps[lo:hi].max() if hi > lo else 0
        # rank-based grouping: groups of size features_per_sf along the chunk;
        # SF_j collects the j-th smallest of each group.
        g = self.cfg.features_per_sf
        ngroups = self.cfg.sf_count
        grouped = feats[: g * ngroups].reshape(ngroups, g)
        ranked = np.sort(grouped, axis=1)          # [ngroups, g]
        return tuple(_fnv64(ranked[:, j]) for j in range(g))[: self.cfg.sf_count]


class SuperFeatureIndex:
    """FirstFit store: any-SF-match -> similar; first match is the base.

    `query`/`stage` accept an *overlay* (same table-list shape, holding
    staged-but-not-admitted entries) so a batch can be scored as if its
    earlier chunks were already inserted — without mutating the index.
    Persistent tables win over the overlay, matching insert's
    first-writer-wins `setdefault`. The FirstFit ordering lives only
    here; callers never touch the tables directly.
    """

    def __init__(self):
        self._tables: list[dict[int, int]] = []

    def query(self, sfs: tuple[int, ...],
              overlay: list[dict[int, int]] | None = None) -> int | None:
        for j, sf in enumerate(sfs):
            hit = self._tables[j].get(sf) if j < len(self._tables) else None
            if hit is None and overlay is not None and j < len(overlay):
                hit = overlay[j].get(sf)
            if hit is not None:
                return hit
        return None

    def stage(self, sfs: tuple[int, ...], chunk_id: int,
              overlay: list[dict[int, int]]) -> None:
        """Record an insert in `overlay` only (the index is untouched),
        preserving first-writer-wins across persistent + staged entries."""
        while len(overlay) < len(sfs):
            overlay.append({})
        for j, sf in enumerate(sfs):
            if j >= len(self._tables) or sf not in self._tables[j]:
                overlay[j].setdefault(sf, chunk_id)

    def insert(self, sfs: tuple[int, ...], chunk_id: int) -> None:
        while len(self._tables) < len(sfs):
            self._tables.append({})
        for j, sf in enumerate(sfs):
            self._tables[j].setdefault(sf, chunk_id)
