"""End-to-end deduplication + delta-compression pipeline (paper §5 system).

    stream -> FastCDC chunks -> exact dedup (blake2b)
           -> resemblance detection (pluggable: CARD / Finesse / N-transform)
           -> delta-encode against the detected base | store raw
           -> container store; DCR = bytes_in / bytes_stored

Detectors implement:

    fit(training_streams, chunker_cfg)            offline model training
    detect(chunks, ids, is_new, stream_hashes)    -> base chunk id per chunk
                                                     (-1 = store raw), and
                                                     must index new chunks

`detect` sees the whole stream at once so feature extraction and index
search batch properly (CARD queries are one matmul, not n python calls);
FirstFit baselines keep their sequential any-SF-match semantics internally.
Detection time (the paper's speed metric) = wall time inside `detect`,
excluding chunking and delta I/O, matching the paper's accounting.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Protocol, Sequence

import numpy as np

from repro.core import baselines, chunking, context_model, delta, features, hashing, similarity


@dataclasses.dataclass
class StoreStats:
    bytes_in: int = 0
    bytes_stored: int = 0
    chunks: int = 0
    dup_chunks: int = 0
    delta_chunks: int = 0
    raw_chunks: int = 0
    detect_seconds: float = 0.0
    chunk_seconds: float = 0.0
    delta_seconds: float = 0.0
    fit_seconds: float = 0.0

    @property
    def dcr(self) -> float:
        return self.bytes_in / max(1, self.bytes_stored)


class Detector(Protocol):
    name: str

    def fit(self, training_streams: Sequence[bytes],
            cfg: chunking.ChunkerConfig) -> None: ...

    def detect(self, chunks: list[chunking.Chunk], ids: np.ndarray,
               is_new: np.ndarray, stream_hashes: np.ndarray) -> np.ndarray: ...


class NullDetector:
    """Exact dedup only (no delta compression)."""
    name = "dedup-only"

    def fit(self, training_streams, cfg):
        pass

    def detect(self, chunks, ids, is_new, stream_hashes):
        return np.full(len(chunks), -1, np.int64)


class SuperFeatureDetector:
    """Shared FirstFit wrapper for N-transform / Finesse."""

    def __init__(self, scheme, name: str):
        self._scheme = scheme
        self.name = name
        self._index = baselines.SuperFeatureIndex()

    def fit(self, training_streams, cfg):
        pass  # content-only schemes have no training phase

    def detect(self, chunks, ids, is_new, stream_hashes):
        out = np.full(len(chunks), -1, np.int64)
        for i, ck in enumerate(chunks):
            sfs = self._scheme.super_features(ck.data)
            if is_new[i]:
                hit = self._index.query(sfs)
                if hit is not None and hit != ids[i]:
                    out[i] = hit
            self._index.insert(sfs, int(ids[i]))
        return out


def ntransform_detector(cfg: baselines.SuperFeatureConfig | None = None):
    return SuperFeatureDetector(baselines.NTransform(cfg), "n-transform")


def finesse_detector(cfg: baselines.SuperFeatureConfig | None = None):
    return SuperFeatureDetector(baselines.Finesse(cfg), "finesse")


class CARDDetector:
    """The paper's scheme: initial features -> context model -> cosine index.

    Batch two-phase search: one top-1 query of all new chunks against the
    stored index, plus one intra-stream similarity pass (earlier chunks of
    the same stream are eligible bases), then a single batched insert.
    """

    name = "card"

    def __init__(self,
                 feat_cfg: features.FeatureConfig | None = None,
                 model_cfg: context_model.ContextModelConfig | None = None,
                 threshold: float = 0.3,
                 use_lsh_bands: bool = False,
                 use_kernel: bool = True):
        self.feat_cfg = feat_cfg or features.FeatureConfig()
        self.model_cfg = model_cfg or context_model.ContextModelConfig(m=self.feat_cfg.m)
        assert self.model_cfg.m == self.feat_cfg.m
        self.threshold = threshold
        self.extractor = features.FeatureExtractor(self.feat_cfg, use_kernel=use_kernel)
        self.model = context_model.ContextModel(self.model_cfg)
        if use_lsh_bands:
            self.index: similarity.CosineIndex | similarity.BandedLSHIndex = \
                similarity.BandedLSHIndex(self.model_cfg.d, threshold=threshold)
        else:
            self.index = similarity.CosineIndex(self.model_cfg.d, threshold=threshold,
                                                use_kernel=use_kernel)

    def fit(self, training_streams, cfg):
        """Training process (paper Fig. 3 left): chunk the training data in
        stream order, extract initial features, train the CBOW model."""
        feats = []
        for stream in training_streams:
            buf = np.frombuffer(stream, dtype=np.uint8)
            h = hashing.gear_hashes_np(buf)
            chunks = chunking.chunk_stream(stream, cfg, hashes=h)
            if chunks:
                offs = np.asarray([c.offset for c in chunks])
                feats.append(self.extractor([c.data for c in chunks], h, offs))
        if not feats:
            raise ValueError("CARD needs at least one training stream")
        self.model.fit(np.concatenate(feats, axis=0))

    def detect(self, chunks, ids, is_new, stream_hashes):
        offs = np.asarray([c.offset for c in chunks])
        init = self.extractor([c.data for c in chunks], stream_hashes, offs)
        feats = self.model.transform(init)                    # [n, D]
        n = len(chunks)
        out = np.full(n, -1, np.int64)

        # phase 1: against the stored index
        ext_ids, ext_scores = self.index.query(feats)

        # phase 2: intra-stream (earlier chunks of this stream)
        sims = feats @ feats.T
        iu = np.triu_indices(n)
        sims[iu] = -np.inf                                   # j < i only
        intra_j = sims.argmax(axis=1)
        intra_s = sims[np.arange(n), intra_j]

        use_intra = intra_s >= np.maximum(ext_scores, self.threshold)
        best_id = np.where(use_intra, ids[intra_j], ext_ids)
        best_sc = np.where(use_intra, intra_s, ext_scores)
        ok = (best_sc >= self.threshold) & is_new & (best_id != ids)
        out[ok] = best_id[ok]

        new_mask = is_new.astype(bool)
        if new_mask.any():
            self.index.insert_batch(feats[new_mask], ids[new_mask])
        return out


class DedupStore:
    """Container store with exact dedup + detector-driven delta compression."""

    def __init__(self, detector: Detector,
                 chunker_cfg: chunking.ChunkerConfig | None = None):
        self.detector = detector
        self.cfg = chunker_cfg or chunking.ChunkerConfig()
        self.stats = StoreStats()
        self._by_digest: dict[bytes, int] = {}
        self._payload: dict[int, bytes] = {}   # chunk_id -> raw bytes
        self._kind: dict[int, tuple] = {}      # chunk_id -> ("raw",)|("delta",base,d)
        self._next_id = 0
        self._recipes: list[list[int]] = []    # stream -> chunk ids (restore)

    def fit(self, training_streams: Sequence[bytes]) -> None:
        t0 = time.perf_counter()
        self.detector.fit(training_streams, self.cfg)
        self.stats.fit_seconds += time.perf_counter() - t0

    def ingest(self, stream: bytes) -> StoreStats:
        t0 = time.perf_counter()
        buf = np.frombuffer(stream, dtype=np.uint8)
        stream_hashes = hashing.gear_hashes_np(buf)
        chunks = chunking.chunk_stream(stream, self.cfg, hashes=stream_hashes)
        self.stats.chunk_seconds += time.perf_counter() - t0

        # pass 1: exact dedup; assign ids
        n = len(chunks)
        ids = np.empty(n, np.int64)
        is_new = np.zeros(n, bool)
        digests = [ck.digest for ck in chunks]
        seen_in_stream: dict[bytes, int] = {}
        for i, dig in enumerate(digests):
            ref = self._by_digest.get(dig)
            if ref is None:
                ref = seen_in_stream.get(dig)
            if ref is not None:
                ids[i] = ref
            else:
                ids[i] = self._next_id
                self._next_id += 1
                is_new[i] = True
                seen_in_stream[dig] = int(ids[i])

        # pass 2: resemblance detection (batched)
        t0 = time.perf_counter()
        base_ids = self.detector.detect(chunks, ids, is_new, stream_hashes)
        self.stats.detect_seconds += time.perf_counter() - t0

        # pass 3: store
        recipe: list[int] = []
        for i, ck in enumerate(chunks):
            self.stats.bytes_in += ck.length
            self.stats.chunks += 1
            cid = int(ids[i])
            recipe.append(cid)
            if not is_new[i]:
                self.stats.dup_chunks += 1
                continue
            stored = None
            base = int(base_ids[i])
            if base >= 0 and base in self._payload:
                t0 = time.perf_counter()
                d = delta.encode(ck.data, self._payload[base])
                self.stats.delta_seconds += time.perf_counter() - t0
                if len(d) < ck.length:
                    stored = len(d) + 8  # + recipe metadata
                    self._kind[cid] = ("delta", base, d)
                    self.stats.delta_chunks += 1
            if stored is None:
                stored = ck.length
                self._kind[cid] = ("raw",)
                self.stats.raw_chunks += 1
            self._payload[cid] = ck.data
            self._by_digest[digests[i]] = cid
            self.stats.bytes_stored += stored
        self._recipes.append(recipe)
        return self.stats

    def restore(self, stream_idx: int) -> bytes:
        """Reconstruct a stream byte-for-byte from stored containers."""
        out = bytearray()
        for cid in self._recipes[stream_idx]:
            kind = self._kind[cid]
            if kind[0] == "raw":
                out.extend(self._payload[cid])
            else:
                _, base_id, d = kind
                out.extend(delta.decode(d, self._payload[base_id]))
        return bytes(out)


def run_workload(detector: Detector, versions: Sequence[bytes],
                 cfg: chunking.ChunkerConfig | None = None,
                 train_on: int = 1) -> StoreStats:
    """Paper experiment harness: fit on the first `train_on` versions, then
    ingest every version through the store; returns final stats."""
    store = DedupStore(detector, cfg)
    store.fit(list(versions[:train_on]))
    for v in versions:
        store.ingest(v)
    return store.stats
