"""Detectors + the end-to-end dedup/delta pipeline (paper §5 system).

    stream -> FastCDC chunks -> exact dedup (blake2b)
           -> resemblance detection (pluggable: CARD / Finesse / N-transform)
           -> delta-encode against the detected base | store raw
           -> container backend; DCR = bytes_in / bytes_stored

Detectors implement the staged protocol (repro.api.detect, DESIGN.md §2.1):

    fit(training_streams, chunker_cfg)   offline model training
    extract(batch) -> features           pure, batched heavy lifting
    score(features, batch) -> result     pure candidate scoring
    observe(features, batch)             the one index-mutating step

`extract` sees the whole stream at once so feature extraction and index
search batch properly (CARD queries are one matmul, not n python calls);
FirstFit baselines keep their sequential any-SF-match semantics via a
pure overlay in `score`. The v0 single-call `detect(chunks, ids, is_new,
stream_hashes)` surface survives via LegacyDetectMixin, bit-identical.
Detection time (the paper's speed metric) = wall time across the three
stages, excluding chunking and delta I/O, matching the paper's accounting.

The store itself lives in repro.api.store (StreamSession ingestion over a
ContainerBackend); DedupStore/StoreStats are re-exported here for the v0
import surface.
"""
from __future__ import annotations

from typing import Any, Protocol, Sequence

import numpy as np

from repro.api.detect import LegacyDetectMixin
from repro.api.registry import register_detector
from repro.api.store import DedupStore, StreamSession, chunk_with  # noqa: F401  (v0 surface)
from repro.api.types import DetectBatch, DetectResult, IngestReport, StoreStats  # noqa: F401
from repro.core import baselines, chunking, context_model, features


class Detector(Protocol):
    """v0 single-call protocol; still accepted everywhere (run_detect
    falls back to it for detectors that are not staged)."""

    name: str

    def fit(self, training_streams: Sequence[bytes],
            cfg: chunking.ChunkerConfig) -> None: ...

    def detect(self, chunks: list[chunking.Chunk], ids: np.ndarray,
               is_new: np.ndarray, stream_hashes: np.ndarray) -> np.ndarray: ...


class NullDetector(LegacyDetectMixin):
    """Exact dedup only (no delta compression)."""
    name = "dedup-only"

    def fit(self, training_streams, cfg):
        pass

    def extract(self, batch: DetectBatch) -> None:
        return None

    def score(self, feats: None, batch: DetectBatch) -> DetectResult:
        return DetectResult(np.full(len(batch), -1, np.int64))

    def observe(self, feats: None, batch: DetectBatch) -> None:
        pass


class SuperFeatureDetector(LegacyDetectMixin):
    """Shared FirstFit wrapper for N-transform / Finesse.

    FirstFit is inherently sequential (chunk i may delta against chunk
    j < i of the same stream, inserted moments earlier), so `score`
    replays that order against a *pure overlay* of the shared index:
    persistent tables are consulted first (insert is first-writer-wins),
    then same-batch entries. `observe` then admits the batch for real —
    the final index state and every verdict are bit-identical to the v0
    interleaved query/insert loop.
    """

    def __init__(self, scheme, name: str):
        self._scheme = scheme
        self.name = name
        self._index = baselines.SuperFeatureIndex()

    def fit(self, training_streams, cfg):
        pass  # content-only schemes have no training phase

    def extract(self, batch: DetectBatch) -> list[tuple[int, ...]]:
        return [self._scheme.super_features(ck.data) for ck in batch.chunks]

    def score(self, sfs_list: list[tuple[int, ...]],
              batch: DetectBatch) -> DetectResult:
        n = len(batch)
        out = np.full(n, -1, np.int64)
        overlay: list[dict[int, int]] = []
        for i, sfs in enumerate(sfs_list):
            if batch.is_new[i]:
                hit = self._index.query(sfs, overlay=overlay)
                if hit is not None and hit != batch.ids[i]:
                    out[i] = hit
            self._index.stage(sfs, int(batch.ids[i]), overlay)
        return DetectResult(out)

    def observe(self, sfs_list: list[tuple[int, ...]],
                batch: DetectBatch) -> None:
        for sfs, cid in zip(sfs_list, batch.ids):
            self._index.insert(sfs, int(cid))


def ntransform_detector(cfg: baselines.SuperFeatureConfig | None = None):
    return SuperFeatureDetector(baselines.NTransform(cfg), "n-transform")


def finesse_detector(cfg: baselines.SuperFeatureConfig | None = None):
    return SuperFeatureDetector(baselines.Finesse(cfg), "finesse")


class CARDDetector(LegacyDetectMixin):
    """The paper's scheme: initial features -> context model -> cosine index.

    Batch two-phase search: one top-1 query of all new chunks against the
    stored index, plus one intra-stream similarity pass (earlier chunks of
    the same stream are eligible bases), then a single batched insert.

    The resemblance index is a registry knob (`index="exact"` |
    "banded-lsh" | an already-built index object), not a constructor
    branch; `use_lsh_bands` survives as a v0 alias.
    """

    name = "card"

    def __init__(self,
                 feat_cfg: features.FeatureConfig | None = None,
                 model_cfg: context_model.ContextModelConfig | None = None,
                 threshold: float = 0.3,
                 use_lsh_bands: bool = False,
                 use_kernel: bool = True,
                 fused: bool = True,
                 index: str | Any | None = None,
                 index_args: dict | None = None):
        self.feat_cfg = feat_cfg or features.FeatureConfig()
        self.model_cfg = model_cfg or context_model.ContextModelConfig(m=self.feat_cfg.m)
        assert self.model_cfg.m == self.feat_cfg.m
        self.threshold = threshold
        self.fused = fused
        self._lmax_floor = 0            # set from the chunker cfg in fit()
        self.extractor = features.FeatureExtractor(self.feat_cfg,
                                                   use_kernel=use_kernel,
                                                   fused=fused)
        self.model = context_model.ContextModel(self.model_cfg)
        if index is None:
            index = "banded-lsh" if use_lsh_bands else "exact"
        if isinstance(index, str):
            from repro.api.registry import get_index
            kwargs = dict(index_args or {})
            if index == "exact":
                kwargs.setdefault("use_kernel", use_kernel)
            self.index = get_index(index)(self.model_cfg.d,
                                          threshold=threshold, **kwargs)
        else:
            self.index = index

    def fit(self, training_streams, cfg):
        """Training process (paper Fig. 3 left): chunk the training data in
        stream order, extract initial features, train the CBOW model."""
        # pin the fused path's Lmax bucket at the chunker's max chunk
        # size, so steady-state streams of this config never retrace just
        # because their observed longest chunk straddles a pow2 boundary
        self._lmax_floor = int(getattr(cfg, "max_size", 0) or 0)
        feats = []
        for stream in training_streams:
            chunks, h = chunk_with(cfg, stream)
            if chunks:
                offs = np.asarray([c.offset for c in chunks])
                feats.append(self.extractor([c.data for c in chunks], h, offs,
                                            lmax_floor=self._lmax_floor))
        if not feats:
            raise ValueError("CARD needs at least one training stream")
        self.model.fit(np.concatenate(feats, axis=0))

    def extract(self, batch: DetectBatch) -> np.ndarray:
        init = self.extractor([c.data for c in batch.chunks],
                              batch.stream_hashes, batch.offsets,
                              lmax_floor=self._lmax_floor)
        if not self.fused:
            return self.model.transform(init)                 # [n, D]
        # bucket the row count so the jitted projection compiles once per
        # pow2 bucket, not once per stream length (DESIGN.md §8); the
        # transform is row-wise, so padding rows changes nothing
        n = init.shape[0]
        pad = features.bucket_pow2(n, 16) - n
        if pad:
            init = np.pad(init, ((0, pad), (0, 0)))
        return self.model.transform(init)[:n]                 # [n, D]

    def score(self, feats: np.ndarray, batch: DetectBatch) -> DetectResult:
        n = len(batch)
        out = np.full(n, -1, np.int64)

        # phase 1: against the stored index
        ext_ids, ext_scores = self.index.query(feats)

        # phase 2: intra-stream (earlier chunks of this stream)
        sims = feats @ feats.T
        iu = np.triu_indices(n)
        sims[iu] = -np.inf                                   # j < i only
        intra_j = sims.argmax(axis=1)
        intra_s = sims[np.arange(n), intra_j]

        use_intra = intra_s >= np.maximum(ext_scores, self.threshold)
        best_id = np.where(use_intra, batch.ids[intra_j], ext_ids)
        best_sc = np.where(use_intra, intra_s, ext_scores)
        ok = (best_sc >= self.threshold) & batch.is_new & (best_id != batch.ids)
        out[ok] = best_id[ok]
        return DetectResult(out, scores=np.where(ok, best_sc, 0.0))

    def observe(self, feats: np.ndarray, batch: DetectBatch) -> None:
        new_mask = batch.is_new.astype(bool)
        if new_mask.any():
            self.index.insert_batch(feats[new_mask], batch.ids[new_mask])


# --- registry factories (repro.api.config builds through these) --------------

@register_detector("dedup-only")
def _build_null() -> NullDetector:
    return NullDetector()


@register_detector("finesse")
def _build_finesse(**sf_args) -> SuperFeatureDetector:
    cfg = baselines.SuperFeatureConfig(**sf_args) if sf_args else None
    return finesse_detector(cfg)


@register_detector("n-transform")
def _build_ntransform(**sf_args) -> SuperFeatureDetector:
    cfg = baselines.SuperFeatureConfig(**sf_args) if sf_args else None
    return ntransform_detector(cfg)


@register_detector("card")
def _build_card(*, feat: dict | None = None, model: dict | None = None,
                threshold: float = 0.3, index: str | None = None,
                index_args: dict | None = None,
                use_kernel: bool = True, fused: bool = True) -> CARDDetector:
    feat_cfg = features.FeatureConfig(**(feat or {}))
    model_kw = dict(model or {})
    model_kw.setdefault("m", feat_cfg.m)
    model_cfg = context_model.ContextModelConfig(**model_kw)
    return CARDDetector(feat_cfg=feat_cfg, model_cfg=model_cfg,
                        threshold=threshold, index=index,
                        index_args=index_args, use_kernel=use_kernel,
                        fused=fused)


def run_workload(detector: Detector, versions: Sequence[bytes],
                 cfg: chunking.ChunkerConfig | None = None,
                 train_on: int = 1) -> StoreStats:
    """Paper experiment harness: fit on the first `train_on` versions, then
    ingest every version through the store; returns final stats."""
    store = DedupStore(detector, cfg)
    store.fit(list(versions[:train_on]))
    for v in versions:
        store.ingest(v)
    return store.stats
