"""Resemblance index over context-aware features.

Two search paths:
  * exact: tiled cosine top-1 against the stored feature matrix — the
    Pallas `sim_topk` kernel (flash-style running max, DESIGN.md §3), with
    a jnp/numpy fallback;
  * banded: SimHash LSH banding for sub-linear candidate lookup at scale
    (sign random projections -> `bands` bucket tables), exact rerank of
    candidates. This is what a 1000-node deployment uses; the exact path
    is the oracle and what the paper-scale experiments run.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.api.registry import register_index


@register_index("exact")
class CosineIndex:
    """Append-only exact cosine top-1 index (features assumed L2-normalized).

    Rows live in an amortized-doubling buffer so inserts are O(D) and the
    query path sees one contiguous matrix.
    """

    def __init__(self, dim: int, threshold: float = 0.3, use_kernel: bool = True):
        self.dim = dim
        self.threshold = threshold
        self._use_kernel = use_kernel
        self._buf = np.zeros((1024, dim), np.float32)
        self._ids = np.zeros(1024, np.int64)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def _grow(self, need: int) -> None:
        cap = self._buf.shape[0]
        if self._n + need <= cap:
            return
        new_cap = max(cap * 2, self._n + need)
        self._buf = np.concatenate([self._buf, np.zeros((new_cap - cap, self.dim), np.float32)])
        self._ids = np.concatenate([self._ids, np.zeros(new_cap - cap, np.int64)])

    def insert(self, feature: np.ndarray, chunk_id: int) -> None:
        self._grow(1)
        self._buf[self._n] = feature
        self._ids[self._n] = chunk_id
        self._n += 1

    def insert_batch(self, features: np.ndarray, chunk_ids: np.ndarray) -> None:
        k = features.shape[0]
        self._grow(k)
        self._buf[self._n:self._n + k] = features
        self._ids[self._n:self._n + k] = chunk_ids
        self._n += k

    def query(self, features: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """[B, D] -> (best chunk_id [B] or -1, best score [B])."""
        q = np.atleast_2d(np.asarray(features, np.float32))
        if self._n == 0:
            return np.full(q.shape[0], -1, np.int64), np.zeros(q.shape[0], np.float32)
        index = self._buf[:self._n]
        if self._use_kernel and self._n >= 512 and q.shape[0] >= 8:
            from repro.kernels import ops as kops
            score, arg = kops.sim_topk(jnp.asarray(q), jnp.asarray(index))
            score, arg = np.asarray(score), np.asarray(arg)
        else:
            sims = q @ index.T
            arg = sims.argmax(axis=1)
            score = sims[np.arange(q.shape[0]), arg]
        ids = self._ids[arg]
        ids = np.where(score >= self.threshold, ids, -1)
        return ids, score


@register_index("banded-lsh")
class BandedLSHIndex:
    """SimHash banding: `bands` tables keyed by `band_bits`-bit sign patterns."""

    def __init__(self, dim: int, bands: int = 16, band_bits: int = 6,
                 threshold: float = 0.3, seed: int = 11):
        # recall at cos=0.6: 1-(1-(1-acos(.6)/pi)^6)^16 ~ 0.9; at cos=0.9 ~ 1.0
        rng = np.random.Generator(np.random.PCG64(seed))
        self.threshold = threshold
        self.bands = bands
        self.band_bits = band_bits
        self._planes = rng.standard_normal((bands, band_bits, dim)).astype(np.float32)
        self._tables: list[dict[int, list[int]]] = [dict() for _ in range(bands)]
        self._feats: dict[int, np.ndarray] = {}

    def _keys(self, feature: np.ndarray) -> np.ndarray:
        return self._keys_batch(feature[None, :])[0]

    def _keys_batch(self, features: np.ndarray) -> np.ndarray:
        """[n, D] -> [n, bands] bucket keys in one projection einsum."""
        signs = (np.einsum("bkd,nd->nbk", self._planes, features) > 0)
        weights = (1 << np.arange(self.band_bits, dtype=np.uint64))
        return (signs.astype(np.uint64) * weights).sum(axis=2)

    def insert(self, feature: np.ndarray, chunk_id: int) -> None:
        self.insert_batch(np.asarray(feature, np.float32)[None, :],
                          np.asarray([chunk_id], np.int64))

    def insert_batch(self, features: np.ndarray, chunk_ids: np.ndarray) -> None:
        features = np.asarray(features, np.float32)
        keys = self._keys_batch(features)                # one [n, bands] einsum
        for i, cid in enumerate(chunk_ids):
            cid = int(cid)
            self._feats[cid] = features[i]
            row = keys[i]
            for b in range(self.bands):
                self._tables[b].setdefault(int(row[b]), []).append(cid)

    def _rerank(self, feature: np.ndarray, keys: np.ndarray) -> tuple[int, float]:
        cands: list[int] = []
        for b in range(self.bands):
            cands.extend(self._tables[b].get(int(keys[b]), ()))
        if not cands:
            return -1, 0.0
        cand_ids = np.unique(np.asarray(cands, np.int64))
        mat = np.stack([self._feats[int(c)] for c in cand_ids])
        sims = mat @ feature
        best = int(sims.argmax())
        score = float(sims[best])
        if score < self.threshold:
            return -1, score
        return int(cand_ids[best]), score

    def query_one(self, feature: np.ndarray) -> tuple[int, float]:
        feature = np.asarray(feature, np.float32)
        return self._rerank(feature, self._keys(feature))

    def query(self, features: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        q = np.atleast_2d(np.asarray(features, np.float32))
        keys = self._keys_batch(q)                       # one [B, bands] einsum
        out_id = np.empty(q.shape[0], np.int64)
        out_sc = np.empty(q.shape[0], np.float32)
        for i, f in enumerate(q):
            out_id[i], out_sc[i] = self._rerank(f, keys[i])
        return out_id, out_sc
