"""Serving driver: batched autoregressive decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
        --batch 8 --prompt-len 32 --gen 32 [--full]

Reduced configs run the real decode path on CPU; full configs are
exercised shape-only via the dry-run (launch/dryrun.py). Reports prefill
and decode tokens/s and validates finiteness.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import make_model


def serve_loop(model, params, prompts, gen_len: int, temperature: float = 0.0,
               rng=None):
    b, plen = prompts.shape
    cache = model.init_cache(b, plen + gen_len)
    dec = jax.jit(model.decode_step)
    logits = None
    t0 = time.time()
    for i in range(plen):
        logits, cache = dec(params, prompts[:, i:i + 1], cache)
    prefill_s = time.time() - t0

    toks = []
    tok = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.time()
    for _ in range(gen_len):
        toks.append(np.asarray(tok)[:, 0])
        logits, cache = dec(params, tok, cache)
        if temperature > 0 and rng is not None:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits / temperature)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None]
    decode_s = time.time() - t0
    return np.stack(toks, axis=1), prefill_s, decode_s


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    out, prefill_s, decode_s = serve_loop(model, params, prompts, args.gen,
                                          args.temperature,
                                          jax.random.PRNGKey(2))
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill {args.prompt_len} steps: {prefill_s:.2f}s "
          f"({args.batch * args.prompt_len / max(prefill_s, 1e-9):.1f} tok/s)")
    print(f"decode  {args.gen} steps: {decode_s:.2f}s "
          f"({args.batch * args.gen / max(decode_s, 1e-9):.1f} tok/s)")
    assert np.isfinite(out).all()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
