"""Dry-run cell construction: (arch x input-shape x mesh) -> lowered step.

Everything is ShapeDtypeStruct-driven — no parameter allocation ever
happens; `.lower()` traces the full production step (train / prefill /
decode) under the cell's sharding rules, and `.compile()` proves the
distribution config is coherent.

Per-cell policy knobs (all overridable by the perf hillclimb):
  * fsdp: shard the d_model param axis over "data" (ZeRO-3). Default: on
    for train; on for serving when bf16 params exceed ~3 GB/chip under TP
    alone (grok-1, jamba, qwen3).
  * num_microbatches: gradient-accumulation splits for train cells.
  * m_dtype: bf16 first moment for >=100B params (fits 16 GB/chip HBM).
  * long_500k: batch (=1) replicated, KV-cache sequence axis sharded over
    ("data","model") — sequence parallelism for single-stream decode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.configs import get_config, get_shape
from repro.distributed import sharding as shd
from repro.models import make_model
from repro.train import make_train_step
from repro.train.step import init_state


@dataclasses.dataclass
class CellMeta:
    arch: str
    shape_name: str
    kind: str
    chips: int
    fsdp: bool
    num_microbatches: int
    rules: shd.ShardingRules


def _serve_fsdp(cfg) -> bool:
    """Serving gathers FSDP-sharded weights every step (the jamba long_500k
    hillclimb measured a 2170x collective-term penalty), so serve cells use
    plain TP unless bf16 params exceed ~10 GB/chip under the 16-way model
    axis alone (only grok-1: 39 GB/chip -> needs the data axis too)."""
    return cfg.param_count() * 2 / 16 > 10e9


def _default_microbatches(cfg) -> int:
    return 8


def _extras_shapes(cfg, batch: int, dtype, kind: str) -> dict:
    ex = {}
    if cfg.family == "vlm":
        ex["images"] = jax.ShapeDtypeStruct((batch, cfg.num_image_tokens, cfg.d_model), dtype)
    if cfg.family == "audio":
        # decode serves from the cached encoder output ("memory"); train /
        # prefill run the encoder over stub frame embeddings
        name = "memory" if kind == "decode" else "frames"
        ex[name] = jax.ShapeDtypeStruct((batch, cfg.num_audio_frames, cfg.d_model), dtype)
    return ex


def _extras_specs(cfg, rules, kind: str) -> dict:
    out = {}
    name = {"vlm": "images",
            "audio": "memory" if kind == "decode" else "frames"}.get(cfg.family)
    if name:
        out[name] = shd.activation_spec("batch", None, None, rules=rules)
    return out


def input_specs(arch: str, shape_name: str, *, multi_pod: bool = False,
                fsdp: Optional[bool] = None,
                rules: Optional[shd.ShardingRules] = None) -> dict:
    """ShapeDtypeStruct stand-ins + PartitionSpecs for every model input."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    model = make_model(cfg)
    dtype = jnp.dtype(cfg.dtype)

    if rules is None:
        if shape.kind == "decode" and shape.global_batch == 1:
            # long-context single stream: replicate batch, shard the cache
            # sequence axis over every mesh axis (SP decode)
            rules = dataclasses.replace(
                shd.default_rules(cfg, multi_pod=multi_pod, decode=True,
                                  fsdp=_serve_fsdp(cfg) if fsdp is None else fsdp),
                batch=None, cache_seq=("data", "model"))
        elif shape.kind == "train":
            rules = shd.default_rules(cfg, multi_pod=multi_pod,
                                      fsdp=True if fsdp is None else fsdp)
        else:
            rules = shd.default_rules(cfg, multi_pod=multi_pod,
                                      decode=shape.kind == "decode",
                                      fsdp=_serve_fsdp(cfg) if fsdp is None else fsdp)

    b, s = shape.global_batch, shape.seq_len
    tok_spec = shd.activation_spec("batch", None, rules=rules)
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            **_extras_shapes(cfg, b, dtype, shape.kind),
        }
        specs = {"tokens": tok_spec, "labels": tok_spec,
                 **_extras_specs(cfg, rules, shape.kind)}
    elif shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 **_extras_shapes(cfg, b, dtype, shape.kind)}
        specs = {"tokens": tok_spec, **_extras_specs(cfg, rules, shape.kind)}
    else:  # decode
        batch = {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                 **_extras_shapes(cfg, b, dtype, shape.kind)}
        specs = {"token": tok_spec, **_extras_specs(cfg, rules, shape.kind)}
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
        batch["__cache__"] = cache
        specs["__cache__"] = shd.cache_pspecs(cache, rules)
    return {"batch": batch, "specs": specs, "rules": rules, "cfg": cfg,
            "shape": shape, "model": model}


def lower_cell(arch: str, shape_name: str, mesh, *, multi_pod: bool = False,
               fsdp: Optional[bool] = None,
               num_microbatches: Optional[int] = None,
               m_dtype: Optional[str] = None,
               rules: Optional[shd.ShardingRules] = None,
               donate: bool = True):
    """Lower one cell on `mesh`; returns (lowered, meta)."""
    spec = input_specs(arch, shape_name, multi_pod=multi_pod, fsdp=fsdp,
                       rules=rules)
    cfg, shape, model, rules = spec["cfg"], spec["shape"], spec["model"], spec["rules"]
    chips = int(np.prod(mesh.devices.shape))

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = shd.param_pspecs(params_shapes, rules)

    def to_sh(shapes, specs):
        specs = shd.sanitize_pspecs(shapes, specs, mesh)
        return jax.tree_util.tree_map(
            lambda sp: jax.sharding.NamedSharding(mesh, sp), specs,
            is_leaf=lambda x: isinstance(x, P))

    nm = num_microbatches or _default_microbatches(cfg)
    meta = CellMeta(arch, shape_name, shape.kind, chips,
                    fsdp if fsdp is not None else shape.kind == "train",
                    nm if shape.kind == "train" else 0, rules)

    batch, bspecs = spec["batch"], spec["specs"]

    with mesh, shd.use_rules(rules, mesh):
        if shape.kind == "train":
            moments = jnp.bfloat16 if (m_dtype == "bfloat16" or
                                       (m_dtype is None and cfg.param_count() > 1e11)) else None
            tx = optim.adamw(optim.cosine_schedule(3e-4, 2000, 100_000),
                             weight_decay=0.1, m_dtype=moments,
                             max_grad_norm=1.0)
            state_shapes = jax.eval_shape(
                lambda p: init_state(p, tx), params_shapes)
            sspecs = init_state_specs(state_shapes, pspecs)
            step = make_train_step(model, tx, num_microbatches=nm)
            jf = jax.jit(step,
                         in_shardings=(to_sh(state_shapes, sspecs), to_sh(batch, bspecs)),
                         out_shardings=(to_sh(state_shapes, sspecs), None),
                         donate_argnums=(0,) if donate else ())
            lowered = jf.lower(state_shapes, batch)
        elif shape.kind == "prefill":
            def prefill(params, b):
                extras = {k: v for k, v in b.items() if k != "tokens"}
                return model.prefill(params, b["tokens"], extras or None)
            jf = jax.jit(prefill,
                         in_shardings=(to_sh(params_shapes, pspecs), to_sh(batch, bspecs)),
                         out_shardings=None)
            lowered = jf.lower(params_shapes, batch)
        else:
            cache_shapes = batch.pop("__cache__")
            cache_specs = bspecs.pop("__cache__")

            def decode(params, b, cache):
                extras = {k: v for k, v in b.items() if k != "token"}
                return model.decode_step(params, b["token"], cache,
                                         extras or None)
            jf = jax.jit(decode,
                         in_shardings=(to_sh(params_shapes, pspecs), to_sh(batch, bspecs),
                                       to_sh(cache_shapes, cache_specs)),
                         out_shardings=(None, to_sh(cache_shapes, cache_specs)),
                         donate_argnums=(2,) if donate else ())
            lowered = jf.lower(params_shapes, batch, cache_shapes)
    return lowered, meta


def init_state_specs(state_shapes, pspecs):
    """TrainState specs: params/mu/nu follow param specs; scalars replicate."""
    from repro.train.step import TrainState
    from repro.optim.optimizers import OptState
    return TrainState(
        params=pspecs,
        opt_state=OptState(step=P(), mu=pspecs, nu=pspecs),
        step=P(),
    )
