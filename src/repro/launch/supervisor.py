"""Single-host stand-in for the cluster job manager: run a worker command,
restart it on failure (bounded retries), rely on checkpoint/restart for
state. With `--heartbeat-timeout`, a worker that stops producing output is
treated as a straggler/hang and killed+restarted — the same policy a
1000-node deployment applies per-worker.

    python -m repro.launch.supervisor --retries 3 -- \
        python -m repro.launch.train --ckpt-dir /tmp/run --fail-at 12
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import threading
import time


def run_once(cmd: list[str], heartbeat_timeout: float | None) -> int:
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    last_beat = [time.time()]

    def pump():
        for line in proc.stdout:
            sys.stdout.write(line)
            sys.stdout.flush()
            last_beat[0] = time.time()

    t = threading.Thread(target=pump, daemon=True)
    t.start()
    while proc.poll() is None:
        time.sleep(0.5)
        if heartbeat_timeout and time.time() - last_beat[0] > heartbeat_timeout:
            print(f"[supervisor] no heartbeat for {heartbeat_timeout}s — "
                  "killing straggler", flush=True)
            proc.kill()
            proc.wait()
            return -9
    t.join(timeout=5)
    return proc.returncode


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--retries", type=int, default=3)
    ap.add_argument("--heartbeat-timeout", type=float, default=None)
    ap.add_argument("cmd", nargs=argparse.REMAINDER)
    args = ap.parse_args(argv)
    cmd = args.cmd[1:] if args.cmd and args.cmd[0] == "--" else args.cmd
    assert cmd, "no worker command given"

    for attempt in range(args.retries + 1):
        code = run_once(cmd, args.heartbeat_timeout)
        if code == 0:
            print(f"[supervisor] worker finished (attempt {attempt})", flush=True)
            return 0
        print(f"[supervisor] worker exited {code}; "
              f"{'restarting' if attempt < args.retries else 'giving up'}",
              flush=True)
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
