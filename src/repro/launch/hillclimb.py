import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede any jax import — see dryrun.py)

import argparse          # noqa: E402
import json              # noqa: E402
from pathlib import Path  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402


"""Perf-iteration driver: re-lower one cell with explicit overrides and log
the roofline deltas, building the EXPERIMENTS.md §Perf record.

    PYTHONPATH=src python -m repro.launch.hillclimb --arch grok-1-314b \
        --shape train_4k --tag mb4 --microbatches 4
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--fsdp", choices=["on", "off"], default=None)
    ap.add_argument("--m-dtype", default=None)
    args = ap.parse_args()

    overrides = {}
    if args.microbatches is not None:
        overrides["num_microbatches"] = args.microbatches
    if args.fsdp is not None:
        overrides["fsdp"] = args.fsdp == "on"
    if args.m_dtype is not None:
        overrides["m_dtype"] = args.m_dtype

    out = Path(f"artifacts/hillclimb/{args.arch}.{args.shape}.{args.tag}")
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   out_dir=out, **overrides)
    if rec.get("ok"):
        r = rec["roofline"]
        print(json.dumps({
            "tag": args.tag, "t_compute": r["t_compute_s"],
            "t_memory": r["t_memory_s"], "t_collective": r["t_collective_s"],
            "dominant": r["dominant"], "frac": r["roofline_fraction"],
            "mem_gb": rec["memory"]["peak_per_chip_gb"],
        }, indent=1))


if __name__ == "__main__":
    main()
