"""Training driver with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
        --steps 50 --checkpoint-every 10 --ckpt-dir /tmp/run1

Restart semantics: on start, if the checkpoint dir has a committed step,
training resumes from it (data pipeline is (step, shard)-deterministic so
the restarted worker replays exactly its shard — no coordination needed).
`--fail-at N` raises at step N to exercise the restart path;
launch/supervisor.py wraps this process and restarts it, which is the
single-host simulation of a 1000-node job manager rescheduling a worker.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import DedupCheckpointStore, latest_step, restore, save
from repro.configs import get_config
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import make_model
from repro.train import make_train_step
from repro.train.step import TrainState, init_state


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = make_model(cfg)
    tx = optim.adamw(optim.cosine_schedule(args.lr, 20, max(args.steps, 21)),
                     weight_decay=0.1, max_grad_norm=1.0)
    step_fn = jax.jit(make_train_step(model, tx,
                                      num_microbatches=args.microbatches))
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, global_batch=args.batch,
        seq_len=args.seq, shards=1))
    return cfg, model, tx, step_fn, pipe


def extras_for(cfg, batch):
    ex = {}
    if cfg.family == "vlm":
        ex["images"] = np.zeros((batch, cfg.num_image_tokens, cfg.d_model), np.float32)
    if cfg.family == "audio":
        ex["frames"] = np.zeros((batch, cfg.num_audio_frames, cfg.d_model), np.float32)
    return ex


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--dedup-ckpt", action="store_true",
                    help="also mirror checkpoints into the CARD dedup store")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="simulate a worker crash at this step")
    args = ap.parse_args(argv)

    cfg, model, tx, step_fn, pipe = build(args)
    state = init_state(model.init(jax.random.PRNGKey(0)), tx)

    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore(args.ckpt_dir, state, last)
            start = int(last)
            print(f"[resume] restored step {start} from {args.ckpt_dir}", flush=True)

    dstore = DedupCheckpointStore() if args.dedup_ckpt else None
    extras = extras_for(cfg, args.batch)
    t0 = time.time()
    for step in range(start, args.steps):
        if step == args.fail_at and start == 0:
            # fire only on a fresh (non-resumed) run so the restarted worker
            # can make progress — mirrors a one-off node failure
            print(f"[failure-injection] crashing at step {step}", flush=True)
            sys.exit(17)
        batch = dict(pipe.batch(step), **extras)
        state, metrics = step_fn(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.checkpoint_every == 0:
            save(args.ckpt_dir, state, step + 1)
            if dstore is not None:
                stats = dstore.save(jax.device_get(state.params), step + 1)
                print(f"[dedup-ckpt] DCR={stats.dcr:.2f} "
                      f"stored={stats.bytes_stored >> 20}MiB "
                      f"raw={stats.bytes_in >> 20}MiB", flush=True)
    print(f"[done] {args.steps} steps in {time.time()-t0:.1f}s", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
