"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; dryrun.py sets XLA_FLAGS before importing.

Mesh layout (TPU v5e pods of 256 chips):
  single-pod:  (16, 16)      axes ("data", "model")
  multi-pod:   (2, 16, 16)   axes ("pod", "data", "model")

The "pod" axis extends data parallelism across pod boundaries (gradient
all-reduce crosses DCI hierarchically); nothing in the code assumes 2 pods
— growing the leading axis scales to N pods.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    jax >= 0.5 grew ``jax.sharding.AxisType`` and ``make_mesh`` takes an
    ``axis_types`` tuple; 0.4.x has neither. Everything in this repo (and
    the subprocess scripts in tests) builds meshes through this shim so
    the explicit-axis-type request is made exactly where it exists and
    omitted where it would raise AttributeError/TypeError.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return make_mesh((n // model_axis, model_axis), ("data", "model"))
