import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init, and the dry-run needs 512 placeholder host devices to
# build the production mesh. (Everything else — tests, benches — sees 1.)

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import ARCH_IDS, cells as arch_cells, get_config  # noqa: E402
from repro.distributed import roofline as rl  # noqa: E402
from repro.launch.cells import lower_cell     # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path,
             verbose: bool = True, **overrides) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    tag = f"{arch}.{shape_name}.{'multi' if multi_pod else 'single'}"
    t0 = time.time()
    record: dict = {"arch": arch, "shape": shape_name,
                    "mesh": "2x16x16" if multi_pod else "16x16",
                    "chips": chips, "ok": False, "overrides": {
                        k: str(v) for k, v in overrides.items()}}
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh,
                                   multi_pod=multi_pod, **overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        cfg = get_config(arch)
        from repro.configs import get_shape
        roof = rl.build(compiled, hlo, cfg, get_shape(shape_name), chips)

        record.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "kind": meta.kind,
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_per_chip_gb": round(
                    (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
            },
            "roofline": roof.to_dict(),
        })
        if verbose:
            print(f"[ok] {tag}: compile={t_compile:.1f}s "
                  f"mem/chip={record['memory']['peak_per_chip_gb']}GB "
                  f"dominant={roof.dominant} "
                  f"t=({roof.t_compute:.4f},{roof.t_memory:.4f},"
                  f"{roof.t_collective:.4f})s "
                  f"roofline={roof.roofline_fraction:.2%}", flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {tag}: {record['error']}", flush=True)
    out_dir.mkdir(parents=True, exist_ok=True)
    with open(out_dir / f"{tag}.json", "w") as f:
        json.dump(record, f, indent=2)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run driver")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)

    results = []
    for arch in archs:
        shapes = [s.name for s in arch_cells(arch)]
        if args.shape != "all":
            if args.shape not in shapes:
                print(f"[skip] {arch}.{args.shape}: N/A for this arch")
                continue
            shapes = [args.shape]
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}.{shape_name}.{'multi' if mp else 'single'}"
                if args.skip_existing and (out_dir / f"{tag}.json").exists():
                    existing = json.loads((out_dir / f"{tag}.json").read_text())
                    if existing.get("ok"):
                        print(f"[cached] {tag}")
                        results.append(existing)
                        continue
                results.append(run_cell(arch, shape_name, multi_pod=mp,
                                        out_dir=out_dir))
    ok = sum(r.get("ok", False) for r in results)
    print(f"\n{ok}/{len(results)} cells compiled")
    if ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
