"""Public jit'd wrappers around the Pallas kernels.

On TPU the kernels compile natively; everywhere else they run in
interpret=True mode (the kernel body executed op-by-op), which is the
validation mode this container exercises. `ref.py` holds the pure-jnp
oracles used by tests and as large-input fallbacks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.kernels import gear_hash as _gear
from repro.kernels import shingle_embed as _shingle
from repro.kernels import sim_topk as _topk


@functools.lru_cache(maxsize=None)
def _interpret() -> bool:
    # cached: jax.default_backend() walks the backend registry on every
    # call, and this gates every kernel dispatch on the ingest hot path
    return jax.default_backend() != "tpu"


ROW_WIDTH = 8192


def _to_rows(stream: jax.Array, width: int = ROW_WIDTH) -> tuple[jax.Array, int]:
    """Lay a stream out as [R, C] rows, padding R up to a power of two so
    the row-grid kernels (grid=(R,)) compile once per bucket instead of
    once per stream length (DESIGN.md §8)."""
    n = stream.shape[0]
    rows = max(1, -(-n // width))
    rows = 1 << (rows - 1).bit_length()
    pad = rows * width - n
    if pad:
        stream = jnp.pad(stream, (0, pad))
    return stream.reshape(-1, width), n


def gear_hashes(data: jax.Array) -> jax.Array:
    """[n] uint8 byte stream -> [n] uint32 windowed gear hashes."""
    g = hashing.GEAR_TABLE_J[data.astype(jnp.int32)]
    rows, n = _to_rows(g)
    weights = tuple(int(w) for w in hashing.GEAR_WEIGHTS)
    out = _gear.windowed_sum(rows, weights, interpret=_interpret())
    return out.reshape(-1)[:n]


def rabin_fps(data: jax.Array, window: int = hashing.RABIN_WINDOW) -> jax.Array:
    """[n] uint8 byte stream -> [n] uint32 windowed polynomial fingerprints."""
    rows, n = _to_rows(data.astype(jnp.uint32))
    weights = tuple(int(w) for w in hashing.poly_powers(window))
    out = _gear.windowed_sum(rows, weights, interpret=_interpret())
    return out.reshape(-1)[:n]


def shingle_embed(ids: jax.Array, mask: jax.Array, a: jax.Array, b: jax.Array,
                  normalize: bool = True) -> jax.Array:
    """[B, S] shingle ids + mask -> [B, M] initial features."""
    a2 = a.reshape(1, -1).astype(jnp.uint32)
    b2 = b.reshape(1, -1).astype(jnp.uint32)
    total = _shingle.shingle_embed_sum(ids, mask, a2, b2, interpret=_interpret())
    cnt = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1).astype(jnp.float32)
    feat = total / cnt
    if normalize:
        feat = feat / (jnp.linalg.norm(feat, axis=-1, keepdims=True) + 1e-12)
    return feat


def sim_topk(q: jax.Array, index: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[B, D] queries x [N, D] index -> (best score [B], best row [B])."""
    return _topk.sim_topk(q, index, interpret=_interpret())


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True) -> jax.Array:
    """Model-layout wrapper: q [B, Tq, H, hd], k/v [B, Tk, KV, hd]."""
    from repro.kernels import flash_attn as _fa
    out = _fa.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)
