"""Parallel windowed weighted-sum kernel (gear hash / Rabin fingerprints).

The serial rolling hashes of FastCDC / Finesse / N-transform are linear, so
every position's hash is a W-tap correlation over the byte stream
(DESIGN.md §3):

    h_i = sum_{k=0..W-1} w_k * g_{i-k}      (uint32 wraparound)

The stream is laid out as [R, C] rows (row r continues row r-1), and the
grid walks rows. Each step sees its row plus the previous row (for the
W-1-byte halo) and evaluates all C hashes as W static shifted
multiply-adds — pure VPU work with no sequential dependency, in contrast
to the serial CPU loop the paper uses. Tap weights are compile-time
constants baked into the kernel (gear: 1<<k; rabin: p^k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _windowed_sum_kernel(prev_ref, cur_ref, out_ref, *, weights: tuple[int, ...]):
    w = len(weights)
    row = pl.program_id(0)
    cur = cur_ref[...]                      # [1, C] uint32
    prev_tail = prev_ref[:, cur.shape[1] - (w - 1):]  # [1, W-1]
    # Row 0 has no predecessor: its halo must contribute zeros.
    prev_tail = jnp.where(row == 0, jnp.zeros_like(prev_tail), prev_tail)
    ext = jnp.concatenate([prev_tail, cur], axis=1)   # [1, C + W - 1]
    c = cur.shape[1]
    acc = jnp.zeros_like(cur)
    for k, wk in enumerate(weights):
        # g_{i-k} for i in [0, C): ext[:, (W-1-k) : (W-1-k)+C]
        acc = acc + ext[:, w - 1 - k : w - 1 - k + c] * jnp.uint32(wk)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("weights", "interpret"))
def windowed_sum(g: jax.Array, weights: tuple[int, ...],
                 interpret: bool = True) -> jax.Array:
    """g [R, C] uint32 (flattened stream, row-major) -> [R, C] uint32 hashes."""
    r, c = g.shape
    w = len(weights)
    assert c >= w, f"row width {c} must cover the {w}-tap window"
    kernel = functools.partial(_windowed_sum_kernel, weights=weights)
    return pl.pallas_call(
        kernel,
        grid=(r,),
        in_specs=[
            # previous row (halo); clamped at row 0 and masked in-kernel
            pl.BlockSpec((1, c), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.uint32),
        interpret=interpret,
    )(g, g)
