"""Shingle -> M-dim feature embedding kernel (paper Algorithm 1, step 5).

For every (masked-unique) shingle id e: map through M multiply-shift hash
functions to a pseudo-random sub-vector in [-1, 1)^M, L2-normalize it, and
accumulate the sum over shingles:

    out[b, :] = sum_s mask[b,s] * msu(ids[b,s]) / ||msu(ids[b,s])||

(The divide-by-count and final normalization are cheap epilogues done by the
caller.) Blocked (Bb x Sb x M) so each tile lives in VMEM; the S grid axis is
innermost and accumulates into the same output block (TPU grid is
sequential), initialised at s == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shingle_embed_kernel(ids_ref, mask_ref, a_ref, b_ref, out_ref):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                       # [Bb, Sb] uint32
    mask = mask_ref[...]                     # [Bb, Sb] float32 (0/1)
    a = a_ref[...]                           # [1, M] uint32
    b = b_ref[...]                           # [1, M] uint32
    h = ids[:, :, None] * a[None, :, :] + b[None, :, :]   # [Bb, Sb, M] uint32
    v = h.astype(jnp.int32).astype(jnp.float32) * jnp.float32(2.0 ** -31)
    norm = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True)) + jnp.float32(1e-12)
    v = v / norm * mask[:, :, None]
    out_ref[...] += jnp.sum(v, axis=1)


@functools.partial(jax.jit, static_argnames=("block_b", "block_s", "interpret"))
def shingle_embed_sum(ids: jax.Array, mask: jax.Array, a: jax.Array,
                      b: jax.Array, block_b: int = 8, block_s: int = 128,
                      interpret: bool = True) -> jax.Array:
    """ids/mask [B, S], a/b [1, M] -> unnormalized feature sums [B, M]."""
    bsz, s = ids.shape
    m = a.shape[-1]
    pad_b = (-bsz) % block_b
    pad_s = (-s) % block_s
    if pad_b or pad_s:
        ids = jnp.pad(ids, ((0, pad_b), (0, pad_s)))
        mask = jnp.pad(mask, ((0, pad_b), (0, pad_s)))
    bp, sp = ids.shape
    out = pl.pallas_call(
        _shingle_embed_kernel,
        grid=(bp // block_b, sp // block_s),
        in_specs=[
            pl.BlockSpec((block_b, block_s), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_s), lambda i, j: (i, j)),
            pl.BlockSpec((1, m), lambda i, j: (0, 0)),
            pl.BlockSpec((1, m), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, m), jnp.float32),
        interpret=interpret,
    )(ids, mask.astype(jnp.float32), a, b)
    return out[:bsz]
