"""Tiled cosine-similarity top-1 kernel (resemblance search).

score = q @ index^T with a running (max, argmax) — the flash-attention
online-max trick applied to similarity search (DESIGN.md §3): index tiles
stream through VMEM and the [B, N] score matrix never exists in HBM.

Grid = (B blocks, N blocks), N innermost; the output block depends only on
the B index, so the running best accumulates across the sequential N steps.
Padding rows of the index are masked to -inf via the static `n_valid`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sim_topk_kernel(q_ref, idx_ref, best_ref, arg_ref, *, block_n: int,
                     n_valid: int):
    nj = pl.program_id(1)

    @pl.when(nj == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, -jnp.inf)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    q = q_ref[...]                            # [Bb, D]
    idx = idx_ref[...]                        # [Nb, D]
    scores = jax.lax.dot_general(
        q, idx, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # [Bb, Nb]
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + nj * block_n
    scores = jnp.where(col < n_valid, scores, -jnp.inf)
    loc_arg = jnp.argmax(scores, axis=1).astype(jnp.int32)
    loc_max = jnp.max(scores, axis=1)
    prev = best_ref[:, 0]
    take = loc_max > prev
    best_ref[:, 0] = jnp.where(take, loc_max, prev)
    arg_ref[:, 0] = jnp.where(take, loc_arg + nj * block_n, arg_ref[:, 0])


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def sim_topk(q: jax.Array, index: jax.Array, block_b: int = 8,
             block_n: int = 1024, interpret: bool = True
             ) -> tuple[jax.Array, jax.Array]:
    """q [B, D] x index [N, D] -> (best score [B], best row id [B] int32)."""
    bsz, d = q.shape
    n = index.shape[0]
    block_n = min(block_n, max(128, 1 << (n - 1).bit_length()))
    pad_b = (-bsz) % block_b
    pad_n = (-n) % block_n
    if pad_b:
        q = jnp.pad(q, ((0, pad_b), (0, 0)))
    if pad_n:
        index = jnp.pad(index, ((0, pad_n), (0, 0)))
    bp, np_ = q.shape[0], index.shape[0]
    kernel = functools.partial(_sim_topk_kernel, block_n=block_n, n_valid=n)
    best, arg = pl.pallas_call(
        kernel,
        grid=(bp // block_b, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(q, index)
    return best[:bsz, 0], arg[:bsz, 0]
