"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.features import embed_shingles_j


def windowed_sum_ref(g: jax.Array, weights: np.ndarray) -> jax.Array:
    """h_i = sum_k weights[k] * g_{i-k} over the *flattened* [R, C] stream."""
    r, c = g.shape
    flat = hashing.windowed_weighted_sum_j(g.reshape(-1), weights)
    return flat.reshape(r, c)


def shingle_embed_ref(ids: jax.Array, mask: jax.Array, a: jax.Array,
                      b: jax.Array) -> jax.Array:
    """Masked normalized-sub-vector sum (unnormalized; callers normalize)."""
    return embed_shingles_j(ids, mask, a, b, normalize=False)


def sim_topk_ref(q: jax.Array, index: jax.Array) -> tuple[jax.Array, jax.Array]:
    """q [B, D], index [N, D] -> (best score [B], best row [B])."""
    scores = q @ index.T
    arg = jnp.argmax(scores, axis=1)
    best = jnp.take_along_axis(scores, arg[:, None], axis=1)[:, 0]
    return best, arg.astype(jnp.int32)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """[B, H, Tq, Dh] x [B, Hkv, Tk, Dh] -> [B, H, Tq, Dh], GQA-aware."""
    b, h, tq, dh = q.shape
    hkv = k.shape[1]
    group = h // hkv
    qg = q.reshape(b, hkv, group, tq, dh)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(dh)
    if causal:
        tk = k.shape[2]
        mask = jnp.tril(jnp.ones((tq, tk), bool), k=tk - tq)
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, tq, dh).astype(q.dtype)
