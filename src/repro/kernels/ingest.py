"""Fused per-stream ingest pipeline (DESIGN.md §8).

The pre-fusion ingest path ran the scan and Algorithm 1 as a host-bound
pipeline: a 31-pass numpy gear scan, a per-chunk Python loop over
``subchunk_maxgear_np`` (with its own warm-up re-derivation loop), then
shingle/unique/embed dispatches with numpy round-trips in between — and
a fresh XLA compilation whenever the stream's chunk count or longest
chunk changed. This module replaces all of it with TWO jitted device
programs per stream:

    _scan_fused      bytes [Spad] u8
                       -> windowed gear hashes [Spad] u32 (window
                          doubling; stays device-resident: StreamScan)
                       -> bit-packed FastCDC candidate maps (to host,
                          n/8 bytes each, for boundary selection)
    _extract_fused   StreamScan + chunk offsets/lengths [Bpad]
                       -> sub-chunk maxgear LSH [B, K] (two-tier
                          scatter-free segment max)
                       -> shingle ids + per-row uniquification
                       -> multiply-shift embed + normalize -> [B, M]

Two rules make the steady state hit a warm jit cache (zero recompiles,
asserted by tests/test_ingest_fast.py):

  * every dynamic extent is padded up to a power-of-two bucket — the
    stream length, the chunk count B, and the longest-chunk extent Lmax;
  * all knobs that change the traced program (K, N, normalize, embed
    path, FastCDC masks) are static jit arguments.

Padding is sliced away on exit, and padded rows/positions are masked
inside the programs, so every integer stage is bit-identical per row to
the per-chunk numpy oracle (``subchunk_maxgear_np`` -> ``shingle_ids``;
boundaries to ``chunking.chunk_stream``) and the float embed agrees to
~1 ULP (XLA fuses the single program differently than the staged
dispatches) — pinned by tests/test_ingest_fast.py across ragged chunk
sizes including chunks shorter than the 32-byte gear warm-up, plus an
end-to-end verdict/container equality test on real workloads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as _feat
from repro.core import hashing
from repro.core.features import bucket_pow2  # noqa: F401  (canonical rule)

# Monotonic count of XLA traces of the fused program. A trace happens
# exactly when a (shape-bucket, static-arg) combination misses the jit
# cache, so steady-state ingest of same-bucket streams must not move it
# (the zero-recompilation acceptance test reads this).
_TRACES: list[tuple] = []


def trace_count() -> int:
    return len(_TRACES)


# Bucket floors. B matches the historical FeatureExtractor pad floor so
# the embed stage sees the exact shapes the unfused path produced;
# the stream floor keeps tiny commits from fragmenting the cache.
_FLOOR_B = 16
_FLOOR_STREAM = 1 << 16

# The fused program indexes with int32; positions reach at most
# stream_len + one edge tile (<= 128), so cap well below 2**31 and let
# FeatureExtractor route oversized streams to the per-chunk host path.
FUSED_STREAM_LIMIT = 2**31 - 2**20

# Reusable pinned host staging buffers, one per stream bucket. Safe to
# overwrite between scans: the scan program has fully executed (its
# candidate bitmaps are materialized to host) before scan_stream returns.
# Buckets past the cap are allocated transiently so one huge stream does
# not pin its buffer for process lifetime.
_SCAN_BUFS: dict[int, np.ndarray] = {}
_SCAN_BUF_CACHE_CAP = 64 << 20


def _stage(data: np.ndarray, spad: int) -> jax.Array:
    """Zero-copy (dlpack) handoff of a bucket-padded host buffer."""
    buf = _SCAN_BUFS.get(spad)
    if buf is None:
        buf = np.zeros(spad, np.uint8)
        if spad <= _SCAN_BUF_CACHE_CAP:
            _SCAN_BUFS[spad] = buf
    buf[:len(data)] = data
    try:
        return jnp.from_dlpack(buf)
    except Exception:        # older jax / exotic layouts: plain copy
        return jnp.asarray(buf)


class StreamScan:
    """Device-resident gear scan of one stream (bucket-padded), with lazy
    host materialization for the per-chunk numpy paths. Detectors that
    fuse (CARD) read ``.device`` and never pay a round-trip; legacy
    consumers index it like the old [n] uint32 numpy array."""

    def __init__(self, device: jax.Array, n: int) -> None:
        self.device = device            # [bucket_pow2(n)] uint32
        self.n = n
        self._np: np.ndarray | None = None

    def asnumpy(self) -> np.ndarray:
        if self._np is None:
            self._np = np.asarray(self.device)[:self.n]
        return self._np

    def __len__(self) -> int:
        return self.n

    def __getitem__(self, key):
        return self.asnumpy()[key]

    def __array__(self, dtype=None):
        a = self.asnumpy()
        return a if dtype is None else a.astype(dtype)


@functools.partial(jax.jit, static_argnames=("mask_s", "mask_l"))
def _scan_fused(data: jax.Array, *, mask_s: int, mask_l: int):
    """[Spad] u8 -> windowed gear hashes [Spad] u32 (left on device) +
    bit-packed FastCDC boundary-candidate maps (shipped to host).

    Window-doubling evaluation (see hashing.gear_hashes_np): 5 shifted
    adds instead of 31, all uint32 wraparound, bit-identical to the
    serial gear recurrence past the 32B warm-up."""
    _TRACES.append(("scan", data.shape, mask_s, mask_l))
    g = hashing.GEAR_TABLE_J[data.astype(jnp.int32)]
    h = g
    m = data.shape[0]
    w = 1
    while w < hashing.GEAR_WINDOW:
        shifted = jnp.concatenate([jnp.zeros(w, jnp.uint32), h[:m - w]])
        h = h + shifted * jnp.uint32((1 << w) & 0xFFFFFFFF)
        w *= 2
    cand_s = jnp.packbits((h & jnp.uint32(mask_s)) == 0)
    cand_l = jnp.packbits((h & jnp.uint32(mask_l)) == 0)
    return h, cand_s, cand_l


def scan_stream(data: np.ndarray, mask_s: int, mask_l: int
                ) -> tuple[StreamScan, np.ndarray, np.ndarray]:
    """One device program for the chunker scan: returns the device-
    resident StreamScan plus the two [n] bool candidate maps the host
    boundary selection walks. Only bytes go up and packed bits come
    down — the 4-bytes-per-position hash array never round-trips."""
    n = len(data)
    spad = bucket_pow2(n, _FLOOR_STREAM)
    h, cs, cl = _scan_fused(_stage(data, spad),
                            mask_s=int(mask_s), mask_l=int(mask_l))
    cand_s = np.unpackbits(np.asarray(cs))[:n].view(np.bool_)
    cand_l = np.unpackbits(np.asarray(cl))[:n].view(np.bool_)
    return StreamScan(h, n), cand_s, cand_l


# Lmax is a gather extent (a shape), so it is a static argument like the
# feature-config knobs.
@functools.partial(jax.jit, static_argnames=("k", "n", "lmax", "normalize",
                                             "use_kernel"))
def _extract_fused(stream_hashes: jax.Array, offsets: jax.Array,
                   lengths: jax.Array, a: jax.Array, b: jax.Array,
                   *, k: int, n: int, lmax: int, normalize: bool,
                   use_kernel: bool) -> jax.Array:
    """[Spad] u32 hashes + [Bpad] offsets/lengths -> [Bpad, M] features."""
    _TRACES.append((stream_hashes.shape, offsets.shape, lmax, k, n,
                    normalize, use_kernel))
    spad = stream_hashes.shape[0]

    # Sub-chunk maxgear LSH without scatter (XLA CPU scatter is serial and
    # was 10x the cost of the rest of the program combined). Segment j of
    # a length-L chunk spans [floor(j*L/k), floor((j+1)*L/k)) — the
    # _bounds convention — clipped below by the 32B gear warm-up; empty
    # segments must come out 0.
    j = jnp.arange(k + 1)
    lens = jnp.maximum(lengths, 0)
    bounds = (j[None, :] * lens[:, None]) // k          # [B, K+1]
    s_abs = offsets[:, None] + jnp.maximum(bounds[:, :k], _feat._WARMUP)
    e_abs = offsets[:, None] + bounds[:, 1:]            # [B, K] absolute

    tmax = lmax // k + 1                                # max segment width
    if tmax <= 32:
        # tiny chunks: one dense masked gather [B, K, Tmax] is cheapest
        t = jnp.arange(tmax)
        pos = s_abs[:, :, None] + t[None, None, :]
        valid = pos < e_abs[:, :, None]
        vals = jnp.where(valid, stream_hashes[jnp.clip(pos, 0, spad - 1)], 0)
        sub = jnp.max(vals, axis=-1).astype(jnp.uint32)
    else:
        # two-tier max: precompute tile maxes over the stream (one
        # contiguous reshape-reduce), cover each segment's interior with
        # whole tiles and its ragged edges with two <=T-wide gathers.
        # Work per segment drops from Tmax to ~2T + Tmax/T (about 10x at
        # the default chunk config); max is idempotent, so the edge
        # gathers overlapping the tile span (or each other, for segments
        # inside one tile) is harmless.
        tile = min(128, max(8, bucket_pow2(int(tmax ** 0.5))))
        ntiles = tmax // tile + 2
        tiles = jnp.max(stream_hashes.reshape(-1, tile), axis=-1)
        ti0 = (s_abs + tile - 1) // tile                # first whole tile
        ti1 = e_abs // tile                             # one past last
        ji = jnp.arange(ntiles)
        tidx = ti0[:, :, None] + ji[None, None, :]
        tmask = ji[None, None, :] < (ti1 - ti0)[:, :, None]
        interior = jnp.where(
            tmask, tiles[jnp.clip(tidx, 0, tiles.shape[0] - 1)], 0)
        tj = jnp.arange(tile)
        hpos = s_abs[:, :, None] + tj[None, None, :]    # head edge
        hmask = hpos < jnp.minimum(e_abs, ti0 * tile)[:, :, None]
        head = jnp.where(
            hmask, stream_hashes[jnp.clip(hpos, 0, spad - 1)], 0)
        ts = jnp.maximum(s_abs, ti1 * tile)             # tail edge
        tpos = ts[:, :, None] + tj[None, None, :]
        tmask2 = tpos < e_abs[:, :, None]
        tail = jnp.where(
            tmask2, stream_hashes[jnp.clip(tpos, 0, spad - 1)], 0)
        sub = jnp.maximum(jnp.max(interior, axis=-1),
                          jnp.maximum(jnp.max(head, axis=-1),
                                      jnp.max(tail, axis=-1)))
        sub = sub.astype(jnp.uint32)

    ids = _feat.shingle_ids(sub, n)
    ids, mask = _feat.unique_mask(ids)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.shingle_embed(ids, mask, a, b, normalize=normalize)
    return _feat.embed_shingles_j(ids, mask, a, b, normalize)


def extract_stream(stream_hashes: np.ndarray, offsets: np.ndarray,
                   lengths: np.ndarray, a: jax.Array, b: jax.Array,
                   *, k: int, n: int, normalize: bool = True,
                   use_kernel: bool = False,
                   lmax_floor: int = 0) -> np.ndarray:
    """Host entry: bucket-pad everything, run the fused program, slice.

    ``stream_hashes`` may be a StreamScan (already device-resident and
    bucket-padded — the zero-round-trip path the store uses) or a host
    [n] uint32 array. ``lmax_floor`` should be the chunker's max chunk
    size so every stream cut by the same config lands in the same Lmax
    bucket.
    """
    bsz = int(offsets.shape[0])
    if bsz == 0:
        return np.zeros((0, int(a.shape[-1])), np.float32)
    ends = np.asarray(offsets, np.int64) + np.asarray(lengths, np.int64)
    if int(ends.max()) > FUSED_STREAM_LIMIT:
        raise ValueError(
            "fused extract indexes with int32; streams past "
            "FUSED_STREAM_LIMIT must take the per-chunk host path "
            "(FeatureExtractor routes this)")
    lengths = np.asarray(lengths, np.int32)
    offsets = np.asarray(offsets, np.int32)

    if isinstance(stream_hashes, StreamScan):
        sh = stream_hashes.device
    else:
        spad = bucket_pow2(len(stream_hashes), _FLOOR_STREAM)
        sh = np.zeros(spad, np.uint32)
        sh[:len(stream_hashes)] = stream_hashes
    bpad = bucket_pow2(bsz, _FLOOR_B)
    lmax = bucket_pow2(max(int(lengths.max()), 1), max(1, int(lmax_floor)))

    off_p = np.zeros(bpad, np.int32)
    off_p[:bsz] = offsets
    len_p = np.zeros(bpad, np.int32)
    len_p[:bsz] = lengths

    out = _extract_fused(
        jnp.asarray(sh), jnp.asarray(off_p), jnp.asarray(len_p), a, b,
        k=k, n=n, lmax=lmax, normalize=normalize, use_kernel=use_kernel)
    return np.asarray(out)[:bsz]
