"""Pallas TPU kernels for CARD's compute hot spots + the prefill fast path.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
jit'd public wrappers in ops.py (interpret=True off-TPU), pure-jnp oracles
in ref.py. tests/test_kernels.py sweeps shapes/dtypes and asserts
equality/allclose against the oracles.

  gear_hash      windowed weighted-sum scan (gear + Rabin fingerprints):
                 the serial rolling hashes are linear, so every position is
                 a W-tap correlation evaluated in parallel (DESIGN.md §3)
  shingle_embed  multiply-shift M-hash feature accumulation (Algorithm 1)
  sim_topk       tiled cosine top-1 with running (max, argmax) — the
                 flash-attention trick applied to resemblance search
  flash_attn     blockwise online-softmax attention with GQA-by-indexing
"""
from repro.kernels import ops  # noqa: F401
