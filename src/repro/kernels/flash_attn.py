"""Blockwise online-softmax attention kernel (flash-attention schedule).

Grid (B, H, Tq/Bq, Tk/Bk) with the KV axis innermost: each (b, h, qi)
keeps running (max, denom, accumulator) in VMEM scratch across the
sequential KV steps, so the [Tq, Tk] score matrix never exists in HBM —
the TPU-native prefill path whose jnp twin is
models/layers._chunked_attention (same schedule, validated against each
other and against kernels/ref.flash_attention_ref).

GQA without materialization: the K/V BlockSpec index_map sends query head
h to KV head h // group, so grouped heads share K/V blocks by indexing,
not by jnp.repeat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, causal: bool, scale: float,
                  tk_valid: int, nk: int):
    kj = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(F32) * scale              # [Bq, hd]
    k = k_ref[0, 0].astype(F32)                      # [Bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)  # [Bq, Bk]
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < tk_valid
    if causal:
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v_ref[0, 0].astype(F32), (((1,), (0,)), ((), ())),
        preferred_element_type=F32)
    m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = True) -> jax.Array:
    """q [B, H, Tq, hd], k/v [B, KV, Tk, hd] (H % KV == 0) -> [B, H, Tq, hd]."""
    b, h, tq, hd = q.shape
    kvh, tk = k.shape[1], k.shape[2]
    group = h // kvh
    pq = (-tq) % block_q
    pk = (-tk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (tq + pq) // block_q
    nk = (tk + pk) // block_k

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        scale=float(1.0 / np.sqrt(hd)), tk_valid=tk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bb, hh, qi, kj: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, qi, kj, g=group: (bb, hh // g, kj, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, qi, kj, g=group: (bb, hh // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bb, hh, qi, kj: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, tq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), F32),   # running max
            pltpu.VMEM((block_q, 1), F32),   # running denominator
            pltpu.VMEM((block_q, hd), F32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :tq]
