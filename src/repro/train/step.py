"""Train/eval step builders.

Production details:
  * microbatch gradient accumulation (lax.scan) with fp32 accumulators —
    collectives for the gradient all-reduce happen ONCE per step, after
    accumulation (collective deferral, DESIGN.md §6);
  * optimizer is any repro.optim GradientTransform; its state pytree
    mirrors params, so param sharding rules shard optimizer state (ZeRO);
  * optional int8 gradient compression hook (distributed/compress.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import optim

F32 = jnp.float32


class TrainState(NamedTuple):
    params: Any
    opt_state: optim.OptState
    step: jax.Array


def init_state(params, tx: optim.GradientTransform) -> TrainState:
    return TrainState(params=params, opt_state=tx.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model, tx: optim.GradientTransform, *,
                    num_microbatches: int = 1,
                    compress_grads: Optional[Callable] = None,
                    remat: bool = True):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb, remat=remat)
        return loss, metrics

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        params = state.params
        if num_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % num_microbatches == 0
                return x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)
            g0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, F32), params)

            def body(acc, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(F32), acc, g)
                return acc, (l, m)

            grads, (losses, ms) = jax.lax.scan(body, g0, micro)
            grads = jax.tree_util.tree_map(lambda g: g / num_microbatches, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree_util.tree_map(jnp.mean, ms)
        if compress_grads is not None:
            grads = compress_grads(grads)
        deltas, opt_state = tx.update(grads, state.opt_state, params)
        params = optim.apply_updates(params, deltas)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = optim.global_norm(grads)
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch, remat=False)
        return dict(metrics, loss=loss)
    return eval_step
