from repro.train.step import TrainState, make_train_step, make_eval_step  # noqa: F401
