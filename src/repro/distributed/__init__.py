from repro.distributed.sharding import (  # noqa: F401
    ShardingRules,
    activation_spec,
    constrain,
    default_rules,
    param_pspecs,
    shard_map,
    use_rules,
)
