"""GPipe-style pipeline parallelism over a mesh axis (DESIGN.md §6).

Layer blocks are assigned to pipeline stages along a mesh axis (typically
"pod"); microbatches stream through the stages with collective_permute
hand-offs. Schedule: with S stages and M microbatches, the loop runs
M + S - 1 ticks; stage s works on microbatch t - s at tick t (bubble
fraction = (S-1)/(M+S-1), the standard GPipe trade).

The implementation is a shard_map over the pipeline axis: every device
holds ONE stage's parameters (leading stage axis sharded over the axis),
applies its stage, and ppermutes activations to the next stage. ppermute
is differentiable, so jax.grad pipelines the backward pass automatically
(reverse hand-offs).

    y = pipeline_apply(stage_fn, stage_params, x, mesh=mesh,
                       axis="pod", num_microbatches=8)

`stage_fn(params_s, x_mb) -> y_mb` must be shape-preserving (equal-width
stages), which matches the repeating-block structure of
models/transformer.py.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params: Any, x: jax.Array,
                   *, mesh, axis: str = "pod",
                   num_microbatches: int | None = None) -> jax.Array:
    """x [B, ...] -> stacked stage_fn applications, pipelined over `axis`.

    stage_params: pytree with a leading [S] axis (S = mesh.shape[axis]).
    """
    s = mesh.shape[axis]
    b = x.shape[0]
    m = num_microbatches or s
    assert b % m == 0, (b, m)
    mb = b // m

    xs = x.reshape(m, mb, *x.shape[1:])
    perm = [(i, (i + 1) % s) for i in range(s)]

    def local(params_local, xs_local):
        # params_local: this stage's params (leading axis stripped to 1)
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        ticks = m + s - 1

        def tick(carry, t):
            buf = carry                       # activation entering this stage
            inject = xs_local[jnp.minimum(t, m - 1)]
            cur = jnp.where(stage == 0, inject, buf)
            out = stage_fn(params_local, cur)
            nxt = jax.lax.ppermute(out, axis, perm)
            # last stage emits its result at ticks >= s-1
            emit = jnp.where((stage == s - 1) & (t >= s - 1), out,
                             jnp.zeros_like(out))
            return nxt, emit

        _, emits = jax.lax.scan(tick, jnp.zeros_like(xs_local[0]),
                                jnp.arange(ticks))
        # emits[t] holds microbatch t-(s-1); reorder to [M, mb, ...]
        ys = emits[s - 1:]
        return ys

    from repro.distributed.sharding import shard_map
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()),        # params staged; microbatches replicated
        out_specs=P(axis),              # [S, M, mb, ...]; only last stage valid
        check_vma=False)
    stacked = fn(stage_params, xs)      # [S*M, mb, ...] (axis-concatenated)
    ys = stacked.reshape(s, m, mb, *x.shape[1:])[s - 1]
    return ys.reshape(b, *x.shape[1:])


def reference_apply(stage_fn: Callable, stage_params: Any, x: jax.Array) -> jax.Array:
    """Sequential oracle: apply every stage in order (tests)."""
    s = jax.tree_util.tree_leaves(stage_params)[0].shape[0]
    for i in range(s):
        p_i = jax.tree_util.tree_map(lambda p: p[i], stage_params)
        x = stage_fn(p_i, x)
    return x
