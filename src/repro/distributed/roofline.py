"""Roofline-term extraction from AOT-compiled artifacts.

    compute term    = HLO_FLOPs_per_chip / peak_FLOPs
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = sum over collective ops of per-chip tensor bytes x
                      ring factor / link_bw

`compiled.cost_analysis()` supplies per-chip FLOPs/bytes (the module is the
per-partition SPMD program). Collective bytes are NOT in cost_analysis, so
we parse the optimized HLO text and sum operand sizes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute (including
async -start forms). Ring factors: all-reduce moves ~2x its bytes over the
slowest link, the others ~1x.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(?P<shape>\([^=]*?\)|[\w\[\],{}:#*\s]+?)\s+"
    r"(?P<kind>" + "|".join(_COLL_KINDS) + r")(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-chip link bytes by collective kind (ring-factor weighted)."""
    out: dict[str, float] = {k: 0.0 for k in _COLL_KINDS}
    counts: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        if "-done(" in line:  # async completion: counted at -start
            continue
        m = _LINE_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        out[kind] += _shape_bytes(m.group("shape")) * _FACTOR[kind]
        counts[kind] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                  # per-chip
    hbm_bytes: float              # per-chip
    coll_bytes: float             # per-chip, factor-weighted
    coll_by_kind: dict[str, Any]
    model_flops_global: float     # 6*N*D etc.
    model_bytes_global: float     # minimum bytes that must move through HBM
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs (catches remat/redundancy)."""
        total = self.flops * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def t_ideal(self) -> float:
        """Analytic lower bound: max(useful FLOPs at peak, minimum bytes at
        full HBM bandwidth) — decode steps are legitimately bandwidth-bound,
        so the ideal for them is the time to stream params + cache once."""
        return max(self.model_flops_global / self.chips / PEAK_FLOPS,
                   self.model_bytes_global / self.chips / HBM_BW)

    @property
    def roofline_fraction(self) -> float:
        return self.t_ideal / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "coll_by_kind": self.coll_by_kind,
            "model_flops_global": self.model_flops_global,
            "model_bytes_global": self.model_bytes_global,
            "chips": self.chips,
            "t_ideal_s": self.t_ideal,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*tokens (train), 2*N_active*tokens
    (prefill/decode), plus attention term 12*L_attn*d*T_ctx per token."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        attn = _attn_flops(cfg, shape.seq_len, causal=True) * shape.global_batch * 3
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_active * tokens
        attn = _attn_flops(cfg, shape.seq_len, causal=True) * shape.global_batch
    else:  # decode: one token per sequence, attention reads the full cache
        tokens = shape.global_batch
        base = 2.0 * n_active * tokens
        attn = (4.0 * _attn_layers(cfg) * cfg.num_heads * cfg.head_dim
                * shape.seq_len) * shape.global_batch
    return base + attn


def model_bytes(cfg, shape) -> float:
    """Minimum HBM traffic (global): weights streamed once per step, plus —
    for decode — the KV cache / SSM state read once."""
    n_active = cfg.active_param_count()
    wbytes = 2.0 * n_active  # bf16
    if shape.kind != "decode":
        return wbytes
    l_attn = _attn_layers(cfg)
    kv = (2.0 * l_attn * shape.seq_len * cfg.num_kv_heads * cfg.head_dim
          * 2.0 * shape.global_batch)
    ssm = 0.0
    if cfg.family in ("ssm", "hybrid"):
        d_inner = 2 * cfg.d_model
        heads = d_inner // cfg.ssm_head_dim
        l_ssm = cfg.num_layers - l_attn
        ssm = 4.0 * l_ssm * heads * cfg.ssm_state * cfg.ssm_head_dim * shape.global_batch
    return wbytes + kv + ssm


def _attn_layers(cfg) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid" and cfg.attn_layer_period:
        return cfg.num_layers // cfg.attn_layer_period
    return cfg.num_layers


def _attn_flops(cfg, seq: int, causal: bool) -> float:
    l = _attn_layers(cfg)
    if not l:
        return 0.0
    # 2 matmuls (QK^T, PV), 2*d_head*H per position pair; causal halves it
    per_layer = 4.0 * cfg.num_heads * cfg.head_dim * seq * seq
    return l * per_layer * (0.5 if causal else 1.0)


def build(compiled, hlo_text: str, cfg, shape, chips: int) -> Roofline:
    """Per-chip roofline. FLOPs/bytes/collectives come from the trip-count-
    aware HLO walk (distributed/hlo_cost.py) because cost_analysis() counts
    while-loop bodies once; raw cost_analysis values are kept for
    cross-checking in the artifact."""
    from repro.distributed import hlo_cost
    ca = compiled.cost_analysis() or {}
    agg = hlo_cost.aggregate(hlo_text)
    return Roofline(
        flops=max(float(agg["flops"]), float(ca.get("flops", 0.0))),
        # TPU-projected terms: the CPU backend (a) legalizes bf16 dots to
        # f32 so boundary collectives ride f32, and (b) materializes
        # standalone f32 convert-fusions of bf16 weights; neither exists on
        # the TPU target (native bf16 MXU, converts fuse into consumers).
        # Raw CPU-text values are kept alongside in coll_by_kind.
        hbm_bytes=max(float(agg["bytes_tpu"]), float(ca.get("bytes accessed", 0.0))),
        coll_bytes=float(agg["coll_bytes_tpu"]),
        coll_by_kind={"bytes": agg["coll"], "counts": agg["coll_n"],
                      "raw_text_bytes": float(agg["coll_bytes"]),
                      "raw_hbm_bytes": float(agg["bytes"]),
                      "f32_share": float(agg["coll_bytes_f32"]),
                      "xla_cost_analysis_flops": float(ca.get("flops", 0.0)),
                      "xla_cost_analysis_bytes": float(ca.get("bytes accessed", 0.0))},
        model_flops_global=model_flops(cfg, shape),
        model_bytes_global=model_bytes(cfg, shape),
        chips=chips,
    )
