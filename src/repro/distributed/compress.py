"""Gradient compression for cross-pod all-reduce (DESIGN.md §6).

int8 block-quantization with error feedback: each gradient leaf is scaled
per block of 256 values to int8 before the (cross-pod) reduction; the
quantization residual is carried locally and added to the next step's
gradient, so the *accumulated* update is unbiased (EF-SGD / 1-bit Adam
lineage). At 512+ chips the pod-crossing gradient bytes drop 4x vs f32
(2x vs bf16).

Usage (train step integration):

    compressor = GradCompressor()
    step = make_train_step(model, tx, compress_grads=compressor)

The transform is pure at the pytree level: state (residuals) lives inside
the callable and is updated functionally via `jax.jit` donation in the
wrapper returned by `stateful()`, or callers thread `(grads, residual)`
through `compress_decompress` directly.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """g -> (int8 codes, per-block f32 scales). Pads to BLOCK internally."""
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def _dequantize_leaf(codes: jax.Array, scale: jax.Array, shape,
                     dtype) -> jax.Array:
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_decompress(grads: Any, residual: Optional[Any] = None
                        ) -> tuple[Any, Any]:
    """Quantize+dequantize each leaf (the network sees int8); returns the
    effective gradients and the new error-feedback residuals."""
    if residual is None:
        residual = jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, r):
        g_ef = g.astype(jnp.float32) + r
        codes, scale = _quantize_leaf(g_ef)
        deq = _dequantize_leaf(codes, scale, g.shape, jnp.float32)
        return deq.astype(g.dtype), g_ef - deq

    out = jax.tree_util.tree_map(one, grads, residual)
    eff = jax.tree_util.tree_map(lambda t: t[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree_util.tree_map(lambda t: t[1], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return eff, new_res


class GradCompressor:
    """Stateful convenience wrapper matching make_train_step's hook.

    NOTE: holds the residual pytree as a Python attribute, so use it with
    one train-step callable at a time (the hook is invoked inside jit; the
    residual is threaded as a constant captured on first trace and updated
    via the returned value — for multi-step jitted loops, thread
    `compress_decompress` manually instead).
    """

    def __init__(self):
        self.residual: Optional[Any] = None

    def __call__(self, grads: Any) -> Any:
        eff, self.residual = compress_decompress(grads, self.residual)
        return eff
