"""Trip-count-aware cost extraction from optimized HLO text.

XLA's `compiled.cost_analysis()` counts each computation ONCE — a 64-layer
scan or an 8-microbatch accumulation loop contributes its body a single
time, so FLOPs/bytes/collective counts are off by orders of magnitude for
scanned models. This module parses the optimized HLO text and:

  1. builds a symbol table (instruction name -> shape) per computation;
  2. computes per-computation costs:
       * dot FLOPs: 2 x |output| x contracted-dim size,
       * HBM bytes: operand+output traffic of top-level ops, where
         - slicing ops move only the slice,
         - fusion operands consumed *only via dynamic-slice inside the
           fused computation* are charged at slice size (this is how the
           stacked-layer weight tables are read inside scans),
         - layout/meta ops are free;
       * collective link-bytes: per-partition tensor bytes x ring factor;
  3. extracts while-loop trip counts from their condition computations
     (the `constant(N)` compared against the induction variable);
  4. propagates costs bottom-up through the call graph (while x trip,
     fusion/call/conditional x 1; fusion callees contribute FLOPs but not
     bytes — they execute in registers/VMEM).

Shapes in the per-partition SPMD module are per-chip, so all results are
per-chip values.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<shape>.*?)\s"
    r"(?P<op>[\w\-]+)\((?P<args>.*)$")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+(?:\([^)]*\))?.*\{\s*$")
_CALLED = re.compile(r"(?:body|to_apply|calls|condition|branch_computations)="
                     r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")

_SLICE_OPS = ("dynamic-slice", "slice", "gather")
_LAYOUT_OPS = ("reshape", "bitcast", "tuple", "get-tuple-element", "parameter",
               "constant", "iota", "after-all", "partition-id", "replica-id",
               "while", "conditional", "optimization-barrier")
_CALL_OPS = ("fusion", "call", "conditional", "custom-call", "async-start",
             "map", "reduce", "sort", "scatter", "select-and-scatter",
             "reduce-window", "all-reduce", "reduce-scatter")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dtype, dims in _SHAPE_TOKEN.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dtype]
    return elems, bytes_


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_n: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    whiles: list = dataclasses.field(default_factory=list)   # (cond, body)
    calls: list = dataclasses.field(default_factory=list)    # (callee, op)
    fusions: list = dataclasses.field(default_factory=list)  # (callee, [arg bytes], out)
    param_eff: dict = dataclasses.field(default_factory=dict)  # idx -> bytes|None
    root_eff: float | None = None   # effective output bytes (DUS roots alias)
    pure_convert: bool = True   # computation contains only converts (dtype
    # legalization artifact: the CPU backend upcasts bf16 weights to f32 via
    # standalone convert fusions; on TPU these fuse into consumers and move
    # no HBM bytes — bytes_tpu discounts them)
    max_const: float = 1.0


def parse_computations(hlo: str) -> dict[str, CompCost]:
    comps: dict[str, CompCost] = {}
    shapes: dict[str, dict[str, str]] = {}
    params: dict[str, dict[str, int]] = {}    # comp -> param name -> index
    uses: dict[str, dict[int, list]] = {}     # comp -> idx -> [(op, out_bytes)]
    dus_upd: dict[str, dict[str, int]] = {}   # comp -> DUS instr -> update bytes
    roots: dict[str, tuple[str, str]] = {}    # comp -> (root name, root args)
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None or (line and not line.startswith(" ") and "{" in line):
            m = _COMP_HEADER.match(line)
            if m and ("->" in line or line.startswith("ENTRY")):
                cur = m.group("name")
                comps[cur] = CompCost()
                shapes[cur] = {}
                params[cur] = {}
                uses[cur] = defaultdict(list)
                dus_upd[cur] = {}
                continue
        if cur is None or line.strip() == "}":
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape_s, op, args = (m.group("name"), m.group("shape"),
                                   m.group("op"), m.group("args"))
        shapes[cur][name] = shape_s
        if raw.lstrip().startswith("ROOT"):
            roots[cur] = (name, op, args)
        c = comps[cur]
        elems, bts = _shape_elems_bytes(shape_s)

        if op == "parameter":
            pm = re.match(r"(\d+)\)?", args)
            if pm:
                params[cur][name] = int(pm.group(1))
            continue
        if op not in ("convert", "bitcast", "reshape", "tuple",
                      "get-tuple-element", "constant"):
            c.pure_convert = False

        # track param usage (for fusion operand effective bytes)
        arg_names = re.findall(r"%([\w.\-]+)", args)
        if op == "dynamic-update-slice" and arg_names:
            upd_b = (_shape_elems_bytes(shapes[cur][arg_names[1]])[1]
                     if len(arg_names) > 1 and arg_names[1] in shapes[cur] else 0)
            if arg_names[0] in params[cur]:
                # param is the DUS target: traffic = the written slice only
                uses[cur][params[cur][arg_names[0]]].append(("dus-target", upd_b))
            for an in arg_names[1:]:
                if an in params[cur]:
                    uses[cur][params[cur][an]].append((op, bts))
            dus_upd[cur][name] = upd_b
        else:
            for an in arg_names:
                if an in params[cur]:
                    uses[cur][params[cur][an]].append((op, bts))

        if op == "while" and "condition=" in line and "body=" in line:
            cond = re.search(r"condition=%?([\w.\-]+)", line).group(1)
            body = re.search(r"body=%?([\w.\-]+)", line).group(1)
            c.whiles.append((cond, body))
            continue

        cm = _CALLED.search(line)
        if cm and op in _CALL_OPS:
            callees = [x.lstrip("%") for x in re.split(r",\s*", cm.group(1))]
            for callee in callees:
                c.calls.append((callee, op))
            if op in ("fusion", "custom-call"):
                arg_bytes = [_shape_elems_bytes(shapes[cur][an])[1]
                             if an in shapes[cur] else 0
                             for an in re.findall(r"%([\w.\-]+)", args)]
                c.fusions.append((callees[0], arg_bytes, bts))

        if op == "constant" and shape_s.strip().startswith("s32[]"):
            mm = re.search(r"constant\((\d+)\)", line)
            if mm:
                c.max_const = max(c.max_const, float(mm.group(1)))

        base = op.replace("-start", "")
        if base in _COLL_FACTOR and not op.endswith("-done"):
            c.coll[base] += bts * _COLL_FACTOR[base]
            c.coll_n[base] += 1
            dt = _SHAPE_TOKEN.findall(shape_s)
            if dt and dt[0][0] == "f32":
                c.coll["_f32"] += bts * _COLL_FACTOR[base]

        if op in ("dot", "convolution"):
            k = _contracted_size(line, args, shapes[cur])
            c.flops += 2.0 * elems * k

        if op not in ("fusion", "custom-call"):  # fusions resolved later
            c.bytes += _plain_bytes(op, bts, args, shapes[cur])

    # effective per-param bytes: slice-only / DUS-target params charge the
    # slice (XLA aliases the untouched remainder in place)
    for comp, pu in uses.items():
        for idx, ulist in pu.items():
            if ulist and all(u[0] in _SLICE_OPS or u[0] == "dus-target"
                             for u in ulist):
                comps[comp].param_eff[idx] = 2.0 * sum(u[1] for u in ulist)
            else:
                comps[comp].param_eff[idx] = None  # full operand

    # effective output bytes: a root that is (a tuple of) dynamic-update-
    # slices writes only the update slices
    for comp, (rname, rop, rargs) in roots.items():
        du = dus_upd.get(comp, {})
        if rop == "dynamic-update-slice" and rname in du:
            comps[comp].root_eff = float(du[rname])
        elif rop == "tuple":
            names = re.findall(r"%([\w.\-]+)", rargs)
            if names and any(n in du for n in names):
                eff = 0.0
                for n in names:
                    if n in du:
                        eff += du[n]
                    elif n in shapes[comp]:
                        eff += _shape_elems_bytes(shapes[comp][n])[1]
                comps[comp].root_eff = eff
    return comps


def _plain_bytes(op: str, out_bytes: int, args: str, table: dict) -> float:
    if op in _LAYOUT_OPS or op in _COLL_FACTOR or op.endswith("-done") \
            or op.endswith("-start"):
        return 0.0
    if op in _SLICE_OPS:
        return 2.0 * out_bytes
    if op == "dynamic-update-slice":
        names = re.findall(r"%([\w.\-]+)", args)
        upd = (_shape_elems_bytes(table[names[1]])[1]
               if len(names) > 1 and names[1] in table else 0)
        return 2.0 * upd
    ab = 0
    for an in re.findall(r"%([\w.\-]+)", args):
        if an in table:
            ab += _shape_elems_bytes(table[an])[1]
    return out_bytes + ab


def _contracted_size(line: str, args: str, table: dict[str, str]) -> int:
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if not m:
        return 1
    dims = [int(d) for d in m.group(1).split(",") if d]
    ops = re.findall(r"%([\w.\-]+)", args)
    if not ops or ops[0] not in table:
        return 1
    lhs_dims = _SHAPE_TOKEN.findall(table[ops[0]])
    if not lhs_dims:
        return 1
    shape = [int(d) for d in lhs_dims[0][1].split(",") if d]
    k = 1
    for d in dims:
        if d < len(shape):
            k *= shape[d]
    return max(k, 1)


def _trip_count(comps: dict[str, CompCost], cond: str) -> float:
    c = comps.get(cond)
    return max(c.max_const, 1.0) if c else 1.0


def aggregate(hlo: str) -> dict:
    """Entry-rooted per-chip totals with loop multipliers applied."""
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line)
            if m:
                entry = m.group("name")
            break
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda k: comps[k].flops, default=None)
    memo: dict[str, dict] = {}

    def fusion_bytes(c: CompCost) -> tuple[float, float]:
        total = tpu = 0.0
        for callee, arg_bytes, out_b in c.fusions:
            cal = comps.get(callee)
            if cal is not None and cal.root_eff is not None:
                sub = min(out_b, cal.root_eff)
            else:
                sub = out_b
            for i, ab in enumerate(arg_bytes):
                eff = cal.param_eff.get(i, None) if cal else None
                sub += min(ab, eff) if eff is not None else ab
            total += sub
            if not (cal is not None and cal.pure_convert):
                tpu += sub
        return total, tpu

    def total(name: str, stack=()) -> dict:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {"flops": 0.0, "bytes": 0.0, "bytes_tpu": 0.0,
                    "coll": {}, "coll_n": {}}
        c = comps[name]
        fb, fb_tpu = fusion_bytes(c)
        out = {"flops": c.flops, "bytes": c.bytes + fb,
               "bytes_tpu": c.bytes + fb_tpu,
               "coll": dict(c.coll), "coll_n": dict(c.coll_n)}

        def add(sub: dict, mult: float, with_bytes: bool = True):
            out["flops"] += sub["flops"] * mult
            if with_bytes:
                out["bytes"] += sub["bytes"] * mult
                out["bytes_tpu"] += sub["bytes_tpu"] * mult
            for k, v in sub["coll"].items():
                out["coll"][k] = out["coll"].get(k, 0.0) + v * mult
            for k, v in sub["coll_n"].items():
                out["coll_n"][k] = out["coll_n"].get(k, 0.0) + v * mult

        for cond, body in c.whiles:
            trip = _trip_count(comps, cond)
            add(total(body, stack + (name,)), trip)
        for callee, kind in c.calls:
            add(total(callee, stack + (name,)), 1.0,
                with_bytes=(kind in ("call", "conditional", "async-start")))
        memo[name] = out
        return out

    agg = total(entry)
    f32 = agg["coll"].pop("_f32", 0.0)
    agg["coll_bytes"] = float(sum(agg["coll"].values()))
    # TPU projection: the CPU backend legalizes bf16 dots to f32 BEFORE the
    # SPMD partitioner, so boundary collectives appear f32 in this text even
    # though every boundary tensor is bf16 by construction (layers.pe); a
    # TPU build moves them in bf16. Halve f32 collective bytes for the
    # projected term (the raw value is kept alongside).
    agg["coll_bytes_f32"] = float(f32)
    agg["coll_bytes_tpu"] = float(agg["coll_bytes"] - f32 / 2.0)
    return agg
