"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Tensors are annotated with *logical* axis names; a `ShardingRules` table maps
each name to mesh axes (or None = replicated). Swapping the table is how the
perf hillclimb changes layouts without touching model code (EXPERIMENTS.md
§Perf), and how decode cells get different layouts than train cells.

GSPMD pads uneven partitions, so rules may map e.g. 40 heads onto a 16-way
axis; rules chosen per-arch avoid the wasteful cases (see default_rules).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


Axes = Any  # None | str | tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axes (None = replicate)."""
    # activation axes
    batch: Axes = ("pod", "data")
    seq: Axes = None            # sequence parallelism when set
    d_model: Axes = None
    heads: Axes = "model"
    kv_heads: Axes = None
    head_dim: Axes = None
    d_ff: Axes = "model"
    vocab: Axes = "model"
    expert: Axes = "model"
    capacity: Axes = None
    cache_seq: Axes = None      # KV-cache / SSM-state seq axis (long-context SP)
    frames: Axes = None         # audio/vision memory tokens
    state: Axes = None          # SSM state dim
    # parameter axes
    p_vocab: Axes = "model"
    p_d_model: Axes = None      # FSDP shards this over "data"
    p_heads: Axes = "model"
    p_kv_heads: Axes = None
    p_d_ff: Axes = "model"
    p_expert: Axes = "model"
    p_moe_ff: Axes = None
    p_ssm_inner: Axes = "model"
    # MoE execution mode: "ep" (experts sharded over model, all_to_all
    # dispatch) when num_experts % model_axis == 0, else "tp" (expert FFNs
    # tensor-parallel over model, local dispatch) — see models/layers.moe.
    moe_mode: str = "ep"

    def get(self, name: str) -> Axes:
        return getattr(self, name)


def default_rules(cfg=None, *, multi_pod: bool = False, fsdp: bool = False,
                  decode: bool = False, seq_shard: bool = False) -> ShardingRules:
    """Per-arch / per-shape sensible defaults.

    * TP shards Q heads / FFN / vocab over "model"; KV heads shard only when
      they divide the axis (GQA with few KV heads replicates them instead of
      paying GSPMD padding on the KV cache).
    * FSDP additionally shards the d_model param axis over "data" (ZeRO-3;
      optimizer state follows params automatically).
    * decode: batch stays on ("pod","data"); the KV-cache sequence axis is
      sharded over "model" (sequence-parallel decode: no arch's KV-head
      count divides 16, so seq is the productive cache axis — attention
      does partial softmax per shard + a small all-reduce).
    """
    batch = ("pod", "data") if multi_pod else ("data",)
    kv_ok = bool(cfg and cfg.num_kv_heads and cfg.num_kv_heads % 16 == 0)
    ep_ok = bool(cfg is None or not cfg.num_experts
                 or (cfg.num_experts * getattr(cfg, "moe_ffn_shards", 1)) % 16 == 0)
    return ShardingRules(
        batch=batch,
        kv_heads="model" if kv_ok else None,
        p_kv_heads="model" if kv_ok else None,
        # FSDP spans the pod axis too on multi-pod meshes: optimizer state
        # per chip halves with every pod added (grok-1: 12.3 -> 6.2 GB/chip)
        p_d_model=(("pod", "data") if multi_pod else ("data",)) if fsdp else None,
        cache_seq=("model" if not kv_ok else None) if decode else None,
        heads="model", p_heads="model",
        moe_mode="ep" if ep_ok else "tp",
        p_expert="model" if ep_ok else None,
        p_moe_ff=None if ep_ok else "model",
    )


_ACTIVE: contextvars.ContextVar[Optional[ShardingRules]] = \
    contextvars.ContextVar("sharding_rules", default=None)
_ACTIVE_MESH: contextvars.ContextVar[Optional[Mesh]] = \
    contextvars.ContextVar("sharding_mesh", default=None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules], mesh: Optional[Mesh] = None):
    tok = _ACTIVE.set(rules)
    tok_m = _ACTIVE_MESH.set(mesh)
    try:
        yield
    finally:
        _ACTIVE.reset(tok)
        _ACTIVE_MESH.reset(tok_m)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across jax versions.

    jax >= 0.5 hoisted shard_map to ``jax.shard_map`` and renamed the
    replication-check kwarg to ``check_vma``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map(check_rep=...)``. Same
    semantics either way, so everything in the repo routes through here
    (the same compat seam as ``repro.launch.mesh.make_mesh``).
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_shard_map
    return legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=check_vma)


def current_rules() -> Optional[ShardingRules]:
    return _ACTIVE.get()


def current_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH.get()


def _flatten(axes_list: tuple[Axes, ...]) -> P:
    out = []
    for a in axes_list:
        if isinstance(a, (list, tuple)):
            a = tuple(x for x in a if x is not None) or None
            if a is not None and len(a) == 1:
                a = a[0]
        out.append(a)
    return P(*out)


def activation_spec(*logical: Optional[str], rules: ShardingRules | None = None) -> P:
    rules = rules or _ACTIVE.get()
    assert rules is not None
    return _flatten(tuple(None if n is None else rules.get(n) for n in logical))


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside use_rules()."""
    rules = _ACTIVE.get()
    if rules is None:
        return x
    spec = activation_spec(*logical, rules=rules)
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Parameter specs: leaf-name -> logical axes (leading stacked-layer axis is
# added automatically for block params).
# ---------------------------------------------------------------------------

_PARAM_AXES: dict[str, tuple[Optional[str], ...]] = {
    "embed": ("p_vocab", "p_d_model"),
    "lm_head": ("p_d_model", "p_vocab"),
    "pos_embed": (None, "p_d_model"),
    # attention
    "wq": ("p_d_model", "p_heads", None),
    "wk": ("p_d_model", "p_kv_heads", None),
    "wv": ("p_d_model", "p_kv_heads", None),
    "wo": ("p_heads", None, "p_d_model"),
    # dense mlp
    "w_gate": ("p_d_model", "p_d_ff"),
    "w_up": ("p_d_model", "p_d_ff"),
    "w_in": ("p_d_model", "p_d_ff"),
    "w_down": ("p_d_ff", "p_d_model"),
    # moe
    "router": ("p_d_model", None),
    "e_gate": ("p_expert", "p_d_model", "p_moe_ff"),
    "e_up": ("p_expert", "p_d_model", "p_moe_ff"),
    "e_in": ("p_expert", "p_d_model", "p_moe_ff"),
    "e_down": ("p_expert", "p_moe_ff", "p_d_model"),
    # ssm (mamba2)
    "in_proj": ("p_d_model", "p_ssm_inner"),
    "conv_w": (None, "p_ssm_inner"),
    "conv_b": ("p_ssm_inner",),
    "a_log": (None,),
    "dt_bias": (None,),
    "out_proj": ("p_ssm_inner", "p_d_model"),
    # norms / scalars
    "scale": (None,),
    "norm": (None,),
}


def _spec_for_leaf(name: str, ndim: int, rules: ShardingRules) -> P:
    axes = _PARAM_AXES.get(name)
    if axes is None:
        return P()  # replicate unknown leaves
    pad = ndim - len(axes)
    full = (None,) * pad + tuple(axes)  # leading stacked-layer axes replicate
    return _flatten(tuple(None if a is None else rules.get(a) for a in full))


_CACHE_AXES: dict[str, tuple[Optional[str], ...]] = {
    # leading n_rep axis is handled by padding, like stacked params
    "k": ("batch", "cache_seq", "kv_heads", None),
    "v": ("batch", "cache_seq", "kv_heads", None),
    "conv": ("batch", None, "p_ssm_inner"),
    "h": ("batch", "p_ssm_inner", None, None),
    "pos": (),
}


def cache_pspecs(cache_tree: Any, rules: ShardingRules) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    for path, leaf in flat:
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        axes = _CACHE_AXES.get(name or "")
        if axes is None:
            specs.append(P())
            continue
        ndim = getattr(leaf, "ndim", 0)
        pad = ndim - len(axes)
        full = (None,) * pad + tuple(axes)
        specs.append(_flatten(tuple(None if a is None else rules.get(a)
                                    for a in full)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_pspecs(params_tree: Any, rules: ShardingRules) -> Any:
    """PartitionSpec pytree mirroring `params_tree` (works on shape structs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for path, leaf in flat:
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        specs.append(_spec_for_leaf(name or "", getattr(leaf, "ndim", 0), rules))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_shardings(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def _axes_size(ax: Axes, mesh: Mesh) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax]
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    return n


def _as_tuple(ax: Axes) -> tuple:
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


def sanitize_pspecs(shapes_tree: Any, specs_tree: Any, mesh: Mesh) -> Any:
    """Make specs legal as pjit INPUT shardings (exact divisibility).

    Interior with_sharding_constraint tolerates uneven shards (GSPMD pads),
    but pjit argument shardings must divide. For each leaf dim whose size
    the assigned axes do not divide, the axes are shifted to the next dim
    if that works (e.g. 40 heads on a 16-way axis -> shard head_dim), else
    dropped (e.g. vocab 51865 -> replicate).
    """
    import numpy as np

    def fix(shape_leaf, spec):
        if not isinstance(spec, P):
            return spec
        dims = tuple(getattr(shape_leaf, "shape", ()) or ())
        entries = list(spec) + [None] * (len(dims) - len(spec))
        out = [list(_as_tuple(e)) for e in entries]
        for i in range(len(dims)):
            keep = []
            for ax in list(out[i]):
                cur = int(np.prod([mesh.shape[a] for a in keep] or [1]))
                if dims[i] % (cur * mesh.shape[ax]) == 0:
                    keep.append(ax)
                else:
                    # shift to the next dim only if it is currently
                    # unsharded (e.g. heads -> head_dim); never pile axes
                    # onto an already-sharded dim
                    if i + 1 < len(dims) and not out[i + 1]:
                        if dims[i + 1] % mesh.shape[ax] == 0:
                            out[i + 1].append(ax)
            out[i] = keep
        cleaned = tuple(None if not e else (e[0] if len(e) == 1 else tuple(e))
                        for e in out)
        return P(*cleaned)

    return jax.tree_util.tree_map(fix, shapes_tree, specs_tree,
                                  is_leaf=lambda x: isinstance(x, P))
