"""Mamba2 SSD (state-space duality) block [arXiv:2405.21060].

Recurrence per head (P = head dim, N = state dim, scalar decay a_t):

    h_t = a_t * h_{t-1} + B_t (dt_t x_t)^T        h: [N, P]
    y_t = C_t^T h_t + D * x_t

Training uses the chunked dual form — quadratic attention-like einsums
*within* a chunk, a single recurrent state hand-off *between* chunks
(lax.scan) — which is the matmul-heavy, MXU-friendly formulation.
Decode is the O(1) recurrent update. Both paths share parameters and are
cross-validated in tests (chunked == step-by-step).

Layout follows Mamba2: in_proj -> [z | xBC | dt]; depthwise conv width-W
over xBC; ngroups=1 (B, C shared across heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import constrain
from repro.models.layers import dense_init, dtype_of, pe

F32 = jnp.float32


def ssm_dims(cfg):
    d_inner = 2 * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    conv_ch = d_inner + 2 * cfg.ssm_state
    return d_inner, heads, conv_ch


def init_ssm(key, cfg):
    d = cfg.d_model
    d_inner, heads, conv_ch = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "in_proj": dense_init(ks[0], (d, d_inner + conv_ch + heads), dtype=dt),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_ch), dtype=dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.zeros((heads,), F32),          # A = -exp(a_log)
        "dt_bias": jnp.zeros((heads,), F32),
        "d_skip": jnp.ones((heads,), F32),
        "out_proj": dense_init(ks[2], (d_inner, d), dtype=dt),
    }


def _split_proj(params, x, cfg):
    d_inner, heads, conv_ch = ssm_dims(cfg)
    proj = pe("btd,de->bte", x, params["in_proj"])
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + conv_ch]
    dt_raw = proj[..., d_inner + conv_ch:]
    return z, xbc, dt_raw


def _conv_scan(params, xbc, conv_state=None):
    """Depthwise causal conv width W. conv_state: [B, W-1, C] history."""
    w = params["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    ext = jnp.concatenate([pad, xbc], axis=1)
    out = sum(ext[:, i:i + xbc.shape[1], :] * params["conv_w"][i]
              for i in range(w))
    out = jax.nn.silu((out + params["conv_b"]).astype(F32))
    new_state = ext[:, -(w - 1):, :]
    return out, new_state


def _gates(params, dt_raw):
    dt = jax.nn.softplus(dt_raw.astype(F32) + params["dt_bias"])  # [B,T,H]
    a = jnp.exp(-dt * jnp.exp(params["a_log"]))                   # decay in (0,1)
    return dt, a


def ssm_train(params, x, cfg, chunk: int = 256):
    """x [B, T, D] -> y [B, T, D] (chunked SSD; T % chunk need not be 0)."""
    b, t, _ = x.shape
    d_inner, heads, conv_ch = ssm_dims(cfg)
    n, p = cfg.ssm_state, cfg.ssm_head_dim

    z, xbc, dt_raw = _split_proj(params, x, cfg)
    xbc, _ = _conv_scan(params, xbc)
    xs = xbc[..., :d_inner].reshape(b, t, heads, p)
    bmat = xbc[..., d_inner:d_inner + n]                          # [B,T,N]
    cmat = xbc[..., d_inner + n:]                                 # [B,T,N]
    dt, a = _gates(params, dt_raw)
    xdt = xs.astype(F32) * dt[..., None]                          # [B,T,H,P]

    pad = (-t) % chunk
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    nc = (t + pad) // chunk

    def rs(u, extra):  # [B, T, ...] -> [nc, B, chunk, ...]
        return u.reshape((b, nc, chunk) + extra).transpose((1, 0, 2) + tuple(range(3, 3 + len(extra))))

    # the recurrence is independent per head: shard heads over "model"
    # (B/C are head-shared; their per-head broadcast happens post-shard)
    xdt = constrain(xdt, "batch", None, "heads", None)
    a = constrain(a, "batch", None, "heads")

    xc = rs(xdt, (heads, p))
    bc = rs(bmat.astype(F32), (n,))
    cc = rs(cmat.astype(F32), (n,))
    ac = rs(a, (heads,))

    def body(h, blk):
        xb, bb, cb, ab = blk            # [B,Q,H,P], [B,Q,N], [B,Q,N], [B,Q,H]
        xb = constrain(xb, "batch", None, "heads", None)
        h = constrain(h, "batch", "heads", None, None)
        la = jnp.cumsum(jnp.log(jnp.maximum(ab, 1e-20)), axis=1)  # [B,Q,H]
        # intra-chunk (dual quadratic form)
        qpos = jnp.arange(chunk)
        causal = qpos[:, None] >= qpos[None, :]
        decay = jnp.exp(la[:, :, None, :] - la[:, None, :, :])    # [B,Q,K,H]
        decay = jnp.where(causal[None, :, :, None], decay, 0.0)
        scores = jnp.einsum("bqn,bkn->bqk", cb, bb)
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, decay, xb)
        # inter-chunk (carried state)
        y_inter = jnp.einsum("bqn,bhnp->bqhp", cb, h) * jnp.exp(la)[..., None]
        # state update
        tail = jnp.exp(la[:, -1:, :] - la)                        # [B,Q,H]
        s_new = jnp.einsum("bkn,bkh,bkhp->bhnp", bb, tail, xb)
        h_new = h * jnp.exp(la[:, -1, :])[:, :, None, None] + s_new
        h_new = constrain(h_new, "batch", "heads", None, None)
        y = constrain(y_intra + y_inter, "batch", None, "heads", None)
        return h_new, y

    h0 = jnp.zeros((b, heads, n, p), F32)
    _, ys = jax.lax.scan(body, h0, (xc, bc, cc, ac))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t + pad, heads, p)[:, :t]
    y = y + xs.astype(F32) * params["d_skip"][:, None]
    y = (y.reshape(b, t, d_inner) * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    y = constrain(y, "batch", "seq", None)
    return pe("bte,ed->btd", y, params["out_proj"])


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    d_inner, heads, conv_ch = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "h": jnp.zeros((batch, heads, cfg.ssm_state, cfg.ssm_head_dim), F32),
    }


def ssm_step(params, x, cfg, cache):
    """Single-token decode: x [B, 1, D] -> (y [B, 1, D], new cache)."""
    b = x.shape[0]
    d_inner, heads, conv_ch = ssm_dims(cfg)
    n, p = cfg.ssm_state, cfg.ssm_head_dim

    z, xbc, dt_raw = _split_proj(params, x, cfg)
    xbc, conv_state = _conv_scan(params, xbc, cache["conv"])
    xs = xbc[:, 0, :d_inner].reshape(b, heads, p)
    bvec = xbc[:, 0, d_inner:d_inner + n]
    cvec = xbc[:, 0, d_inner + n:]
    dt, a = _gates(params, dt_raw)                     # [B,1,H]
    xdt = xs.astype(F32) * dt[:, 0, :, None]           # [B,H,P]

    h = cache["h"] * a[:, 0, :, None, None] + \
        jnp.einsum("bn,bhp->bhnp", bvec.astype(F32), xdt)
    y = jnp.einsum("bn,bhnp->bhp", cvec.astype(F32), h)
    y = y + xs.astype(F32) * params["d_skip"][:, None]
    y = (y.reshape(b, 1, d_inner) * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    out = pe("bte,ed->btd", y, params["out_proj"])
    return out, {"conv": conv_state, "h": h}
