"""Shared transformer layers: RMSNorm, RoPE (incl. partial/2D), GQA
attention (dense / chunked-online-softmax / cached-decode), SwiGLU & GeLU
MLPs, and sort-based token-dispatch MoE with expert parallelism.

Everything is pure functional JAX over plain dict pytrees; activation
sharding is annotated through repro.distributed.constrain (logical names),
so the same code runs single-device smoke tests and 512-chip dry-runs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import constrain

F32 = jnp.float32
NEG_INF = -1e30


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def pe(spec: str, x, w):
    """Projection einsum with bf16 collective boundaries.

    `preferred_element_type=x.dtype` makes the emitted dot produce the
    activation dtype directly, so GSPMD's cross-shard partial-sum
    all-reduces (row-parallel TP) and FSDP weight all-gathers move bf16
    instead of the dot's f32 accumulator — this halved grok-1's dominant
    collective term (EXPERIMENTS.md §Perf iteration 2). MXU accumulation
    stays f32 internally; only the reduce/network dtype changes.
    """
    return jnp.einsum(spec, x, w, preferred_element_type=x.dtype)


# -----------------------------------------------------------------------------
# init helpers
# -----------------------------------------------------------------------------

def dense_init(key, shape, in_axes=(0,), dtype=jnp.float32):
    fan_in = int(np.prod([shape[a] for a in in_axes]))
    return (jax.random.normal(key, shape, jnp.float32)
            * (1.0 / np.sqrt(fan_in))).astype(dtype)


# -----------------------------------------------------------------------------
# RMSNorm
# -----------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(F32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(F32)).astype(x.dtype)


# -----------------------------------------------------------------------------
# RoPE (supports partial rotary — chatglm's rope_fraction=0.5 "2D RoPE")
# -----------------------------------------------------------------------------

def rope_freqs(head_dim: int, fraction: float, theta: float) -> np.ndarray:
    rot = int(head_dim * fraction) // 2 * 2
    return 1.0 / theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot)


def apply_rope(x: jax.Array, positions: jax.Array, fraction: float,
               theta: float) -> jax.Array:
    """x [B, T, H, hd]; positions [T] or [B, T]."""
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    if rot == 0:
        return x
    freqs = jnp.asarray(rope_freqs(hd, fraction, theta))       # [rot/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(F32) * freqs           # [B, T, rot/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xr = x[..., :rot].astype(F32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rotated = jnp.stack([out1, out2], axis=-1).reshape(x[..., :rot].shape)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)


# -----------------------------------------------------------------------------
# Attention
# -----------------------------------------------------------------------------

def init_attention(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "wq": dense_init(ks[0], (d, cfg.num_heads, hd), dtype=dt),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads, hd), dtype=dt),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads, hd), dtype=dt),
        "wo": dense_init(ks[3], (cfg.num_heads, hd, d), in_axes=(0, 1), dtype=dt),
    }


def _dense_attention(q, k, v, *, causal: bool, q_offset=0) -> jax.Array:
    """q [B, Tq, H, hd], k/v [B, Tk, KV, hd] — scores materialized (train /
    decode paths; prefill uses the chunked version).

    bf16 operands with f32 score accumulation (preferred_element_type) and
    bf16 probabilities: softmax stats stay f32 for stability while the big
    [*, Tq, Tk] tensors move at half width (EXPERIMENTS.md §Perf)."""
    b, tq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, tq, kvh, g, hd) * jnp.asarray(hd ** -0.5, q.dtype)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, k, preferred_element_type=F32)
    if causal:
        qpos = q_offset + jnp.arange(tq)[:, None]
        kpos = jnp.arange(k.shape[1])[None, :]
        s = jnp.where((kpos <= qpos)[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqj,bjkd->bqkgd", p, v, preferred_element_type=F32)
    return out.reshape(b, tq, h, hd).astype(q.dtype)


def _chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                       kv_block: int = 1024) -> jax.Array:
    """Online-softmax over KV blocks (forward-only prefill path; the Pallas
    flash_attn kernel implements the same schedule on TPU)."""
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    pad = (-tk) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = k.shape[1] // kv_block
    qg = q.reshape(b, tq, kvh, g, hd) * jnp.asarray(hd ** -0.5, q.dtype)
    qpos = q_offset + jnp.arange(tq)

    ks = k.reshape(b, nb, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nb, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, j = blk
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, kb,
                       preferred_element_type=F32)
        kpos = j * kv_block + jnp.arange(kv_block)
        mask = (kpos[None, :] <= qpos[:, None]) if causal else \
            (kpos[None, :] < tk)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bkgqj,bjkd->bkgqd", p.astype(q.dtype), vb,
            preferred_element_type=F32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, tq, 1), NEG_INF, F32)
    l0 = jnp.zeros((b, kvh, g, tq, 1), F32)
    a0 = jnp.zeros((b, kvh, g, tq, hd), F32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (ks, vs, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-20)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, tq, h, hd).astype(q.dtype)


def attention(params, x, cfg, *, causal=True, kv_cache=None, pos=None,
              memory=None, rope=True):
    """Self- or cross-attention sublayer (projection + mixing + out-proj).

    kv_cache: {"k": [B, T_max, KV, hd], "v": ...} -> returns updated cache.
    memory:   [B, T_mem, D] for cross-attention (keys/values from memory).
    pos:      scalar position for single-token decode.
    """
    src = memory if memory is not None else x
    q = pe("btd,dhk->bthk", x, params["wq"])
    k = pe("btd,dhk->bthk", src, params["wk"])
    v = pe("btd,dhk->bthk", src, params["wv"])
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)

    if rope and memory is None:
        if pos is None:
            positions = jnp.arange(x.shape[1])
        else:
            positions = jnp.full((x.shape[0], x.shape[1]), pos)
        q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        assert pos is not None
        ck = jax.lax.dynamic_update_slice(kv_cache["k"], k.astype(kv_cache["k"].dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(kv_cache["v"], v.astype(kv_cache["v"].dtype),
                                          (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        k = constrain(k, "batch", "cache_seq", "kv_heads", None)
        v = constrain(v, "batch", "cache_seq", "kv_heads", None)
        out = _dense_attention(q, k, v, causal=True, q_offset=pos)
    elif memory is not None:
        out = _dense_attention(q, k, v, causal=False)
    elif x.shape[1] > 8192:
        out = _chunked_attention(q, k, v, causal=causal)
    else:
        out = _dense_attention(q, k, v, causal=causal)

    out = constrain(out, "batch", "seq", "heads", None)
    y = pe("bthk,hkd->btd", out, params["wo"])
    return constrain(y, "batch", "seq", "d_model"), new_cache


# -----------------------------------------------------------------------------
# MLP
# -----------------------------------------------------------------------------

def init_mlp(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg)
    if cfg.act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"w_gate": dense_init(k1, (d, f), dtype=dt),
                "w_up": dense_init(k2, (d, f), dtype=dt),
                "w_down": dense_init(k3, (f, d), dtype=dt)}
    k1, k2 = jax.random.split(key)
    return {"w_in": dense_init(k1, (d, f), dtype=dt),
            "w_down": dense_init(k2, (f, d), dtype=dt)}


def mlp(params, x, cfg):
    if "w_gate" in params:
        g = pe("btd,df->btf", x, params["w_gate"])
        u = pe("btd,df->btf", x, params["w_up"])
        h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(pe("btd,df->btf", x, params["w_in"]).astype(F32)).astype(x.dtype)
    h = constrain(h, "batch", "seq", "d_ff")
    y = pe("btf,fd->btd", h, params["w_down"])
    return constrain(y, "batch", "seq", "d_model")


# -----------------------------------------------------------------------------
# MoE: sort-based token dispatch with capacity (expert-parallel over "model")
# -----------------------------------------------------------------------------

def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    s = cfg.moe_ffn_shards
    ev, fv = e * s, f // s          # virtual-expert layout (exact, see moe())
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {"router": dense_init(ks[0], (d, e))}  # router kept fp32, logical E
    if cfg.act == "swiglu":
        p["e_gate"] = dense_init(ks[1], (ev, d, fv), in_axes=(1,), dtype=dt)
        p["e_up"] = dense_init(ks[2], (ev, d, fv), in_axes=(1,), dtype=dt)
    else:
        p["e_in"] = dense_init(ks[1], (ev, d, fv), in_axes=(1,), dtype=dt)
    p["e_down"] = dense_init(ks[3], (ev, fv, d), in_axes=(1,), dtype=dt)
    return p


def _route_and_dispatch(xt, router, e, k, cap, shards: int = 1):
    """Local (per-device) routing: top-k -> slot positions -> [E_v, C, D] buf.

    Pure local ops (cumsum position counters + scatter) — no sort, no
    cross-device traffic; capacity overflow drops (GShard semantics).
    With `shards` > 1 each logical choice fans out to `shards` half-width
    virtual experts carrying the SAME gate (their outputs sum to the full
    expert's output exactly — hidden units are independent).
    Returns (buf, slot, st, gate_flat, keep, probs, expert).
    """
    t, d = xt.shape
    logits = jnp.einsum("td,de->te", xt.astype(F32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)                    # [T, k] logical
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    ev, kv = e * shards, k * shards
    if shards > 1:
        expert_v = (expert[..., None] * shards
                    + jnp.arange(shards)).reshape(t, kv)      # [T, k*s]
        gate_v = jnp.repeat(gate, shards, axis=-1)
    else:
        expert_v, gate_v = expert, gate

    flat_e = expert_v.reshape(-1)                             # [T*kv] token-major
    oh = jax.nn.one_hot(flat_e, ev, dtype=jnp.int32)          # [T*kv, E_v]
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                              flat_e[:, None], axis=1)[:, 0]  # pos within expert
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, ev * cap)      # OOB -> dropped
    st = jnp.arange(t * kv, dtype=jnp.int32) // kv
    buf = jnp.zeros((ev * cap + 1, d), xt.dtype).at[slot].set(xt[st])[:-1]
    return buf.reshape(ev, cap, d), slot, st, gate_v.reshape(-1), keep, probs, expert


def _combine(y_flat, slot, st, gate_flat, keep, t, d):
    """Inverse of dispatch: gather per-assignment outputs, weight, sum over k."""
    pad = jnp.concatenate([y_flat, jnp.zeros((1, d), y_flat.dtype)])
    contrib = pad[slot]                                       # [T*k, D]
    w = (gate_flat * keep).astype(F32)[:, None]
    return jnp.zeros((t, d), F32).at[st].add(contrib.astype(F32) * w)


def _expert_ffn(params, h, act, f32=F32):
    if "e_gate" in params:
        g = pe("ecd,edf->ecf", h, params["e_gate"])
        u = pe("ecd,edf->ecf", h, params["e_up"])
        a = jax.nn.silu(g.astype(f32)).astype(h.dtype) * u
    else:
        a = jax.nn.gelu(pe("ecd,edf->ecf", h, params["e_in"]).astype(f32)).astype(h.dtype)
    return pe("ecf,efd->ecd", a, params["e_down"])


def moe(params, x, cfg):
    """Top-k routed experts with capacity. Two distributed modes
    (DESIGN.md §6), both built on shard_map so dispatch stays local:

      * "ep" (num_experts % model-axis == 0, e.g. qwen3/jamba): experts are
        sharded over "model"; tokens are split over every mesh axis, routed
        locally, exchanged with ONE all_to_all pair over "model", expert
        FFNs run fully local.
      * "tp" (grok-1's 8 experts on a 16-way axis): expert FFNs are
        tensor-parallel over "model" (d_ff sharded); tokens dispatch
        locally per data shard and the row-parallel e_down psums over
        "model".

    Outside a mesh context (CPU smoke tests) the same local dispatch runs
    without collectives.
    """
    from repro.distributed import sharding as shd

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    vs = cfg.moe_ffn_shards
    ev, kv = e * vs, k * vs
    mesh = shd.current_mesh()
    rules = shd.current_rules()

    if mesh is None or "model" not in mesh.shape:
        t = b * s
        cap = min(int(np.ceil(t * kv * cfg.capacity_factor / ev)), t)
        xt = x.reshape(t, d)
        buf, slot, st, gf, keep, probs, expert = _route_and_dispatch(
            xt, params["router"], e, k, cap, vs)
        y = _expert_ffn(params, buf, cfg.act).reshape(ev * cap, d)
        out = _combine(y, slot, st, gf, keep, t, d)
        aux = _load_balance_loss(probs, expert, e, k)
        return out.astype(x.dtype).reshape(b, s, d), aux

    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map

    m_ax = mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in batch_axes]))
    mode = rules.moe_mode if rules else ("ep" if ev % m_ax == 0 else "tp")

    # token split: batch over dp; seq additionally over model in EP mode
    seq_split = m_ax if (mode == "ep" and s % m_ax == 0) else 1
    x_spec = P(batch_axes if b % dp == 0 else None,
               "model" if seq_split > 1 else None, None)
    b_loc = b // dp if b % dp == 0 else b
    t_loc = b_loc * (s // seq_split)
    cap = max(1, int(np.ceil(t_loc * kv * cfg.capacity_factor / ev)))

    def _wspec(n):
        if mode == "ep":
            return P("model", None, None)
        # TP: d_ff axis over model — e_down is [E, F, D], others [E, D, F]
        return P(None, "model", None) if n == "e_down" else P(None, None, "model")

    wspecs = {n: _wspec(n) for n in params if n.startswith("e_")}
    in_specs = (x_spec, P(None, None),
                tuple(wspecs[n] for n in sorted(wspecs)))
    out_specs = (x_spec, P())
    enames = sorted(wspecs)

    def local_fn(x_loc, router, ws):
        wp = dict(zip(enames, ws))
        bl, sl, _ = x_loc.shape
        t = bl * sl
        xt = x_loc.reshape(t, d)
        buf, slot, st, gf, keep, probs, expert = _route_and_dispatch(
            xt, router, e, k, cap, vs)
        if mode == "ep":
            # send each expert's slice to its owner; receive from all peers
            recv = jax.lax.all_to_all(buf, "model", split_axis=0,
                                      concat_axis=1, tiled=True)  # [Ev/m, m*C, D]
            y = _expert_ffn(wp, recv, cfg.act)
            back = jax.lax.all_to_all(y, "model", split_axis=1,
                                      concat_axis=0, tiled=True)  # [Ev, C, D]
        else:
            y = _expert_ffn(wp, buf, cfg.act)  # F sharded over model
            back = jax.lax.psum(y, "model")     # row-parallel e_down
        out = _combine(back.reshape(ev * cap, d), slot, st, gf, keep, t, d)
        aux = _load_balance_loss(probs, expert, e, k)
        axes = batch_axes + (("model",) if seq_split > 1 or mode == "tp" else ())
        aux = jax.lax.pmean(aux, axes) if axes else aux
        if mode == "tp":  # identical across model columns already (psum'd y)
            pass
        return out.astype(x_loc.dtype).reshape(bl, sl, d), aux

    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    out, aux = fn(x, params["router"],
                  tuple(params[n] for n in enames))
    return constrain(out, "batch", "seq", "d_model"), aux


def _load_balance_loss(probs, expert, e, k):
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    onehot = jax.nn.one_hot(expert, e, dtype=F32).sum(1)      # [T, E]
    f = onehot.mean(0) / k
    p = probs.mean(0)
    return e * jnp.sum(f * p)
