"""Model assembler: every assigned architecture is a pattern of sublayers.

A config induces a repeating *period* of sublayers (attention vs SSM mixer,
MoE vs dense FFN, optional cross-attention), e.g.:

    dense LMs    period 1:  [attn+mlp]                         x L
    grok/qwen3   period 1:  [attn+moe]                         x L
    jamba        period 8:  [ssm+moe, ssm+mlp, ... attn+moe]   x 4
    llama-vision period 5:  [attn+mlp x4, attn+cross+mlp]      x 8
    mamba2       period 1:  [ssm]                              x 24
    whisper      encoder stack + decoder stack (cross every layer)

Parameters of each period-position are stacked across repetitions and the
stack is scanned (jax.lax.scan), so HLO size and compile time are
independent of depth; remat wraps the scanned body.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import constrain
from repro.models import layers as L
from repro.models import ssm as S

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class SublayerKind:
    mixer: str          # "attn" | "ssm"
    moe: bool
    cross: bool
    ffn: bool


def layer_kinds(cfg: ModelConfig) -> list[SublayerKind]:
    kinds = []
    for i in range(cfg.num_layers):
        if cfg.family == "ssm":
            kinds.append(SublayerKind("ssm", False, False, False))
            continue
        if cfg.family == "hybrid" and cfg.attn_layer_period:
            mixer = "attn" if i % cfg.attn_layer_period == cfg.attn_layer_period - 1 else "ssm"
        else:
            mixer = "attn"
        moe = bool(cfg.num_experts) and i % cfg.moe_layer_period == cfg.moe_layer_period - 1
        cross = bool(cfg.cross_attn_period) and i % cfg.cross_attn_period == cfg.cross_attn_period - 1
        kinds.append(SublayerKind(mixer, moe, cross, ffn=True))
    return kinds


def block_period(cfg: ModelConfig) -> int:
    p = 1
    for per in (cfg.moe_layer_period if cfg.num_experts else 1,
                cfg.attn_layer_period or 1,
                cfg.cross_attn_period or 1):
        p = int(np.lcm(p, per))
    assert cfg.num_layers % p == 0, (cfg.name, p)
    return p


# -----------------------------------------------------------------------------
# init
# -----------------------------------------------------------------------------

def _init_sublayer(key, kind: SublayerKind, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"ln1": {"scale": jnp.ones((cfg.d_model,), F32)}}
    if kind.mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    else:
        p["ssm"] = S.init_ssm(ks[0], cfg)
    if kind.cross:
        p["ln_cross"] = {"scale": jnp.ones((cfg.d_model,), F32)}
        p["cross"] = L.init_attention(ks[1], cfg)
    if kind.ffn:
        p["ln2"] = {"scale": jnp.ones((cfg.d_model,), F32)}
        p["moe" if kind.moe else "mlp"] = (
            L.init_moe(ks[2], cfg) if kind.moe else L.init_mlp(ks[2], cfg))
    return p


def init_params(rng: jax.Array, cfg: ModelConfig) -> dict:
    kinds = layer_kinds(cfg)
    period = block_period(cfg)
    n_rep = cfg.num_layers // period
    keys = jax.random.split(rng, cfg.num_layers + 4)
    dt = L.dtype_of(cfg)

    # per-layer params, then stack layers with the same period position
    per_layer = [_init_sublayer(keys[i], kinds[i], cfg)
                 for i in range(cfg.num_layers)]
    blocks = []
    for pos in range(period):
        group = [per_layer[r * period + pos] for r in range(n_rep)]
        blocks.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *group))

    params: dict[str, Any] = {
        "embed": L.dense_init(keys[-1], (cfg.vocab_size, cfg.d_model), dtype=dt),
        "final_norm": {"scale": jnp.ones((cfg.d_model,), F32)},
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), dtype=dt)
    if cfg.encoder_layers:
        enc_kind = SublayerKind("attn", False, False, True)
        ekeys = jax.random.split(keys[-3], cfg.encoder_layers)
        enc = [_init_sublayer(k, enc_kind, cfg) for k in ekeys]
        params["encoder"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *enc)
        params["enc_norm"] = {"scale": jnp.ones((cfg.d_model,), F32)}
        # decoder gets a cross-attn sublayer at every layer
        ckeys = jax.random.split(keys[-4], cfg.num_layers)
        cross = [{"ln_cross": {"scale": jnp.ones((cfg.d_model,), F32)},
                  "cross": L.init_attention(k, cfg)} for k in ckeys]
        groups = []
        for pos in range(period):
            grp = [cross[r * period + pos] for r in range(n_rep)]
            groups.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *grp))
        params["dec_cross"] = groups
    return params


# -----------------------------------------------------------------------------
# sublayer application
# -----------------------------------------------------------------------------

def _apply_sublayer(x, p, kind: SublayerKind, cfg, *, cache=None, pos=None,
                    memory=None, cross_extra=None, decode=False):
    new_cache = {}
    aux = jnp.zeros((), F32)
    h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if kind.mixer == "attn":
        kv = cache.get("kv") if cache else None
        y, new_kv = L.attention(p["attn"], h, cfg, causal=True,
                                kv_cache=kv, pos=pos)
        if new_kv is not None:
            new_cache["kv"] = new_kv
    else:
        if decode:
            y, new_ssm = S.ssm_step(p["ssm"], h, cfg, cache["ssm"])
            new_cache["ssm"] = new_ssm
        else:
            y = S.ssm_train(p["ssm"], h, cfg)
    x = x + y
    cp = cross_extra if cross_extra is not None else p
    if (kind.cross or cross_extra is not None) and memory is not None:
        hc = L.rms_norm(x, cp["ln_cross"]["scale"], cfg.norm_eps)
        yc, _ = L.attention(cp["cross"], hc, cfg, memory=memory)
        x = x + yc
    if kind.ffn:
        h2 = L.rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        if kind.moe:
            y2, aux = L.moe(p["moe"], h2, cfg)
        else:
            y2 = L.mlp(p["mlp"], h2, cfg)
        x = x + y2
    return x, new_cache, aux


def _scan_blocks(x, blocks, cfg, *, kinds_period, cache=None, pos=None,
                 memory=None, dec_cross=None, decode=False, remat=True):
    """Scan the stacked period-groups; cache (if any) is scanned alongside."""

    def body(carry, rep_inputs):
        xc, aux_acc = carry
        rep_params, rep_cache, rep_cross = rep_inputs
        new_rep_cache = []
        for i, kind in enumerate(kinds_period):
            c = rep_cache[i] if rep_cache is not None else None
            ce = rep_cross[i] if rep_cross is not None else None
            xc, nc, aux = _apply_sublayer(
                xc, rep_params[i], kind, cfg, cache=c, pos=pos,
                memory=memory, cross_extra=ce, decode=decode)
            new_rep_cache.append(nc)
            aux_acc = aux_acc + aux
        return (xc, aux_acc), new_rep_cache

    if remat and not decode:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    n_rep = jax.tree_util.tree_leaves(blocks[0])[0].shape[0]
    cache_in = cache if cache is not None else [None] * len(kinds_period)
    xs = (blocks,
          cache if cache is not None else None,
          dec_cross if dec_cross is not None else None)

    # lax.scan needs all xs to have a leading n_rep axis; replace None with
    # dummy zero arrays so the structure is scannable.
    def fix(v):
        return v if v is not None else jnp.zeros((n_rep,), jnp.int8)
    xs = tuple(fix(v) for v in xs)

    def body_wrap(carry, triple):
        rp, rc, rx = triple
        rc = rc if cache is not None else None
        rx = rx if dec_cross is not None else None
        return body(carry, (rp, rc, rx))

    (x, aux), new_cache = jax.lax.scan(body_wrap, (x, jnp.zeros((), F32)), xs)
    return x, (new_cache if cache is not None else None), aux


# -----------------------------------------------------------------------------
# public model API
# -----------------------------------------------------------------------------

def _logits(params, x, cfg):
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head,
                        preferred_element_type=x.dtype)
    return constrain(logits, "batch", "seq", "vocab")


def _embed(params, tokens, cfg):
    x = params["embed"][tokens]
    return constrain(x.astype(L.dtype_of(cfg)), "batch", "seq", "d_model")


def _encode_audio(params, frames, cfg):
    """Encoder stack over precomputed frame embeddings (conv frontend stub)."""
    enc_kind = SublayerKind("attn", False, False, True)

    def body(x, rep):
        h = L.rms_norm(x, rep["ln1"]["scale"], cfg.norm_eps)
        y, _ = L.attention(rep["attn"], h, cfg, causal=False)
        x = x + y
        h2 = L.rms_norm(x, rep["ln2"]["scale"], cfg.norm_eps)
        return x + L.mlp(rep["mlp"], h2, cfg), None

    x, _ = jax.lax.scan(body, frames.astype(L.dtype_of(cfg)), params["encoder"])
    return L.rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def _memory_for(params, cfg, extras):
    if "memory" in extras:  # precomputed encoder output (decode serving path)
        return extras["memory"].astype(L.dtype_of(cfg))
    if cfg.family == "audio":
        return _encode_audio(params, extras["frames"], cfg)
    if cfg.family == "vlm":
        return extras["images"].astype(L.dtype_of(cfg))
    return None


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    def init(self, rng: jax.Array) -> dict:
        return init_params(rng, self.cfg)

    # ---- training ----
    def forward(self, params, tokens, extras=None, remat=True):
        cfg = self.cfg
        kinds = layer_kinds(cfg)[: block_period(cfg)]
        memory = _memory_for(params, cfg, extras or {})
        x = _embed(params, tokens, cfg)
        x, _, aux = _scan_blocks(
            x, params["blocks"], cfg, kinds_period=kinds, memory=memory,
            dec_cross=params.get("dec_cross"), remat=remat)
        return _logits(params, x, cfg), aux

    def loss(self, params, batch, remat=True):
        logits, aux = self.forward(params, batch["tokens"],
                                   {k: v for k, v in batch.items()
                                    if k not in ("tokens", "labels")},
                                   remat=remat)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits.astype(F32), axis=-1)
        gold = jnp.take_along_axis(logits.astype(F32), labels[..., None],
                                   axis=-1)[..., 0]
        nll = jnp.mean(lse - gold)
        z_loss = 1e-4 * jnp.mean(jnp.square(lse))
        return nll + z_loss + 0.01 * aux, {"nll": nll, "aux": aux}

    # ---- serving ----
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        kinds = layer_kinds(cfg)
        period = block_period(cfg)
        n_rep = cfg.num_layers // period
        dt = L.dtype_of(cfg)
        per_pos = []
        for pos in range(period):
            kind = kinds[pos]
            if kind.mixer == "attn":
                kv = {"k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
                      "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt)}
                entry = {"kv": kv}
            else:
                entry = {"ssm": S.init_ssm_cache(cfg, batch, dt)}
            per_pos.append(jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (n_rep,) + x.shape), entry))
        return {"layers": per_pos, "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, tokens, extras=None):
        """Teacher-forced pass returning last-position logits (the compile
        target for prefill_* shapes; cache fill for production serving is
        the decode path's job and is exercised in tests via decode_step)."""
        logits, _ = self.forward(params, tokens, extras, remat=False)
        return logits[:, -1]

    def decode_step(self, params, token, cache, extras=None):
        """token [B, 1] -> (logits [B, V], new cache). One KV/SSM-state update."""
        cfg = self.cfg
        kinds = layer_kinds(cfg)[: block_period(cfg)]
        memory = _memory_for(params, cfg, extras or {})
        pos = cache["pos"]
        x = _embed(params, token, cfg)
        x, new_layers, _ = _scan_blocks(
            x, params["blocks"], cfg, kinds_period=kinds,
            cache=cache["layers"], pos=pos, memory=memory,
            dec_cross=params.get("dec_cross"), decode=True, remat=False)
        logits = _logits(params, x, cfg)[:, 0]
        return logits, {"layers": new_layers, "pos": pos + 1}


def make_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
