"""Object-store serving benchmark: request counts vs injected latency
(DESIGN.md §11.3).

One row per (workload, injected per-request latency, variant) on the
version chains, ingested once through an ``ObjectStoreBackend`` over the
``LocalObjectStore`` fake, then served two ways from a fresh reopen:

    coalesced   the real read path: §9 planned chains with the MB-scale
                coalesce gap, concurrent ranged GETs, double-buffered
                readahead — cold pass (empty decode cache) and a warm
                pass on the same store
    per-chunk   the naive object-store client every backup tool starts
                with: one ``get`` per recipe slot, chain walks and all —
                what the planner exists to beat

The headline column is ``requests`` (client-counted GETs for the cold
pass): at 10 ms injected latency the coalesced path must cut it by >=5x
(the PR gate checked from BENCH_OBJSTORE.json), which is exactly why
``cold_mbps`` diverges between the variants as latency grows — at 0 ms
they are within noise of each other, at S3-like latency the per-chunk
path drowns in round-trips. ``errors`` counts SHA1 mismatches between
restored and original bytes (the smoke gate goes red on any).

Throughputs are best-of-``repeats`` (min-time; shared-CPU box — see
bench_restore.py). Rows land in BENCH_OBJSTORE.json.

    PYTHONPATH=src python -m benchmarks.bench_objstore [--quick] [--json PATH]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import tempfile
import time
from pathlib import Path

from benchmarks import common
from repro import api

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_OBJSTORE.json"

WORKLOADS = ("sql_dump", "vmdk")
LATENCIES = (0.0, 0.01)         # seconds per object-store request
DETECTOR = "card"


def _reopen(tmp: str, latency: float) -> api.DedupStore:
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "backend": "objectstore",
         "backend_args": {"path": tmp, "latency": latency}})
    return api.build_store(cfg)


def _gets(store: api.DedupStore) -> int:
    return store.backend.client.op_counts.get("get", 0)


def _sha_errors(store, jobs, restore_fn) -> tuple[float, int, int]:
    """Run one pass of whole-stream restores; returns (seconds, bytes,
    sha1-mismatch count)."""
    errors = 0
    total = 0
    t0 = time.perf_counter()
    for handle, digest, _ in jobs:
        data = restore_fn(store, handle)
        total += len(data)
        if hashlib.sha1(data).digest() != digest:
            errors += 1
    return time.perf_counter() - t0, total, errors


def _planned(store, handle):
    return store.restore(handle)


def _per_chunk(store, handle):
    """The baseline client: one GET-resolving ``get`` per recipe slot."""
    backend = store.backend
    return b"".join(backend.get(c) for c in backend.recipe(handle))


def run(base_size: int = 3 << 20, versions: int = 3,
        workloads=WORKLOADS, latencies=LATENCIES,
        avg_size: int = 8192, detector: str = DETECTOR,
        repeats: int = 2) -> list[dict]:
    rows = []
    for wl in workloads:
        vs = common.make_versions(wl, base_size, versions)
        cfg = common.detector_config(detector, avg_size=avg_size)
        with tempfile.TemporaryDirectory() as tmp:
            # ingest once with no injected latency (this bench measures
            # the serving path; ingest cost is bench_ingest's story)
            cfg.backend = "objectstore"
            cfg.backend_args = {"path": tmp}
            store = api.build_store(cfg)
            store.fit(list(vs[:1]))
            jobs = []
            for v in vs:
                with store.open_stream() as s:
                    s.write(v)
                jobs.append((s.report.handle, hashlib.sha1(v).digest(),
                             len(v)))
            dcr = store.stats.dcr
            store.close()
            logical_mb = sum(j[2] for j in jobs) / 2**20

            for latency in latencies:
                for variant, restore_fn in (("coalesced", _planned),
                                            ("per-chunk", _per_chunk)):
                    # the naive path at high latency is exactly the slow
                    # case being demonstrated — one timed pass suffices
                    reps = 1 if (variant == "per-chunk"
                                 and latency > 0) else repeats
                    cold_s = warm_s = float("inf")
                    cold_req = warm_req = errors = 0
                    retries = 0
                    for _rep in range(reps):
                        served = _reopen(tmp, latency)
                        g0 = _gets(served)
                        pass_s, _, e1 = _sha_errors(served, jobs,
                                                    restore_fn)
                        if pass_s < cold_s:
                            cold_s = pass_s
                            cold_req = _gets(served) - g0
                        g1 = _gets(served)
                        wpass_s, _, e2 = _sha_errors(served, jobs,
                                                     restore_fn)
                        if wpass_s < warm_s:
                            warm_s = wpass_s
                            warm_req = _gets(served) - g1
                        errors += e1 + e2
                        retries = served.backend.retries
                        served.close()
                    rows.append({
                        "bench": "objstore", "workload": wl,
                        "detector": detector, "variant": variant,
                        "latency_ms": round(latency * 1e3, 3),
                        "versions": versions, "avg_size": avg_size,
                        "bytes_mb": round(logical_mb, 2),
                        "cold_mbps": round(logical_mb / max(1e-9, cold_s),
                                           2),
                        "warm_mbps": round(logical_mb / max(1e-9, warm_s),
                                           2),
                        "requests": cold_req,
                        "warm_requests": warm_req,
                        "req_per_mb": round(cold_req / max(1e-9,
                                                           logical_mb), 2),
                        "retries": retries,
                        "errors": errors,
                        "dcr": round(dcr, 4),
                    })
    return rows


def request_cut(rows: list[dict]) -> list[str]:
    """Human summary: the coalesced-vs-per-chunk request reduction per
    (workload, latency) — the §11.3 headline."""
    out = []
    pairs: dict[tuple, dict[str, int]] = {}
    for r in rows:
        pairs.setdefault((r["workload"], r["latency_ms"]),
                         {})[r["variant"]] = r["requests"]
    for (wl, lat), req in sorted(pairs.items()):
        if "coalesced" in req and "per-chunk" in req:
            cut = req["per-chunk"] / max(1, req["coalesced"])
            out.append(f"# {wl} @ {lat} ms: {req['per-chunk']} -> "
                       f"{req['coalesced']} GETs ({cut:.1f}x fewer)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI smoke)")
    ap.add_argument("--json", default=str(JSON_PATH),
                    help="where to write the JSON row dump")
    args = ap.parse_args()
    if args.quick:
        rows = run(base_size=1 << 20, versions=3,
                   workloads=("sql_dump",), latencies=(0.0, 0.002),
                   repeats=1)
    else:
        rows = run()
    common.emit(rows, "objstore")
    for line in request_cut(rows):
        print(line)
    path = Path(args.json)
    path.write_text(json.dumps(rows, indent=2) + "\n")
    print(f"# wrote {len(rows)} rows to {path}")
    bad = sum(r["errors"] for r in rows)
    if bad:
        raise SystemExit(f"{bad} restores were not byte-identical")


if __name__ == "__main__":
    main()
