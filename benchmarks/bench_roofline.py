"""Aggregate dry-run artifacts into the EXPERIMENTS.md §Roofline table.

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) — this
bench does NOT compile anything itself, so `benchmarks.run` stays fast.
"""
from __future__ import annotations

import json
from pathlib import Path

ART = Path("artifacts/dryrun")


def run(mesh: str = "single") -> list[dict]:
    rows = []
    for p in sorted(ART.glob(f"*.{mesh}.json")):
        r = json.loads(p.read_text())
        if not r.get("ok"):
            rows.append({"bench": "roofline", "arch": r["arch"],
                         "shape": r["shape"], "mesh": r["mesh"],
                         "error": r.get("error", "?")})
            continue
        roof = r["roofline"]
        rows.append({
            "bench": "roofline", "arch": r["arch"], "shape": r["shape"],
            "mesh": r["mesh"],
            "t_compute_s": round(roof["t_compute_s"], 4),
            "t_memory_s": round(roof["t_memory_s"], 4),
            "t_collective_s": round(roof["t_collective_s"], 4),
            "dominant": roof["dominant"],
            "useful_flops_frac": round(roof["useful_flops_fraction"], 4),
            "roofline_frac": round(roof["roofline_fraction"], 4),
            "mem_gb_per_chip": r["memory"]["peak_per_chip_gb"],
        })
    return rows


def main():
    from benchmarks import common
    rows = run("single")
    if not rows:
        print("no dry-run artifacts found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun")
        return
    common.emit(rows, "roofline")


if __name__ == "__main__":
    main()
