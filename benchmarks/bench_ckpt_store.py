"""Beyond-paper: CARD-dedup checkpoint store DCR vs parameter-drift scale
(drift shrinks late in training / with larger batches -> cheaper frequent
checkpoints -> shorter restart gaps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import DedupCheckpointStore


def _tree(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"params": {"w": jax.random.normal(k1, (512, 2048), jnp.bfloat16),
                       "e": jax.random.normal(k2, (2048, 256), jnp.bfloat16)},
            "mu": jax.random.normal(k1, (512, 512), jnp.float32) * 0.01}


def run(sigmas=(1e-3, 1e-4, 1e-5), steps=4) -> list[dict]:
    rows = []
    for byte_plane in (True, False):
        for sigma in sigmas:
            store = DedupCheckpointStore(byte_plane=byte_plane)
            rng = np.random.default_rng(0)
            tree = _tree(1)
            for i in range(steps):
                tree = jax.tree_util.tree_map(
                    lambda x: x + jnp.asarray(rng.standard_normal(x.shape) * sigma, x.dtype)
                    if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
                store.save(tree, step=i)
            s = store.stats
            rows.append({"bench": "ckpt_store", "byte_plane": byte_plane,
                         "drift_sigma": sigma, "dcr": round(s.dcr, 3),
                         "dup": s.dup_chunks, "delta": s.delta_chunks,
                         "raw": s.raw_chunks})
    return rows


def main():
    from benchmarks import common
    common.emit(run(), "ckpt_store")


if __name__ == "__main__":
    main()
