"""Ablations documenting the design decisions recorded in DESIGN.md §1:

  * sub-chunk LSH: locality-sensitive max-gear (ours) vs exact polynomial
    hash (paper-literal reading) — the poly variant collapses under
    insert/delete edits;
  * chunk-context model on/off (CARD's central claim);
  * similarity threshold sensitivity.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import chunking, context_model, features, pipeline


def run(base_size=4 << 20, versions=4, avg=8192) -> list[dict]:
    rows = []
    for wl in common.WORKLOADS:
        vs = common.make_versions(wl, base_size, versions)
        cfg = chunking.ChunkerConfig(avg_size=avg)

        for kind in ("card", "card-poly"):
            stats, _ = common.run_cell(kind, vs, avg)
            rows.append({"bench": "ablation", "workload": wl,
                         "variant": f"lsh:{'maxgear' if kind == 'card' else 'poly'}",
                         "dcr": round(stats.dcr, 4),
                         "delta_chunks": stats.delta_chunks})

        # context model off: raw initial features, same index/threshold
        det = common.detector("card")
        det.model.fit = lambda *a, **k: det.model  # type: ignore[assignment]
        class _Id:
            k = det.model_cfg.k
        def _fit(streams, ccfg, _det=det):
            import numpy as _np
            _det.model._u_pinv = _np.eye(_det.feat_cfg.m, dtype=_np.float32)
            _det.model.params = True  # mark fitted
        det.fit = _fit  # type: ignore[assignment]
        det.index = __import__("repro.core.similarity", fromlist=["x"]).CosineIndex(
            det.feat_cfg.m, threshold=det.threshold, use_kernel=False)
        stats = pipeline.run_workload(det, vs, cfg)
        rows.append({"bench": "ablation", "workload": wl, "variant": "no-context",
                     "dcr": round(stats.dcr, 4), "delta_chunks": stats.delta_chunks})

        for thr in (0.2, 0.3, 0.5):
            det2 = pipeline.CARDDetector(
                feat_cfg=features.FeatureConfig(k=32, m=64, n=2),
                model_cfg=context_model.ContextModelConfig(m=64, d=50, steps=150),
                threshold=thr, use_kernel=False)
            stats = pipeline.run_workload(det2, vs, cfg)
            rows.append({"bench": "ablation", "workload": wl,
                         "variant": f"thr:{thr}", "dcr": round(stats.dcr, 4),
                         "delta_chunks": stats.delta_chunks})
    return rows


def main():
    common.emit(run(), "ablation")


if __name__ == "__main__":
    main()
