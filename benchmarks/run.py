"""Benchmark entry point: one section per paper table/figure + the
framework benches. Prints ``name,...`` CSV sections.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only dcr,time,...]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI-speed)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workloads, every section, fail on first "
                         "raise (perf-plumbing CI gate; implies --quick)")
    ap.add_argument("--only", default=None,
                    help="comma list: dcr,time,dims,kernels,ckpt,ablation,"
                         "roofline,gc,ingest,restore,serve,objstore,cache")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    quick = args.quick or args.smoke

    from benchmarks import (bench_ablation, bench_cache, bench_ckpt_store,
                            bench_dcr, bench_dims, bench_gc, bench_ingest,
                            bench_kernels, bench_objstore, bench_restore,
                            bench_roofline, bench_time, common)

    base = (1 << 20) if args.smoke else (2 << 20) if quick else (6 << 20)
    sizes = common.CHUNK_SIZES[:3] if quick else common.CHUNK_SIZES[:4]

    sections = {
        "dcr": lambda: bench_dcr.run(chunk_sizes=sizes, base_size=base),
        "time": lambda: bench_time.run(chunk_sizes=sizes, base_size=base),
        "dims": lambda: bench_dims.run(base_size=base),
        "kernels": bench_kernels.run,
        "ckpt": bench_ckpt_store.run,
        "ablation": lambda: bench_ablation.run(base_size=min(base, 4 << 20)),
        "roofline": bench_roofline.run,
        "gc": lambda: bench_gc.run(base_size=base,
                                   versions=4 if quick else 6,
                                   retain=2 if quick else 3),
        "ingest": lambda: bench_ingest.run(base_size=base,
                                           versions=3 if quick else 4),
        # each restore row also dumps its store's metrics snapshot
        # (DESIGN.md §12) under bench_metrics/ during the smoke gate, so
        # a BENCH regression ships with its own explanation (CI uploads
        # the directory as a workflow artifact)
        "restore": lambda: bench_restore.run(base_size=base,
                                             versions=3 if quick else 4,
                                             range_reads=100 if quick
                                             else 1000,
                                             repeats=1 if quick else 3,
                                             metrics_dir="bench_metrics"
                                             if args.smoke else None),
        # concurrent serving engine (DESIGN.md §10.7): threaded restore
        # throughput + latency; part of the smoke gate so the reader
        # pool / sharded cache / readahead plumbing cannot silently rot
        "serve": lambda: bench_restore.run_threaded(
            base_size=base, versions=3 if quick else 4,
            threads_list=(2,) if args.smoke else (1, 2, 4),
            warm_reps=2 if quick else 6, repeats=1 if quick else 3),
        # object-store serving (DESIGN.md §11.3): coalesced ranged GETs
        # vs the per-chunk baseline under injected latency; the errors
        # column (SHA1 mismatches after retried faults) feeds the smoke
        # gate below, so restores over the object API must stay
        # byte-identical
        "objstore": lambda: bench_objstore.run(
            base_size=min(base, 2 << 20), versions=3,
            workloads=("sql_dump",) if quick else bench_objstore.WORKLOADS,
            latencies=(0.0, 0.002) if args.smoke else (0.0, 0.01),
            repeats=1 if quick else 2),
        # cache hierarchy (DESIGN.md §14): scan resistance lru vs arc,
        # cold-race singleflight collapse, disk tier over the object
        # store; the singleflight section's errors column (SHA1 checks
        # under the thread race) feeds the smoke gate below
        "cache": lambda: (
            bench_cache.run_scan(base_size=min(base, 1 << 20), versions=3,
                                 range_reads=60, scan_rounds=2, scan_mb=6,
                                 repeats=1, guard=False)
            + bench_cache.run_singleflight(base_size=min(base, 2 << 20),
                                           versions=4, repeats=1)
            + bench_cache.run_tier(base_size=min(base, 1 << 20),
                                   versions=3, repeats=1)
        ) if quick else (bench_cache.run_scan()
                         + bench_cache.run_singleflight()
                         + bench_cache.run_tier()),
    }

    for name, fn in sections.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"\n===== {name} =====", flush=True)
        rows = fn()
        common.emit(rows, name)
        if args.smoke:
            # rows with an errors column count corrupt/failed restores
            # (the serve section's SHA1 mismatches); the gate must go red
            # on them, not just record a nonzero cell — the pre-§10 code
            # corrupts concurrent restores while exiting 0
            bad = sum(r.get("errors", 0) for r in rows)
            if bad:
                raise SystemExit(
                    f"{name}: {bad} corrupt/failed restores — the smoke "
                    f"gate requires error-free serving")
        print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s",
              flush=True)


if __name__ == "__main__":
    main()
