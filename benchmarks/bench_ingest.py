"""End-to-end ingest throughput + stage breakdown (DESIGN.md §8).

One row per (workload, detector) on the sql_dump and vmdk workloads,
ingesting a version chain through a file-backed store and reporting
MB/s end to end plus where the time went (chunk / extract / score /
observe / delta / store). ``card-unfused`` is the per-chunk numpy
extraction baseline (``fused=False``) kept so the fused-path speedup
stays measurable as the code evolves; ``warm_mbps`` excludes the first
version (jit warm-up), which is the steady-state number the shape
buckets are supposed to protect.

Rows land in BENCH_INGEST.json so future PRs have a perf trajectory.

    PYTHONPATH=src python -m benchmarks.bench_ingest [--quick] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

from benchmarks import common
from repro import api

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_INGEST.json"

WORKLOADS = ("sql_dump", "vmdk")
DETECTORS = ("dedup-only", "finesse", "card", "card-unfused")


def _config(kind: str, avg_size: int) -> api.DedupConfig:
    base = "card" if kind == "card-unfused" else kind
    cfg = common.detector_config(base, avg_size=avg_size)
    if kind == "card-unfused":
        cfg.detector_args["fused"] = False
    return cfg


def run(base_size: int = 6 << 20, versions: int = 4,
        detectors=DETECTORS, workloads=WORKLOADS,
        avg_size: int = 8192) -> list[dict]:
    rows = []
    for wl in workloads:
        vs = common.make_versions(wl, base_size, versions)
        for kind in detectors:
            cfg = _config(kind, avg_size)
            with tempfile.TemporaryDirectory() as tmp:
                cfg.backend, cfg.backend_args = "file", {"path": tmp}
                store = api.build_store(cfg)
                store.fit(list(vs[:1]))
                walls = []
                for v in vs:
                    t0 = time.perf_counter()
                    session = store.open_stream()
                    session.write(v)
                    session.commit()
                    walls.append(time.perf_counter() - t0)
                wall = sum(walls)
                # steady state: the first commit pays the jit warm-up the
                # shape buckets then amortize away
                warm_mb = sum(r.bytes_in for r in store.reports[1:]) / 2**20
                warm_s = sum(walls[1:])
                s = store.stats
                mb = s.bytes_in / 2**20
                rows.append({
                    "bench": "ingest", "workload": wl, "detector": kind,
                    "versions": versions, "avg_size": avg_size,
                    "bytes_in_mb": round(mb, 2),
                    "ingest_mbps": round(mb / max(1e-9, wall), 2),
                    "warm_mbps": round(warm_mb / max(1e-9, warm_s), 2),
                    "chunk_s": round(s.chunk_seconds, 4),
                    "extract_s": round(s.extract_seconds, 4),
                    "score_s": round(s.score_seconds, 4),
                    "observe_s": round(s.observe_seconds, 4),
                    "delta_s": round(s.delta_seconds, 4),
                    "store_s": round(s.store_seconds, 4),
                    "dcr": round(s.dcr, 4),
                })
                store.close()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI smoke)")
    ap.add_argument("--json", default=str(JSON_PATH),
                    help="where to write the JSON row dump")
    args = ap.parse_args()
    if args.quick:
        rows = run(base_size=2 << 20, versions=3)
    else:
        rows = run()
    common.emit(rows, "ingest")
    Path(args.json).write_text(json.dumps(rows, indent=2) + "\n")
    print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
