"""Multi-tenant SLO load harness (DESIGN.md §15.5): an open-loop
generator drives a ``DedupServer`` over the object-store backend with a
mixed ingest/restore/range/delete workload across N tenants, then
repeats the run with a transient-fault storm on the backend.

Open loop means arrivals follow a fixed schedule regardless of how the
server keeps up — the honest way to measure tail latency under
overload (a closed loop self-throttles and hides queueing delay,
the "coordinated omission" trap). Every completed restore is verified
by SHA-256 against the bytes ingested; every in-flight request is
awaited with a generous timeout so a hang is detected, never masked.

Two phases, one row each:

    baseline    no faults: p50/p99 restore latency, goodput, shed
                counts (overload shedding can legitimately fire if the
                arrival rate beats the executor).
    fault-drill the same schedule with the backend failing GETs/PUTs
                for a window mid-run (``TransientError`` past the
                retry budget). The §15.4 breaker must open, gate
                writes with typed ``CircuitOpenError``, then recover
                through a half-open probe once the storm passes.

Gates (enforced with ``--check``, CI smoke):
    * zero integrity errors (SHA mismatches) in both phases,
    * zero hangs — every over-deadline request failed *typed*,
    * zero deadline violations (reads completing OK but later than
      deadline + grace; writes past their last shed point are exempt —
      commit atomicity beats lateness, §15.3),
    * the drill demonstrably opened AND recovered the breaker
      (transitions open >= 1, half_open >= 1, final state closed).

Rows land in BENCH_SERVE.json.

    PYTHONPATH=src python -m benchmarks.bench_serve [--quick] [--check]
                                                    [--json PATH]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import random
import sys
import tempfile
import threading
import time
from pathlib import Path

from benchmarks import common
from repro import api
from repro.api.concurrency import DeadlineExceededError, LockTimeout
from repro.api.faults import TransientError
from repro.api.serve import (CircuitBreaker, CircuitOpenError, DedupServer,
                             OverloadError, QuotaExceededError, TenantConfig)

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_SERVE.json"

HANG_TIMEOUT_S = 30.0       # a request not done by then is a hang
LATE_GRACE_S = 0.30         # ok-completion later than deadline+grace = violation
OP_MIX = (("restore", 0.50), ("restore_range", 0.70),
          ("ingest", 0.95), ("delete", 1.00))


class _Storm:
    """Toggleable backend fault hook: while on, every GET/PUT raises a
    retryable ``TransientError`` — the §13.5 brown-out shape the breaker
    exists for. Thread-safe by way of Event."""

    def __init__(self) -> None:
        self.on = threading.Event()
        self.faults = 0

    def __call__(self, op: str, key: str, n: int):
        if self.on.is_set() and op in ("get", "put"):
            self.faults += 1
            return TransientError(503, f"storm: {op} {key}")
        return None


class _TenantState:
    """Dispatcher-side view of one tenant: live handles with their
    expected SHA-256, guarded by a lock (ingest/delete race restores)."""

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.live: dict[int, tuple[int, bytes]] = {}    # handle -> (len, sha)

    def add(self, handle: int, data: bytes) -> None:
        with self.lock:
            self.live[handle] = (len(data), hashlib.sha256(data).digest())

    def pick(self, rng: random.Random) -> tuple[int, int, bytes] | None:
        with self.lock:
            if not self.live:
                return None
            handle = rng.choice(sorted(self.live))
            n, sha = self.live[handle]
            return handle, n, sha

    def take(self, rng: random.Random) -> int | None:
        """Claim a handle for deletion (keeps one live for restores)."""
        with self.lock:
            if len(self.live) < 2:
                return None
            handle = rng.choice(sorted(self.live))
            del self.live[handle]
            return handle


def _build_server(tmp: str, storm: _Storm, tenants: int,
                  latency: float) -> DedupServer:
    cfg = api.DedupConfig.from_dict({
        "detector": "dedup-only", "backend": "objectstore",
        "chunker_args": {"avg_size": 4096},
        "backend_args": {"path": tmp, "latency": latency,
                         "fault_hook": storm, "max_retries": 2,
                         "retry_backoff": 0.01, "retry_deadline": 0.25,
                         "cache_bytes": 1 << 20},
    })
    breaker = CircuitBreaker(fail_threshold=4, window_seconds=5.0,
                             cooldown_seconds=0.5, probe_successes=1)
    return DedupServer(api.build_store(cfg), workers=8, breaker=breaker,
                       default_tenant=TenantConfig(
                           max_inflight=4, max_queue=8,
                           cache_bytes=2 << 20, cache_policy="arc"))


def _classify(exc: BaseException) -> str:
    if isinstance(exc, OverloadError):
        return "shed_overload"
    if isinstance(exc, QuotaExceededError):
        return "shed_quota"
    if isinstance(exc, CircuitOpenError):
        return "shed_circuit"
    if isinstance(exc, (DeadlineExceededError, LockTimeout)):
        return "deadline"
    if isinstance(exc, TransientError):
        return "backend_error"
    if isinstance(exc, KeyError):
        return "missing"        # restore raced a delete: benign, typed
    return "unexpected_error"


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
    return sorted_vals[idx]


def run_phase(srv: DedupServer, storm: _Storm, *, phase: str, tenants: int,
              requests: int, rate_hz: float, payload_bytes: int,
              timeout_s: float, tight_frac: float, seed: int) -> dict:
    """Dispatch ``requests`` open-loop arrivals at ``rate_hz`` across the
    tenants, optionally storming the backend for the middle ~35% of the
    schedule, then drain every future and tally outcomes."""
    rng = random.Random(seed)
    states = {f"t{i}": _TenantState() for i in range(tenants)}
    for name, st in states.items():     # prefill: something to restore
        for k in range(3):
            data = random.Random((seed, name, k).__hash__()).randbytes(
                payload_bytes)
            st.add(srv.ingest(name, data).handle, data)

    storm_window = (int(requests * 0.25), int(requests * 0.60))
    inflight = []       # (op, tenant, deadline_s, t_submit, future, verify)
    tally = {k: 0 for k in ("requests", "ok", "shed_overload", "shed_quota",
                            "shed_circuit", "deadline", "backend_error",
                            "missing", "unexpected_error", "hangs",
                            "deadline_violations", "integrity_errors")}
    restore_lat: list[float] = []
    ok_bytes = 0
    next_ingest_seed = 1 << 20

    t_start = time.perf_counter()
    for i in range(requests):
        if phase == "fault-drill":
            if i == storm_window[0]:
                storm.on.set()
            elif i == storm_window[1]:
                storm.on.clear()
        target = t_start + i / rate_hz
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)           # open loop: never waits for results
        t_submit = time.perf_counter()
        name = f"t{rng.randrange(tenants)}"
        st = states[name]
        r, op = rng.random(), "restore"
        for kind, edge in OP_MIX:
            if r < edge:
                op = kind
                break
        timeout = timeout_s
        if rng.random() < tight_frac:
            timeout = 0.001             # deliberate deadline-miss budget
        tally["requests"] += 1
        try:
            if op == "ingest":
                next_ingest_seed += 1
                data = random.Random(next_ingest_seed).randbytes(
                    payload_bytes)
                fut = srv.submit(name, "ingest", data, timeout=timeout)
                verify = ("ingest", st, data)
            elif op == "delete":
                handle = st.take(rng)
                if handle is None:
                    tally["requests"] -= 1
                    continue
                fut = srv.submit(name, "delete", handle, timeout=timeout)
                verify = ("delete", None, None)
            elif op == "restore_range":
                picked = st.pick(rng)
                if picked is None:
                    tally["requests"] -= 1
                    continue
                handle, n, _ = picked
                off = rng.randrange(max(1, n // 2))
                length = min(n - off, 16 << 10)
                fut = srv.submit(name, "restore_range", handle, off, length,
                                 timeout=timeout)
                verify = ("range", handle, (st, off, length))
            else:
                picked = st.pick(rng)
                if picked is None:
                    tally["requests"] -= 1
                    continue
                handle, _, sha = picked
                fut = srv.submit(name, "restore", handle, timeout=timeout)
                verify = ("restore", handle, sha)
        except (OverloadError, QuotaExceededError) as e:
            tally[_classify(e)] += 1
            continue
        done_at: list[float] = []       # completion instant, not drain time
        fut.add_done_callback(
            lambda f, rec=done_at: rec.append(time.perf_counter()))
        inflight.append((op, name, timeout, t_submit, fut, verify, done_at))
    storm.on.clear()
    dispatch_wall = time.perf_counter() - t_start

    for op, name, timeout, t_submit, fut, verify, done_at in inflight:
        try:
            result = fut.result(HANG_TIMEOUT_S)
        except BaseException as e:          # noqa: BLE001 — tallied below
            # a typed deadline error from the task is also a
            # TimeoutError subclass: only an unfinished future is a hang
            if not fut.done():
                tally["hangs"] += 1
            else:
                tally[_classify(e)] += 1
            continue
        elapsed = (done_at[0] if done_at else time.perf_counter()) - t_submit
        tally["ok"] += 1
        # reads have cooperative deadline checks end to end, so an ok
        # completion past deadline+grace is a violation; a commit that
        # passed its last §15.3 shed point must finish (atomicity beats
        # lateness), so writes are exempt by design
        if (timeout and op in ("restore", "restore_range")
                and elapsed > timeout + LATE_GRACE_S):
            tally["deadline_violations"] += 1
        kind = verify[0]
        if kind == "restore":
            _, handle, sha = verify
            if hashlib.sha256(result).digest() != sha:
                tally["integrity_errors"] += 1
            restore_lat.append(elapsed)
            ok_bytes += len(result)
        elif kind == "range":
            _, handle, (st, off, length) = verify
            with st.lock:
                expect = st.live.get(handle)
            # a handle deleted after this range completed can't be
            # re-verified; the read itself succeeded against live data
            if expect is not None and len(result) != min(length,
                                                         expect[0] - off):
                tally["integrity_errors"] += 1
            restore_lat.append(elapsed)
            ok_bytes += len(result)
        elif kind == "ingest":
            _, st, data = verify
            st.add(result.handle, data)

    probe = None        # a surviving (tenant, handle) for breaker probes
    for name, st in states.items():
        with st.lock:
            if st.live:
                probe = (name, sorted(st.live)[0])
                break
    wall = time.perf_counter() - t_start
    restore_lat.sort()
    return probe, {
        "bench": "serve_slo", "phase": phase, "tenants": tenants,
        "rate_hz": rate_hz, "backend_faults": storm.faults,
        **tally,
        "p50_restore_ms": round(_percentile(restore_lat, 0.50) * 1e3, 2),
        "p99_restore_ms": round(_percentile(restore_lat, 0.99) * 1e3, 2),
        "goodput_mbps": round(common.mbps(ok_bytes, wall), 2),
        "dispatch_wall_s": round(dispatch_wall, 2),
        "wall_s": round(wall, 2),
    }


def _recover_breaker(srv: DedupServer, probe: tuple[str, int] | None,
                     budget_s: float = 5.0) -> bool:
    """Drive half-open probes until the breaker re-closes. Probes are
    *reads* — the half-open breaker still gates writes, so only a
    successful restore can close it (§15.4)."""
    if probe is None:
        return False
    tenant, handle = probe
    deadline = time.monotonic() + budget_s
    while time.monotonic() < deadline:
        if srv.breaker.state() == CircuitBreaker.CLOSED:
            return True
        try:
            srv.restore(tenant, handle)
        except Exception:
            pass
        time.sleep(0.05)
    return srv.breaker.state() == CircuitBreaker.CLOSED


def run(tenants: int = 4, requests: int = 400, rate_hz: float = 120.0,
        payload_bytes: int = 96 << 10, latency: float = 0.002,
        timeout_s: float = 2.0, tight_frac: float = 0.03,
        seed: int = 7) -> list[dict]:
    rows = []
    for phase in ("baseline", "fault-drill"):
        storm = _Storm()
        with tempfile.TemporaryDirectory() as tmp:
            srv = _build_server(tmp, storm, tenants, latency)
            try:
                probe, row = run_phase(
                    srv, storm, phase=phase, tenants=tenants,
                    requests=requests, rate_hz=rate_hz,
                    payload_bytes=payload_bytes, timeout_s=timeout_s,
                    tight_frac=tight_frac, seed=seed)
                if phase == "fault-drill":
                    recovered = _recover_breaker(srv, probe)
                    tr = srv.breaker.transitions
                    row.update({
                        "breaker_opened": tr[CircuitBreaker.OPEN],
                        "breaker_half_open": tr[CircuitBreaker.HALF_OPEN],
                        "breaker_recovered": bool(
                            recovered
                            and srv.breaker.state() == CircuitBreaker.CLOSED),
                    })
                rows.append(row)
            finally:
                srv.close(close_store=True)
    return rows


def gate_failures(rows: list[dict]) -> list[str]:
    bad = []
    for r in rows:
        where = r["phase"]
        if r["integrity_errors"]:
            bad.append(f"{where}: {r['integrity_errors']} integrity errors")
        if r["hangs"]:
            bad.append(f"{where}: {r['hangs']} hung requests")
        if r["deadline_violations"]:
            bad.append(f"{where}: {r['deadline_violations']} ok-completions "
                       "past deadline+grace")
        if r["unexpected_error"]:
            bad.append(f"{where}: {r['unexpected_error']} untyped errors")
        if r["phase"] == "fault-drill":
            if not r.get("breaker_opened"):
                bad.append("fault-drill: breaker never opened")
            if not r.get("breaker_half_open"):
                bad.append("fault-drill: breaker never half-opened")
            if not r.get("breaker_recovered"):
                bad.append("fault-drill: breaker did not re-close")
            if not r.get("shed_circuit") and not r.get("backend_error"):
                bad.append("fault-drill: storm produced no typed failures")
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller schedule (CI smoke)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when any §15.5 gate fails")
    ap.add_argument("--json", default=str(JSON_PATH),
                    help="where to write the JSON row dump")
    args = ap.parse_args()
    if args.quick:
        rows = run(tenants=4, requests=160, rate_hz=150.0,
                   payload_bytes=48 << 10, latency=0.001)
    else:
        rows = run()
    common.emit(rows, "serve_slo")
    bad = gate_failures(rows)
    for msg in bad:
        print(f"# GATE FAILED: {msg}")
    path = Path(args.json)
    existing = []
    if path.exists():
        keep = {(r.get("bench"), r.get("phase")) for r in rows}
        existing = [r for r in json.loads(path.read_text())
                    if (r.get("bench"), r.get("phase")) not in keep]
    path.write_text(json.dumps(existing + rows, indent=2) + "\n")
    print(f"# wrote {len(rows)} rows to {path}")
    if args.check and bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
