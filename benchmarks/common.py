"""Shared benchmark harness utilities.

Scaling note (documented per DESIGN.md): the paper evaluates GB-scale
traces with 16 KB-512 KB average chunks. This container is a single CPU
core, so the default harness uses ~24 MB version chains with 4-64 KB
chunks — the chunks-per-version count (the statistic that drives detector
behaviour) matches the paper's regime. `--full` scales 4x closer.
"""
from __future__ import annotations

import time

from repro.core import baselines, chunking, context_model, features, pipeline
from repro.data import workloads

WORKLOADS = ("sql_dump", "vmdk", "kernel")
CHUNK_SIZES = (4096, 8192, 16384, 32768, 65536)


def make_versions(name: str, base_size: int = 6 << 20, versions: int = 4):
    return workloads.make_workload(
        name, workloads.WorkloadConfig(base_size=base_size, versions=versions))


def detector(kind: str, dim: int = 50, threshold: float = 0.3):
    if kind == "card":
        return pipeline.CARDDetector(
            feat_cfg=features.FeatureConfig(k=32, m=64, n=2),
            model_cfg=context_model.ContextModelConfig(m=64, d=dim, steps=150),
            threshold=threshold, use_kernel=False)
    if kind == "card-poly":  # ablation: paper-literal exact-hash sub-chunk LSH
        return pipeline.CARDDetector(
            feat_cfg=features.FeatureConfig(k=32, m=64, n=2, lsh="poly"),
            model_cfg=context_model.ContextModelConfig(m=64, d=dim, steps=150),
            threshold=threshold, use_kernel=False)
    if kind == "finesse":
        return pipeline.finesse_detector()
    if kind == "n-transform":
        return pipeline.ntransform_detector()
    if kind == "dedup-only":
        return pipeline.NullDetector()
    raise KeyError(kind)


def run_cell(kind: str, versions, avg_size: int, dim: int = 50):
    det = detector(kind, dim=dim)
    cfg = chunking.ChunkerConfig(avg_size=avg_size)
    t0 = time.perf_counter()
    stats = pipeline.run_workload(det, versions, cfg)
    wall = time.perf_counter() - t0
    return stats, wall


def emit(rows: list[dict], name: str) -> None:
    """name,us_per_call,derived CSV convention + full column dump."""
    if not rows:
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
