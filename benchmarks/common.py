"""Shared benchmark harness utilities.

Scaling note (documented per DESIGN.md): the paper evaluates GB-scale
traces with 16 KB-512 KB average chunks. This container is a single CPU
core, so the default harness uses ~24 MB version chains with 4-64 KB
chunks — the chunks-per-version count (the statistic that drives detector
behaviour) matches the paper's regime. `--full` scales 4x closer.
"""
from __future__ import annotations

import time

from repro import api
from repro.data import workloads

WORKLOADS = ("sql_dump", "vmdk", "kernel")
CHUNK_SIZES = (4096, 8192, 16384, 32768, 65536)


def make_versions(name: str, base_size: int = 6 << 20, versions: int = 4):
    return workloads.make_workload(
        name, workloads.WorkloadConfig(base_size=base_size, versions=versions))


def detector_config(kind: str, dim: int = 50, threshold: float = 0.3,
                    avg_size: int = 8192) -> api.DedupConfig:
    """Declarative pipeline config for every benchmark cell; `card-poly`
    is the paper-literal exact-hash sub-chunk LSH ablation."""
    if kind in ("card", "card-poly"):
        feat = {"k": 32, "m": 64, "n": 2}
        if kind == "card-poly":
            feat["lsh"] = "poly"
        d = {"detector": "card",
             "detector_args": {"feat": feat,
                               "model": {"m": 64, "d": dim, "steps": 150},
                               "threshold": threshold, "use_kernel": False}}
    else:
        d = {"detector": kind}
    d["chunker_args"] = {"avg_size": avg_size}
    return api.DedupConfig.from_dict(d)


def detector(kind: str, dim: int = 50, threshold: float = 0.3):
    return api.build_detector(detector_config(kind, dim=dim, threshold=threshold))


def run_cell(kind: str, versions, avg_size: int, dim: int = 50):
    cfg = detector_config(kind, dim=dim, avg_size=avg_size)
    store = api.build_store(cfg)
    t0 = time.perf_counter()
    store.fit(list(versions[:1]))
    for v in versions:
        store.ingest(v)
    wall = time.perf_counter() - t0
    return store.stats, wall


def mbps(nbytes: float, seconds: float) -> float:
    """Throughput in MB/s; 0.0 for zero-byte or zero-duration work. A
    smoke-sized op can finish under the clock's resolution (and an empty
    stream moves no bytes) — a throughput cell must then print ``0.0``,
    never raise ZeroDivisionError."""
    if seconds <= 0 or nbytes <= 0:
        return 0.0
    return nbytes / (1 << 20) / seconds


def ratio(num: float, den: float) -> float:
    """``num / den`` with a zero/negative denominator reading as 0.0
    (read amplification of a zero-byte restore, DCR of an empty store)."""
    return num / den if den > 0 else 0.0


def fmt_ratio(num: float, den: float, places: int = 2) -> str:
    """``ratio`` rendered for a report cell; ``n/a`` when undefined."""
    return f"{num / den:.{places}f}" if den > 0 else "n/a"


def emit(rows: list[dict], name: str) -> None:
    """name,us_per_call,derived CSV convention + full column dump.

    A section may concatenate sub-benches with different columns (the
    cache section's scan/singleflight/tier rows); a fresh header line
    is printed whenever the row shape changes."""
    cols: list[str] | None = None
    for r in rows:
        if list(r.keys()) != cols:
            cols = list(r.keys())
            print(",".join(cols))
        print(",".join(str(r[c]) for c in cols))
