"""Paper Table 1: CARD overall time + DCR across feature dimensions 40-80
at a fixed average chunk size."""
from __future__ import annotations

from benchmarks import common


def run(dims=(40, 50, 60, 70, 80), base_size=6 << 20, versions=4,
        avg_chunk=16384) -> list[dict]:
    rows = []
    for wl in common.WORKLOADS:
        vs = common.make_versions(wl, base_size, versions)
        base_dcr = None
        for dim in dims:
            stats, wall = common.run_cell("card", vs, avg_chunk, dim=dim)
            if base_dcr is None:
                base_dcr = stats.dcr
            rows.append({
                "bench": "dims", "workload": wl, "dimension": dim,
                "time_s": round(stats.detect_seconds + stats.fit_seconds, 3),
                "dcr": round(stats.dcr, 4),
                "dcr_delta_pct": round(100 * (stats.dcr / base_dcr - 1), 2),
            })
    return rows


def main():
    common.emit(run(), "dims")


if __name__ == "__main__":
    main()
