"""Observability smoke gate (``make observe-smoke``, DESIGN.md §12).

Runs a tiny ingest + warm restore + delete/compact cycle with tracing
on, then fails loudly unless the whole observability surface holds up:

  * the Prometheus exposition parses under the strict validator
    (``repro.api.observe.parse_prometheus_text``: name/label syntax,
    escaping, TYPE lines for every family, cumulative buckets that
    agree with ``_count``) — including a store label value chosen to
    exercise backslash/quote/newline escaping;
  * counter/gauge/histogram families exist for stage timings, cache
    outcomes and request counts, and a warm restore's cache-hit series
    actually moved;
  * the JSON snapshot is ``json.loads``-clean and structurally
    consistent (histogram count == sum of buckets);
  * every ingest/restore stage produced at least one trace span, the
    ring and the JSONL sink agree, and each sink line round-trips
    through ``json.loads``;
  * the ``python -m repro.api.observe dump`` CLI renders the sink.

    PYTHONPATH=src python -m benchmarks.observe_smoke
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from repro import api
from repro.api import observe


def check(cond: bool, what: str) -> None:
    if not cond:
        raise SystemExit(f"observe-smoke FAILED: {what}")


def main() -> None:
    with tempfile.TemporaryDirectory() as td:
        trace = os.path.join(td, "trace.jsonl")
        cfg = api.DedupConfig.from_dict({
            "detector": "dedup-only",
            "chunker_args": {"avg_size": 4096},
            "backend": "file",
            "backend_args": {"path": os.path.join(td, "containers")},
            "trace_path": trace,
            "trace_ring_events": 512,
        })
        store = api.build_store(cfg)

        data = os.urandom(96 << 10) + b"tail" * 1024
        with store.open_stream() as s:
            s.write(data)
        handle = s.report.handle
        check(store.restore(handle) == data, "cold restore not byte-exact")
        check(store.restore(handle) == data, "warm restore not byte-exact")
        with store.open_stream() as s2:     # a second, deletable stream
            s2.write(data[: 32 << 10])
        store.delete(s2.report.handle)
        store.compact()

        # a label value that needs every escape the exposition defines
        nasty = 'a\\b"c\nd'
        store.metrics().counter("repro_smoke_escapes_total",
                                "exercises label escaping",
                                labels={"path": nasty}).inc(3)

        # --- Prometheus exposition ---------------------------------------
        text = store.metrics().to_prometheus()
        parsed = observe.parse_prometheus_text(text)
        types, samples = parsed["types"], parsed["samples"]
        wanted = {
            "repro_ingest_stage_seconds": "histogram",
            "repro_restore_stage_seconds": "histogram",
            "repro_restore_requests": "histogram",
            "repro_lock_wait_seconds": "histogram",
            "repro_reader_run_bytes": "histogram",
            "repro_gc_phase_seconds": "histogram",
            "repro_ingest_commits_total": "counter",
            "repro_restore_ops_total": "counter",
            "repro_reader_cache_lookups_total": "counter",
            "repro_reader_requests_total": "counter",
            "repro_store_dcr": "gauge",
            "repro_store_bytes": "gauge",
        }
        for fam, kind in wanted.items():
            check(types.get(fam) == kind, f"family {fam} missing or not "
                                          f"{kind} (got {types.get(fam)})")
        by_series = {(n, tuple(sorted(l.items()))): v
                     for n, l, v in samples}
        check(by_series[("repro_smoke_escapes_total",
                         (("path", nasty),))] == 3.0,
              "escaped label did not round-trip through the exposition")
        check(by_series[("repro_reader_cache_lookups_total",
                         (("outcome", "hit"),))] > 0,
              "warm restore recorded no cache hits")

        # --- JSON snapshot ------------------------------------------------
        snap = json.loads(store.metrics().to_json())
        for fam in wanted:
            check(fam in snap, f"{fam} missing from JSON snapshot")
        for fam, body in snap.items():
            if body["type"] != "histogram":
                continue
            for sample in body["samples"]:
                total = sum(n for _, n in sample["buckets"])
                check(total == sample["count"],
                      f"{fam}: histogram count {sample['count']} != "
                      f"bucket sum {total}")

        # --- trace ring + JSONL sink -------------------------------------
        ops = store.observe.tracer.ops()
        for op in ("ingest", "ingest.chunk", "ingest.store", "restore",
                   "restore.read", "restore.decode", "restore.prefetch",
                   "gc.delete", "gc.compact"):
            check(ops.get(op, 0) >= 1, f"no trace span for {op}")
        ring_count = len(store.observe.tracer.events())
        store.close()   # flushes + closes the sink

        with open(trace, encoding="utf-8") as f:
            sink = [json.loads(line) for line in f if line.strip()]
        check(len(sink) == ring_count,
              f"sink has {len(sink)} spans, ring {ring_count}")
        check(all("op" in e and "id" in e and "tid" in e for e in sink),
              "sink span missing op/id/tid fields")

        out = subprocess.run(
            [sys.executable, "-m", "repro.api.observe", "dump", trace],
            capture_output=True, text=True,
            env=dict(os.environ,
                     PYTHONPATH="src" + os.pathsep
                     + os.environ.get("PYTHONPATH", "")))
        check(out.returncode == 0, f"observe dump CLI failed: {out.stderr}")
        check(f"# {len(sink)} spans" in out.stdout,
              "observe dump did not report the span roll-up")

    print(f"observe-smoke OK: {len(types)} metric families, "
          f"{len(samples)} samples, {len(sink)} trace spans")


if __name__ == "__main__":
    main()
