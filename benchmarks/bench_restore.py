"""Restore/serving throughput + telemetry split (DESIGN.md §9).

One row per (workload, detector) on the sql_dump and vmdk version
chains: ingest through a file-backed store, then measure the serving
path the way production reads it —

    cold_mbps       restore every stream on a *freshly reopened* store
                    (empty decode cache; planner + get_many sequential
                    I/O is what this number buys)
    warm_mbps       second full pass on the same store (decode cache
                    warm; bytes_read should collapse toward 0)
    range_mbps      1000 random 64 KiB ranged reads on the reopened
                    store (the partial-object serving primitive)
    compacted_mbps  cold restore of the newest stream after deleting the
                    older versions and compacting the container

plus where the cold pass spent its time (read/decode seconds), the
decode-cache hit/miss split, and cold read amplification (container
bytes fetched per byte served).

Cold/warm/compacted throughputs are the best of ``repeats`` passes
(each cold pass is a fresh store reopen with an empty decode cache):
this box is a shared-CPU container with ±40% run-to-run noise, and
interference is strictly additive, so min-time is the stable estimator.
The pre-PR baseline rows were measured with the identical protocol.

Rows land in BENCH_RESTORE.json so future PRs have a perf trajectory;
rows with variant="per-chunk" are the pre-planner per-chunk ``get``
path, measured from a worktree at the pre-PR commit on the same machine
(the ``--label`` flag names the variant when reproducing that).

    PYTHONPATH=src python -m benchmarks.bench_restore [--quick] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks import common
from repro import api

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_RESTORE.json"

WORKLOADS = ("sql_dump", "vmdk")
DETECTORS = ("dedup-only", "finesse", "card")
RANGE_READS = 1000
RANGE_BYTES = 64 << 10


def _reopen(tmp: str) -> api.DedupStore:
    """Serving-side store on an existing container dir (detector unused
    by the read path; dedup-only keeps reopen cheap)."""
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "backend": "file",
         "backend_args": {"path": tmp}})
    return api.build_store(cfg)


def _restore_all(store: api.DedupStore, handles) -> tuple[float, int]:
    t0 = time.perf_counter()
    total = 0
    for h in handles:
        total += len(store.restore(h))
    return time.perf_counter() - t0, total


def run(base_size: int = 6 << 20, versions: int = 4,
        detectors=DETECTORS, workloads=WORKLOADS,
        avg_size: int = 8192, label: str = "planned",
        range_reads: int = RANGE_READS, repeats: int = 3) -> list[dict]:
    rows = []
    for wl in workloads:
        vs = common.make_versions(wl, base_size, versions)
        for kind in detectors:
            cfg = common.detector_config(kind, avg_size=avg_size)
            with tempfile.TemporaryDirectory() as tmp:
                cfg.backend, cfg.backend_args = "file", {"path": tmp}
                store = api.build_store(cfg)
                store.fit(list(vs[:1]))
                handles = []
                for v in vs:
                    with store.open_stream() as s:
                        s.write(v)
                    handles.append(s.report.handle)
                dcr = store.stats.dcr
                store.close()

                cold_s, warm_s = float("inf"), float("inf")
                cold_row = {}
                cold = None
                for _rep in range(repeats):     # each pass: fresh reopen
                    if cold is not None:
                        cold.close()
                    cold = _reopen(tmp)
                    pass_s, total = _restore_all(cold, handles)
                    if pass_s < cold_s:
                        cold_s = pass_s
                        s = cold.stats
                        cold_row = {
                            "read_s": round(s.restore_read_seconds, 4),
                            "decode_s": round(s.restore_decode_seconds, 4),
                            "cache_hits": s.restore_cache_hits,
                            "cache_misses": s.restore_cache_misses,
                            "read_amp": round(s.restore_bytes_read
                                              / max(1, s.restore_bytes_out),
                                              4),
                        }
                    warm_s = min(warm_s, _restore_all(cold, handles)[0])

                # ranged reads: the serving primitive (newest version)
                h, v = handles[-1], vs[-1]
                rng = np.random.default_rng(0)
                offs = rng.integers(0, max(1, len(v) - RANGE_BYTES),
                                    range_reads)
                t0 = time.perf_counter()
                range_bytes = 0
                for off in offs:
                    range_bytes += len(cold.restore_range(
                        h, int(off), RANGE_BYTES))
                range_s = time.perf_counter() - t0
                cold.close()

                # restore-after-compaction: drop the history, keep latest
                survivor = _reopen(tmp)
                for hh in handles[:-1]:
                    survivor.delete(hh)
                survivor.compact()
                survivor.close()
                comp_s = float("inf")
                for _rep in range(repeats):
                    compacted = _reopen(tmp)
                    pass_s, comp_total = _restore_all(
                        compacted, [handles[-1]])
                    comp_s = min(comp_s, pass_s)
                    compacted.close()

                mb = total / 2**20
                rows.append({
                    "bench": "restore", "workload": wl, "detector": kind,
                    "variant": label, "versions": versions,
                    "avg_size": avg_size, "bytes_mb": round(mb, 2),
                    "cold_mbps": round(mb / max(1e-9, cold_s), 2),
                    "warm_mbps": round(mb / max(1e-9, warm_s), 2),
                    "range_mbps": round(
                        range_bytes / 2**20 / max(1e-9, range_s), 2),
                    "compacted_mbps": round(
                        comp_total / 2**20 / max(1e-9, comp_s), 2),
                    **cold_row,
                    "dcr": round(dcr, 4),
                })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI smoke)")
    ap.add_argument("--json", default=str(JSON_PATH),
                    help="where to write the JSON row dump")
    ap.add_argument("--label", default="planned",
                    help="variant label for the emitted rows")
    args = ap.parse_args()
    if args.quick:
        rows = run(base_size=2 << 20, versions=3, range_reads=200,
                   label=args.label)
    else:
        rows = run(label=args.label)
    common.emit(rows, "restore")
    path = Path(args.json)
    existing = []
    if path.exists():       # keep rows from other variants (pre-PR runs)
        existing = [r for r in json.loads(path.read_text())
                    if r.get("variant") != args.label]
    path.write_text(json.dumps(existing + rows, indent=2) + "\n")
    print(f"# wrote {len(rows)} rows to {path}")


if __name__ == "__main__":
    main()
