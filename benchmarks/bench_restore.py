"""Restore/serving throughput + telemetry split (DESIGN.md §9).

One row per (workload, detector) on the sql_dump and vmdk version
chains: ingest through a file-backed store, then measure the serving
path the way production reads it —

    cold_mbps       restore every stream on a *freshly reopened* store
                    (empty decode cache; planner + get_many sequential
                    I/O is what this number buys)
    warm_mbps       second full pass on the same store (decode cache
                    warm; bytes_read should collapse toward 0)
    range_mbps      1000 random 64 KiB ranged reads on the reopened
                    store (the partial-object serving primitive)
    compacted_mbps  cold restore of the newest stream after deleting the
                    older versions and compacting the container

``--threads N1,N2,...`` instead runs the concurrent serving bench
(DESIGN.md §10.7): a shared work queue of whole-stream restores drained
by N threads against one store, recording aggregate MB/s (cold: fresh
reopen, one restore per stream; warm: repeated restores, cache hot) and
per-restore p50/p99 latency, plus a per-restore SHA1 byte-identity check
(the ``errors`` column — nonzero on the pre-§10 code, whose shared seek+
read handle and unsynchronized cache corrupt concurrent restores).
``nproc`` is recorded per row: thread scaling is bounded by cores and,
for pure-Python decode work, by the GIL — read syscalls release it.

``--verify-reads`` instead runs the §13.2 integrity-overhead bench:
the same cold+warm protocol with per-chunk crc32c verification off vs
on against one container dir, emitting paired throughputs and the warm
overhead percentage (guarded at ±15% — decode-cache hits skip
re-verification, so warm reads only pay the checksum on misses).

plus where the cold pass spent its time (read/decode seconds), the
decode-cache hit/miss split, and cold read amplification (container
bytes fetched per byte served).

Cold/warm/compacted throughputs are the best of ``repeats`` passes
(each cold pass is a fresh store reopen with an empty decode cache):
this box is a shared-CPU container with ±40% run-to-run noise, and
interference is strictly additive, so min-time is the stable estimator.
The pre-PR baseline rows were measured with the identical protocol.

Rows land in BENCH_RESTORE.json so future PRs have a perf trajectory;
rows with variant="per-chunk" are the pre-planner per-chunk ``get``
path, measured from a worktree at the pre-PR commit on the same machine
(the ``--label`` flag names the variant when reproducing that).

    PYTHONPATH=src python -m benchmarks.bench_restore [--quick] [--json PATH]
"""
from __future__ import annotations

import argparse
import hashlib
import itertools
import json
import os
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from benchmarks import common
from repro import api

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_RESTORE.json"

WORKLOADS = ("sql_dump", "vmdk")
DETECTORS = ("dedup-only", "finesse", "card")
RANGE_READS = 1000
RANGE_BYTES = 64 << 10


def _reopen(tmp: str, verify_reads: bool = False) -> api.DedupStore:
    """Serving-side store on an existing container dir (detector unused
    by the read path; dedup-only keeps reopen cheap)."""
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "backend": "file",
         "backend_args": {"path": tmp}, "verify_reads": verify_reads})
    return api.build_store(cfg)


def _restore_all(store: api.DedupStore, handles) -> tuple[float, int]:
    t0 = time.perf_counter()
    total = 0
    for h in handles:
        total += len(store.restore(h))
    return time.perf_counter() - t0, total


def run(base_size: int = 6 << 20, versions: int = 4,
        detectors=DETECTORS, workloads=WORKLOADS,
        avg_size: int = 8192, label: str = "planned",
        range_reads: int = RANGE_READS, repeats: int = 3,
        metrics_dir: str | None = None) -> list[dict]:
    """One row per (workload, detector); with ``metrics_dir`` set, each
    row's serving store also dumps its metrics snapshot (DESIGN.md §12)
    there as ``restore_<workload>_<detector>.json`` — the row's own
    explanation when a perf regression shows up."""
    rows = []
    for wl in workloads:
        vs = common.make_versions(wl, base_size, versions)
        for kind in detectors:
            cfg = common.detector_config(kind, avg_size=avg_size)
            with tempfile.TemporaryDirectory() as tmp:
                cfg.backend, cfg.backend_args = "file", {"path": tmp}
                store = api.build_store(cfg)
                store.fit(list(vs[:1]))
                handles = []
                for v in vs:
                    with store.open_stream() as s:
                        s.write(v)
                    handles.append(s.report.handle)
                dcr = store.stats.dcr
                store.close()

                cold_s, warm_s = float("inf"), float("inf")
                cold_row = {}
                cold = None
                for _rep in range(repeats):     # each pass: fresh reopen
                    if cold is not None:
                        cold.close()
                    cold = _reopen(tmp)
                    pass_s, total = _restore_all(cold, handles)
                    if pass_s < cold_s:
                        cold_s = pass_s
                        s = cold.stats
                        cold_row = {
                            "read_s": round(s.restore_read_seconds, 4),
                            "decode_s": round(s.restore_decode_seconds, 4),
                            "cache_hits": s.restore_cache_hits,
                            "cache_misses": s.restore_cache_misses,
                            "read_amp": round(
                                common.ratio(s.restore_bytes_read,
                                             s.restore_bytes_out), 4),
                        }
                    warm_s = min(warm_s, _restore_all(cold, handles)[0])

                # ranged reads: the serving primitive (newest version)
                h, v = handles[-1], vs[-1]
                rng = np.random.default_rng(0)
                offs = rng.integers(0, max(1, len(v) - RANGE_BYTES),
                                    range_reads)
                t0 = time.perf_counter()
                range_bytes = 0
                for off in offs:
                    range_bytes += len(cold.restore_range(
                        h, int(off), RANGE_BYTES))
                range_s = time.perf_counter() - t0
                if metrics_dir:
                    mdir = Path(metrics_dir)
                    mdir.mkdir(parents=True, exist_ok=True)
                    (mdir / f"restore_{wl}_{kind}.json").write_text(
                        cold.metrics().to_json(indent=2))
                cold.close()

                # restore-after-compaction: drop the history, keep latest
                survivor = _reopen(tmp)
                for hh in handles[:-1]:
                    survivor.delete(hh)
                survivor.compact()
                survivor.close()
                comp_s = float("inf")
                for _rep in range(repeats):
                    compacted = _reopen(tmp)
                    pass_s, comp_total = _restore_all(
                        compacted, [handles[-1]])
                    comp_s = min(comp_s, pass_s)
                    compacted.close()

                mb = total / 2**20
                rows.append({
                    "bench": "restore", "workload": wl, "detector": kind,
                    "variant": label, "versions": versions,
                    "avg_size": avg_size, "bytes_mb": round(mb, 2),
                    "cold_mbps": round(common.mbps(total, cold_s), 2),
                    "warm_mbps": round(common.mbps(total, warm_s), 2),
                    "range_mbps": round(
                        common.mbps(range_bytes, range_s), 2),
                    "compacted_mbps": round(
                        common.mbps(comp_total, comp_s), 2),
                    **cold_row,
                    "dcr": round(dcr, 4),
                })
    return rows


def run_verify(base_size: int = 6 << 20, versions: int = 4,
               detectors=("card",), workloads=WORKLOADS,
               avg_size: int = 8192, repeats: int = 3) -> list[dict]:
    """Cost of per-chunk crc32c on the read path (DESIGN.md §13.2): the
    identical cold+warm restore protocol with ``verify_reads`` off and
    on against the same container dir, one paired row per (workload,
    detector). ``warm_overhead_pct`` is the number the §13 guard cares
    about — decode-cache hits skip re-verification, so a warm pass pays
    the checksum only on its misses and the overhead must stay within
    ±15% (``warm_within_guard``)."""
    rows = []
    for wl in workloads:
        vs = common.make_versions(wl, base_size, versions)
        for kind in detectors:
            cfg = common.detector_config(kind, avg_size=avg_size)
            with tempfile.TemporaryDirectory() as tmp:
                cfg.backend, cfg.backend_args = "file", {"path": tmp}
                store = api.build_store(cfg)
                store.fit(list(vs[:1]))
                handles = []
                for v in vs:
                    with store.open_stream() as s:
                        s.write(v)
                    handles.append(s.report.handle)
                store.close()

                timing = {}
                for verify in (False, True):
                    cold_s = warm_s = float("inf")
                    for _rep in range(repeats):
                        served = _reopen(tmp, verify_reads=verify)
                        pass_s, total = _restore_all(served, handles)
                        cold_s = min(cold_s, pass_s)
                        warm_s = min(warm_s,
                                     _restore_all(served, handles)[0])
                        served.close()
                    timing[verify] = (cold_s, warm_s, total)

                (cold0, warm0, total) = timing[False]
                (cold1, warm1, _) = timing[True]
                warm_overhead = 100.0 * (warm1 - warm0) / warm0
                rows.append({
                    "bench": "restore_verify", "workload": wl,
                    "detector": kind, "variant": "verify-reads",
                    "versions": versions, "avg_size": avg_size,
                    "bytes_mb": round(total / 2**20, 2),
                    "cold_mbps": round(common.mbps(total, cold0), 2),
                    "cold_verified_mbps": round(
                        common.mbps(total, cold1), 2),
                    "warm_mbps": round(common.mbps(total, warm0), 2),
                    "warm_verified_mbps": round(
                        common.mbps(total, warm1), 2),
                    "cold_overhead_pct": round(
                        100.0 * (cold1 - cold0) / cold0, 2),
                    "warm_overhead_pct": round(warm_overhead, 2),
                    "warm_within_guard": abs(warm_overhead) <= 15.0,
                })
    return rows


def _drain_queue(store, jobs, n_threads):
    """N threads drain a shared queue of (handle, sha1, nbytes) restore
    jobs; returns (wall_seconds, per_job_latencies, corrupt_count)."""
    lat: list[float] = []
    lock = threading.Lock()
    counter = itertools.count()
    errors = [0]

    def worker():
        while True:
            i = next(counter)
            if i >= len(jobs):
                return
            handle, digest, _ = jobs[i]
            t0 = time.perf_counter()
            try:
                ok = hashlib.sha1(store.restore(handle)).digest() == digest
            except Exception:       # pre-§10 code corrupts under threads
                ok = False
            dt = time.perf_counter() - t0
            with lock:
                lat.append(dt)
                if not ok:
                    errors[0] += 1

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(n_threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, lat, errors[0]


def run_threaded(base_size: int = 6 << 20, versions: int = 4,
                 detectors=("card",), workloads=WORKLOADS,
                 avg_size: int = 8192, label: str = "threaded",
                 threads_list=(1, 2, 4), warm_reps: int = 6,
                 repeats: int = 3) -> list[dict]:
    """Concurrent serving rows (see module docstring): one row per
    (workload, detector, thread count), best-of-``repeats`` aggregates,
    p50/p99 from the best pass."""
    rows = []
    nproc = os.cpu_count()
    for wl in workloads:
        vs = common.make_versions(wl, base_size, versions)
        for kind in detectors:
            cfg = common.detector_config(kind, avg_size=avg_size)
            with tempfile.TemporaryDirectory() as tmp:
                cfg.backend, cfg.backend_args = "file", {"path": tmp}
                store = api.build_store(cfg)
                store.fit(list(vs[:1]))
                jobs = []
                for v in vs:
                    with store.open_stream() as s:
                        s.write(v)
                    jobs.append((s.report.handle,
                                 hashlib.sha1(v).digest(), len(v)))
                store.close()
                cold_bytes = sum(j[2] for j in jobs)
                rng = np.random.default_rng(0)

                for n_threads in threads_list:
                    cold_s = warm_s = float("inf")
                    cold_lat = warm_lat = []
                    errs = 0
                    for _rep in range(repeats):
                        served = _reopen(tmp)
                        # cold: every stream exactly once, threads racing
                        # over overlapping base chains
                        wall, lat, e1 = _drain_queue(served, jobs, n_threads)
                        if wall < cold_s:
                            cold_s, cold_lat = wall, lat
                        # warm: repeated whole-stream restores, cache hot
                        warm_jobs = jobs * warm_reps
                        warm_jobs = [warm_jobs[i] for i in
                                     rng.permutation(len(warm_jobs))]
                        wall, lat, e2 = _drain_queue(served, warm_jobs,
                                                     n_threads)
                        if wall < warm_s:
                            warm_s, warm_lat = wall, lat
                        errs += e1 + e2
                        served.close()
                    warm_bytes = cold_bytes * warm_reps
                    cold_lat = sorted(cold_lat)
                    warm_lat = sorted(warm_lat)
                    rows.append({
                        "bench": "restore_threads", "workload": wl,
                        "detector": kind, "variant": label,
                        "threads": n_threads, "nproc": nproc,
                        "versions": versions, "avg_size": avg_size,
                        "bytes_mb": round(cold_bytes / 2**20, 2),
                        "cold_agg_mbps": round(
                            common.mbps(cold_bytes, cold_s), 2),
                        "warm_agg_mbps": round(
                            common.mbps(warm_bytes, warm_s), 2),
                        "cold_p50_ms": round(
                            1e3 * cold_lat[len(cold_lat) // 2], 3),
                        "cold_p99_ms": round(
                            1e3 * cold_lat[
                                min(len(cold_lat) - 1,
                                    int(0.99 * len(cold_lat)))], 3),
                        "warm_p50_ms": round(
                            1e3 * warm_lat[len(warm_lat) // 2], 3),
                        "warm_p99_ms": round(
                            1e3 * warm_lat[
                                min(len(warm_lat) - 1,
                                    int(0.99 * len(warm_lat)))], 3),
                        "errors": errs,
                    })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI smoke)")
    ap.add_argument("--json", default=str(JSON_PATH),
                    help="where to write the JSON row dump")
    ap.add_argument("--label", default=None,
                    help="variant label for the emitted rows")
    ap.add_argument("--threads", default=None,
                    help="comma list of thread counts: run the concurrent "
                         "serving bench instead of the serial sections")
    ap.add_argument("--verify-reads", action="store_true",
                    help="run the §13.2 verified-read overhead bench "
                         "(cold+warm restore with per-chunk crc32c off "
                         "vs on) instead of the serial sections")
    ap.add_argument("--metrics-dir", default=None,
                    help="also dump a per-row metrics snapshot (DESIGN.md "
                         "§12) into this directory (serial bench only)")
    args = ap.parse_args()
    if args.threads:
        label = args.label or "threaded"
        counts = tuple(int(t) for t in args.threads.split(","))
        if args.quick:
            rows = run_threaded(base_size=2 << 20, versions=3,
                                threads_list=counts, warm_reps=3,
                                repeats=1, label=label)
        else:
            rows = run_threaded(threads_list=counts, label=label)
        section = "restore_threads"
    elif args.verify_reads:
        label = args.label or "verify-reads"
        if args.quick:
            rows = run_verify(base_size=2 << 20, versions=3, repeats=1)
        else:
            rows = run_verify()
        section = "restore_verify"
        bad = [r for r in rows if not r["warm_within_guard"]]
        if bad:
            print(f"# WARNING: warm verify_reads overhead outside ±15% "
                  f"guard in {len(bad)} row(s)")
    else:
        label = args.label or "planned"
        if args.quick:
            rows = run(base_size=2 << 20, versions=3, range_reads=200,
                       label=label, metrics_dir=args.metrics_dir)
        else:
            rows = run(label=label, metrics_dir=args.metrics_dir)
        section = "restore"
    common.emit(rows, section)
    path = Path(args.json)
    existing = []
    if path.exists():       # keep rows from other variants/benches
        existing = [r for r in json.loads(path.read_text())
                    if not (r.get("variant") == label
                            and r.get("bench") == rows[0]["bench"])]
    path.write_text(json.dumps(existing + rows, indent=2) + "\n")
    print(f"# wrote {len(rows)} rows to {path}")


if __name__ == "__main__":
    main()
