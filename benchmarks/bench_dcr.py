"""Paper Figs 5 / 7 / 8: DCR vs average chunk size per workload,
CARD vs Finesse vs N-transform (+ dedup-only floor)."""
from __future__ import annotations

from benchmarks import common


def run(chunk_sizes=None, base_size=6 << 20, versions=4) -> list[dict]:
    rows = []
    sizes = chunk_sizes or common.CHUNK_SIZES[:4]
    for wl in common.WORKLOADS:
        vs = common.make_versions(wl, base_size, versions)
        for avg in sizes:
            for kind in ("dedup-only", "finesse", "n-transform", "card"):
                stats, wall = common.run_cell(kind, vs, avg)
                rows.append({
                    "bench": "dcr", "workload": wl, "avg_chunk": avg,
                    "detector": kind, "dcr": round(stats.dcr, 4),
                    "delta_chunks": stats.delta_chunks,
                    "dup_chunks": stats.dup_chunks,
                    "detect_s": round(stats.detect_seconds, 3),
                    "wall_s": round(wall, 2),
                })
    return rows


def main():
    common.emit(run(), "dcr")


if __name__ == "__main__":
    main()
