"""Cache-hierarchy benchmarks (DESIGN.md §14): scan resistance, cold-
decode singleflight, and the local-disk tier over the object store.

Three sections, one JSON row set each:

    cache_scan          pointed-restore throughput (random 64 KiB ranged
                        reads on a hot delta-chained stream, decode
                        cache warm) measured alone and with one-touch
                        cold scans interleaved between read batches —
                        each scan restores a *distinct* chunk-disjoint
                        stream bigger than the cache, the §14.1 backup-
                        scan shape. One row per eviction policy: lru's
                        single recency queue lets every scan flush the
                        hot set; arc's T2 holds the twice-touched chain
                        while the one-touch scan lives and dies in T1,
                        so arc's under-scan throughput must stay within
                        10% of the no-scan baseline (``within_guard``).
    cache_singleflight  4 threads cold-restoring the same delta-heavy
                        sql_dump streams in lockstep (identical handle
                        order, barrier start — the thundering-herd
                        shape), singleflight off vs on. Off, every
                        thread decodes every shared base chain; on, the
                        first prober owns the decode and the rest wait
                        for the materialized bytes — the aggregate MB/s
                        gate is >= 2x (``sf_gate``), with per-restore
                        SHA1 identity checked both ways (``errors``).
    cache_tier          cold restores over the object store with
                        injected per-request latency and a bandwidth
                        cap (the WAN-object-store regime), without a
                        disk tier vs with one: the first tiered pass
                        fills the tier (crc-verified), the second — a
                        fresh process reopen — serves payload bytes
                        from local disk and keeps only journal/manifest
                        GETs. Rows record MB/s and client GET counts.

Cold/measured numbers are best-of-``repeats`` (min-time estimator, same
argument as bench_restore). Rows land in BENCH_CACHE.json.

    PYTHONPATH=src python -m benchmarks.bench_cache [--quick] [--json PATH]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from benchmarks import common
from repro import api

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_CACHE.json"

RANGE_BYTES = 64 << 10


def _ingest(tmp: str, vs, avg_size: int = 8192,
            detector: str = "card") -> list[int]:
    cfg = common.detector_config(detector, avg_size=avg_size)
    cfg.backend, cfg.backend_args = "file", {"path": tmp}
    store = api.build_store(cfg)
    store.fit(list(vs[:1]))
    handles = []
    for v in vs:
        with store.open_stream() as s:
            s.write(v)
        handles.append(s.report.handle)
    store.close()
    return handles


def _serving(tmp: str, policy: str, cache_bytes: int) -> api.DedupStore:
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "backend": "file",
         "backend_args": {"path": tmp},
         "restore_cache_bytes": cache_bytes,
         "restore_cache_policy": policy})
    return api.build_store(cfg)


def _pointed_pass(store, handle, nbytes, offs) -> tuple[float, int]:
    t0 = time.perf_counter()
    total = 0
    for off in offs:
        total += len(store.restore_range(handle, int(off), RANGE_BYTES))
    return time.perf_counter() - t0, total


def run_scan(base_size: int = 2 << 20, versions: int = 4,
             avg_size: int = 8192, range_reads: int = 150,
             scan_rounds: int = 3, scan_mb: int = 12,
             repeats: int = 3, guard: bool = True) -> list[dict]:
    """One row per policy: pointed-restore MB/s with and without
    interleaved one-touch scans, cache sized to the hot chain only.
    Scan fodder is incompressible random data ingested dedup-only, so
    its chunks share nothing with the hot stream's — every scan is pure
    one-touch cache pressure, ``scan_mb`` per round against a cache of
    ``3 * base_size`` bytes."""
    rows = []
    vs = common.make_versions("sql_dump", base_size, versions)
    hot = vs[-1]
    # holds the hot version's materialized chain comfortably, nowhere
    # near the scan set — the regime where eviction policy decides
    cache_bytes = 3 * base_size
    rng = np.random.default_rng(0)
    offs = rng.integers(0, max(1, len(hot) - RANGE_BYTES), range_reads)
    with tempfile.TemporaryDirectory() as tmp:
        handles = _ingest(tmp, vs, avg_size=avg_size)
        h = handles[-1]
        # chunk-disjoint scan fodder, one distinct stream per round
        # (dedup-only reopen on the same containers keeps ingest cheap)
        cfg = api.DedupConfig.from_dict(
            {"detector": "dedup-only", "backend": "file",
             "backend_args": {"path": tmp},
             "chunker_args": {"avg_size": avg_size}})
        feeder = api.build_store(cfg)
        scan_handles = []
        for i in range(scan_rounds):
            blob = np.random.default_rng(100 + i).integers(
                0, 256, scan_mb << 20, np.uint8).tobytes()
            with feeder.open_stream() as s:
                s.write(blob)
            scan_handles.append(s.report.handle)
        feeder.close()
        for policy in ("lru", "arc"):
            noscan_s = scan_s = float("inf")
            signals = {}
            for _rep in range(repeats):
                store = _serving(tmp, policy, cache_bytes)
                _pointed_pass(store, h, len(hot), offs)     # warm the chain
                noscan_s = min(noscan_s,
                               _pointed_pass(store, h, len(hot), offs)[0])
                t_scan = 0.0
                step = 512 << 10
                for sh in scan_handles:                     # the scans:
                    for off in range(0, scan_mb << 20, step):
                        # bounded ranged sweeps, not one whole-stream
                        # get_many — a 12 MB batch would hold most of
                        # the cache pinned at once and force eviction
                        # onto T2 regardless of policy
                        store.restore_range(sh, off, step)
                    dt, _ = _pointed_pass(store, h, len(hot), offs)
                    t_scan += dt
                scan_s = min(scan_s, t_scan / len(scan_handles))
                signals = store.cache_stats()
                store.close()
            total = range_reads * RANGE_BYTES
            noscan = common.mbps(total, noscan_s)
            under = common.mbps(total, scan_s)
            rows.append({
                "bench": "cache_scan", "workload": "sql_dump",
                "policy": policy, "variant": "scan-ab",
                "versions": versions, "cache_mb": round(
                    cache_bytes / 2**20, 2),
                "range_reads": range_reads,
                "noscan_mbps": round(noscan, 2),
                "underscan_mbps": round(under, 2),
                "retained_pct": round(100.0 * under / noscan, 1),
                "ghost_hits": signals["ghost_hits"],
                "evictions": signals["evictions"],
                # the 10% guard binds arc only (lru *degrading* under
                # the scan is the expected half of the A/B), and only
                # at full scale — quick/CI caches are small enough
                # that the threshold is noise, so guard=False leaves
                # the column advisory (None)
                "within_guard": (under >= 0.9 * noscan
                                 if policy == "arc" and guard else None),
            })
    return rows


def _race(tmp: str, jobs, n_threads: int,
          singleflight: bool) -> tuple[float, int, dict]:
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "backend": "file",
         "backend_args": {"path": tmp, "singleflight": singleflight}})
    store = api.build_store(cfg)
    errors = [0]
    lock = threading.Lock()
    barrier = threading.Barrier(n_threads)

    def worker():
        # lockstep, not a shared queue: every thread restores the same
        # streams in the same order, so cold chains are hit by all
        # threads at once — the thundering-herd shape singleflight
        # exists for
        barrier.wait()
        for handle, digest, _ in jobs:
            try:
                ok = hashlib.sha1(store.restore(handle)).digest() == digest
            except Exception:
                ok = False
            if not ok:
                with lock:
                    errors[0] += 1

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(n_threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    signals = store.cache_stats()
    store.close()
    return wall, errors[0], signals


def run_singleflight(base_size: int = 4 << 20, versions: int = 6,
                     avg_size: int = 8192, n_threads: int = 4,
                     repeats: int = 3) -> list[dict]:
    """Cold aggregate MB/s, 4 threads racing over the newest (deepest-
    chained, decode-dominated) delta-heavy stream, singleflight off vs
    on; one paired row. The newest version is the one every chunk of
    which decodes through the shared ancestor chains — the stream whose
    cold thundering herd singleflight collapses."""
    vs = common.make_versions("sql_dump", base_size, versions)
    with tempfile.TemporaryDirectory() as tmp:
        handles = _ingest(tmp, vs, avg_size=avg_size)
        # every thread restores the newest stream, in lockstep (_race)
        jobs = [(handles[-1], hashlib.sha1(vs[-1]).digest(), len(vs[-1]))]
        total = len(vs[-1]) * n_threads
        timing, errs, signals = {}, 0, {}
        for sf in (False, True):
            best = float("inf")
            for _rep in range(repeats):
                wall, e, sig = _race(tmp, jobs, n_threads, sf)
                errs += e
                if wall < best:
                    best = wall
                    if sf:
                        signals = sig
            timing[sf] = best
        off = common.mbps(total, timing[False])
        on = common.mbps(total, timing[True])
        return [{
            "bench": "cache_singleflight", "workload": "sql_dump",
            "variant": "cold-race", "threads": n_threads,
            "versions": versions, "bytes_mb": round(total / 2**20, 2),
            "nosf_agg_mbps": round(off, 2),
            "sf_agg_mbps": round(on, 2),
            "speedup": round(on / off, 2),
            "sf_waits": signals.get("singleflight_waits", 0),
            "sf_collapsed": signals.get("singleflight_collapsed", 0),
            "decoded_chunks": signals.get("decoded_chunks", 0),
            "errors": errs,
            "sf_gate": on >= 2.0 * off,
        }]


def _obj_serving(tmp: str, latency: float, bandwidth: float,
                 tier: str | None) -> api.DedupStore:
    d = {"detector": "dedup-only", "backend": "objectstore",
         "backend_args": {"path": tmp, "latency": latency,
                          "bandwidth_bps": bandwidth}}
    if tier is not None:
        d["restore_tier_path"] = tier
    return api.build_store(api.DedupConfig.from_dict(d))


def run_tier(base_size: int = 4 << 20, versions: int = 4,
             avg_size: int = 8192, latency: float = 0.002,
             bandwidth: float = 24e6, repeats: int = 3) -> list[dict]:
    """Cold restores over the object store with per-request latency and
    a bandwidth cap (remote bytes cost wall-clock; local tier bytes are
    free): no tier, tier filling (first cold pass), tier serving (fresh
    reopen, payloads off local disk). One row per variant, GET counts
    included."""
    vs = common.make_versions("sql_dump", base_size, versions)
    rows = []
    with tempfile.TemporaryDirectory() as tmp, \
            tempfile.TemporaryDirectory() as tier:
        obj = str(Path(tmp) / "o")
        cfg = common.detector_config("card", avg_size=avg_size)
        cfg.backend, cfg.backend_args = "objectstore", {"path": obj}
        store = api.build_store(cfg)
        store.fit(list(vs[:1]))
        handles = []
        for v in vs:
            with store.open_stream() as s:
                s.write(v)
            handles.append(s.report.handle)
        store.close()
        total = sum(len(v) for v in vs)

        def cold_pass(tier_path):
            store = _obj_serving(obj, latency, bandwidth, tier_path)
            t0 = time.perf_counter()
            for h in handles:
                store.restore(h)
            wall = time.perf_counter() - t0
            counts = store.backend.client.op_counts
            gets = counts.get("get", 0) + counts.get("get_range", 0)
            store.close()
            return wall, gets

        variants = []
        for name in ("no-tier", "tier-fill", "tier-serve"):
            best, gets = float("inf"), 0
            for _rep in range(repeats):
                if name != "tier-serve":    # fill measures an empty tier
                    for p in Path(tier).glob("**/*"):
                        if p.is_file():
                            p.unlink()
                if name == "tier-fill":
                    wall, g = cold_pass(tier)
                elif name == "tier-serve":
                    cold_pass(tier)         # fill, then measure a reopen
                    wall, g = cold_pass(tier)
                else:
                    wall, g = cold_pass(None)
                if wall < best:
                    best, gets = wall, g
            variants.append((name, best, gets))
        for name, wall, gets in variants:
            rows.append({
                "bench": "cache_tier", "workload": "sql_dump",
                "variant": name, "versions": versions,
                "latency_ms": latency * 1e3,
                "bandwidth_mbps": round(bandwidth / 1e6, 1),
                "bytes_mb": round(total / 2**20, 2),
                "cold_mbps": round(common.mbps(total, wall), 2),
                "gets": gets,
            })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI smoke)")
    ap.add_argument("--json", default=str(JSON_PATH),
                    help="where to write the JSON row dump")
    args = ap.parse_args()
    if args.quick:
        rows = (run_scan(base_size=1 << 20, versions=3, range_reads=60,
                         scan_rounds=2, scan_mb=6, repeats=1, guard=False)
                + run_singleflight(base_size=1 << 20, versions=3,
                                   repeats=1)
                + run_tier(base_size=1 << 20, versions=3, repeats=1))
    else:
        rows = run_scan() + run_singleflight() + run_tier()
    for section in ("cache_scan", "cache_singleflight", "cache_tier"):
        common.emit([r for r in rows if r["bench"] == section], section)
    bad = [r for r in rows
           if r.get("within_guard") is False or r.get("sf_gate") is False
           or r.get("errors", 0)]
    if bad:
        print(f"# WARNING: {len(bad)} row(s) outside the §14 gates")
    path = Path(args.json)
    existing = []
    if path.exists():
        keep = {(r.get("bench"), r.get("variant"), r.get("policy"))
                for r in rows}
        existing = [r for r in json.loads(path.read_text())
                    if (r.get("bench"), r.get("variant"),
                        r.get("policy")) not in keep]
    path.write_text(json.dumps(existing + rows, indent=2) + "\n")
    print(f"# wrote {len(rows)} rows to {path}")


if __name__ == "__main__":
    main()
