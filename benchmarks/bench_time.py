"""Paper Figs 6 / 9 / 10: resemblance-detection time vs average chunk size.

The paper's speed metric covers feature extraction + index search (not
chunking or delta I/O); `StoreStats.detect_seconds` matches that
accounting. Speedup columns are CARD-relative (paper: 5.6x-17.8x)."""
from __future__ import annotations

from benchmarks import common


def run(chunk_sizes=None, base_size=6 << 20, versions=4) -> list[dict]:
    rows = []
    sizes = chunk_sizes or common.CHUNK_SIZES[:4]
    for wl in common.WORKLOADS:
        vs = common.make_versions(wl, base_size, versions)
        for avg in sizes:
            cell = {}
            for kind in ("finesse", "n-transform", "card"):
                stats, _ = common.run_cell(kind, vs, avg)
                cell[kind] = stats.detect_seconds
            rows.append({
                "bench": "time", "workload": wl, "avg_chunk": avg,
                "card_s": round(cell["card"], 3),
                "finesse_s": round(cell["finesse"], 3),
                "ntransform_s": round(cell["n-transform"], 3),
                "speedup_vs_finesse": round(cell["finesse"] / max(cell["card"], 1e-9), 2),
                "speedup_vs_ntransform": round(cell["n-transform"] / max(cell["card"], 1e-9), 2),
            })
    return rows


def main():
    common.emit(run(), "time")


if __name__ == "__main__":
    main()
