"""Chaos smoke gate (``make chaos-smoke``, DESIGN.md §13).

Fails loudly — nonzero exit — unless the integrity machinery catches
every fault this script injects:

  * **bit-rot detection, 100% or bust**: flip one payload bit in each
    of several known chunks per backend (file + objectstore); every
    injected cid must show up in ``scrub().corrupt``, a verified read
    of an affected stream must raise ``CorruptChunkError``, and after
    ``scrub(repair=True)`` a fresh scrub — and a reopened store's
    scrub — must be clean while untouched streams restore
    byte-identically;
  * **crash matrix, every registered point**: for each crashpoint in
    ``registered_crashpoints()`` run the scripted
    ingest/delete/collect/compact workload to the simulated kill,
    snapshot the directory, reopen, and require
    ``check_crash_invariants`` to hold (scrub clean, committed streams
    byte-identical, deleted streams deleted, in-flight op atomic);
  * **journal damage typing**: mid-file recipe-journal corruption must
    raise ``CorruptJournalError`` on open, while a torn tail must
    still open clean.

    PYTHONPATH=src python -m benchmarks.chaos_smoke
"""
from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from repro import api
from repro.api import faults as F
import repro.api.objectstore as osmod  # noqa: F401 - registers crashpoints
from repro.api.objectstore import _OBJ_MASK, _OBJ_SHIFT

FLIPS_PER_BACKEND = 3


def check(cond: bool, what: str) -> None:
    if not cond:
        raise SystemExit(f"chaos-smoke FAILED: {what}")


def _data(size: int, seed: int) -> bytes:
    rng = np.random.default_rng(seed)
    return bytes(rng.integers(0, 256, size, np.uint8))


def _build(backend: str, root, injector=None) -> api.DedupStore:
    args = {"path": str(root)}
    if injector is not None:
        args["faults"] = injector
    return api.build_store(api.DedupConfig.from_dict(
        {"detector": "card", "backend": backend, "backend_args": args,
         "verify_reads": True}))


def _payload_location(store, cid: int, root: Path, backend: str):
    """(file path, absolute payload offset, length) of one stored chunk."""
    _, _, voff, length = store.backend._index[cid]
    if backend == "file":
        return root / "chunks.log", voff, length
    seq, off = voff >> _OBJ_SHIFT, voff & _OBJ_MASK
    epoch = store.backend.epoch
    return root / f"e{epoch:08d}" / "chunks" / f"{seq:08d}", off, length


def bitrot_drill(backend: str) -> None:
    with tempfile.TemporaryDirectory() as tmp:
        root = Path(tmp) / "store"
        store = _build(backend, root)
        keep = _data(200_000, 1)
        doomed = _data(200_000, 2)
        store.fit([keep])
        with store.open_stream() as s:
            s.write(keep)
        h_keep = s.report.handle
        with store.open_stream() as s:
            s.write(doomed)
        h_doomed = s.report.handle
        store.backend.flush()

        victims = [c for c in store.backend.recipe(h_doomed)
                   if c not in set(store.backend.recipe(h_keep))]
        victims = victims[:FLIPS_PER_BACKEND]
        check(len(victims) > 0, f"{backend}: no distinct chunks to corrupt")
        for cid in victims:
            path, off, length = _payload_location(store, cid, root, backend)
            F.flip_bit(path, off + length // 2, bit=2)
        store.backend._cache.retain(lambda cid: False)

        raised = False
        try:
            store.restore(h_doomed)
        except api.CorruptChunkError:
            raised = True
        check(raised, f"{backend}: verified read served corrupt bytes")

        rep = store.scrub()
        detected = set(rep.corrupt)
        missed = [c for c in victims if c not in detected]
        check(not missed,
              f"{backend}: scrub missed injected corruption in {missed} "
              f"(detected {sorted(detected)})")
        check(h_doomed in rep.streams_lost,
              f"{backend}: corrupt stream not reported lost")

        fix = store.scrub(repair=True)
        check(fix.repaired, f"{backend}: repair did nothing")
        check(store.scrub().clean, f"{backend}: store dirty after repair")
        check(store.restore(h_keep) == keep,
              f"{backend}: repair damaged an untouched stream")
        store.close()

        reopened = _build(backend, root)
        check(reopened.scrub().clean,
              f"{backend}: quarantine did not survive reopen")
        reopened.close()
        print(f"  bit-rot [{backend}]: {len(victims)} flips injected, "
              f"{len(victims)} detected, repair clean")


def crash_matrix(backend: str, points: list[str]) -> None:
    failed: dict[str, object] = {}
    for point in points:
        with tempfile.TemporaryDirectory() as tmp:
            root = Path(tmp) / "store"
            snap = Path(tmp) / "snap"
            inj = F.FaultInjector()
            store = _build(backend, root, inj)
            d1 = _data(120_000, 3)
            d2 = d1[:60_000] + _data(20_000, 4) + d1[60_000:]
            store.fit([d1])
            inj.arm(point)
            run = F.run_crash_script(store, [
                ("ingest", "a", d1), ("ingest", "b", d2),
                ("delete", "a"), ("collect",), ("compact",),
                ("ingest", "c", _data(90_000, 5)), ("flush",)])
            F.snapshot_dir(root, snap)
            F.abandon(store)
            if run.crashed_at != point:
                failed[point] = "crashpoint never fired"
                continue
            reopened = _build(backend, snap)
            errors = F.check_crash_invariants(reopened, run)
            reopened.close()
            if errors:
                failed[point] = errors
    check(not failed, f"{backend}: crash matrix violations: {failed}")
    print(f"  crash matrix [{backend}]: {len(points)} points, "
          f"all invariants held")


def journal_damage_drill() -> None:
    with tempfile.TemporaryDirectory() as tmp:
        root = Path(tmp) / "store"
        store = _build("file", root)
        data = _data(120_000, 6)
        store.fit([data])
        with store.open_stream() as s:
            s.write(data)
        h = s.report.handle
        with store.open_stream() as s:
            s.write(_data(60_000, 7))
        store.close()
        recipes = root / "recipes.jsonl"

        # torn tail: must open clean and restore
        pristine = recipes.read_bytes()
        with open(recipes, "ab") as f:
            f.write(b'{"recipe": [9')
        store2 = _build("file", root)
        check(store2.restore(h) == data, "torn tail broke recovery")
        check(store2.scrub().clean, "torn tail left store dirty")
        store2.close()

        # mid-file damage: must be a typed, loud error
        lines = pristine.splitlines(keepends=True)
        lines[1] = b"@@garbage@@\n"
        recipes.write_bytes(b"".join(lines))
        typed = False
        try:
            _build("file", root)
        except api.CorruptJournalError:
            typed = True
        check(typed, "mid-file journal damage was not a typed error")
    print("  journal damage: torn tail recovered, mid-file damage typed")


def main() -> None:
    print("# chaos smoke (DESIGN.md §13)")
    for backend in ("file", "objectstore"):
        bitrot_drill(backend)
    reg = F.registered_crashpoints()
    crash_matrix("file", sorted(p for p in reg if p.startswith("file.")))
    crash_matrix("objectstore",
                 sorted(p for p in reg if p.startswith("objstore.")))
    journal_damage_drill()
    print("chaos-smoke OK")


if __name__ == "__main__":
    main()
