"""Per-Pallas-kernel microbenchmark: interpret-mode kernel vs pure-jnp ref
(correctness is asserted; on-CPU wall time is for the ref path, which is
the deployable CPU fallback — TPU timing requires hardware)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.kernels import gear_hash, ops, ref, shingle_embed, sim_topk


def _t(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[dict]:
    rng = np.random.Generator(np.random.PCG64(0))
    rows = []

    g = jnp.asarray(rng.integers(0, 2**32, size=(64, 8192), dtype=np.uint32))
    weights = tuple(int(w) for w in hashing.GEAR_WEIGHTS)
    ref_us = _t(lambda x: ref.windowed_sum_ref(x, np.asarray(weights, np.uint32)), g)
    kern = gear_hash.windowed_sum(g, weights, interpret=True)
    oracle = ref.windowed_sum_ref(g, np.asarray(weights, np.uint32))
    rows.append({"bench": "kernels", "name": "gear_hash.windowed_sum",
                 "shape": "64x8192", "us_per_call_ref": round(ref_us, 1),
                 "allclose": bool(np.array_equal(np.asarray(kern), np.asarray(oracle)))})

    ids = jnp.asarray(rng.integers(0, 2**32, size=(256, 61), dtype=np.uint32))
    mask = jnp.ones((256, 61), jnp.float32)
    a, b = hashing.multiply_shift_params(64)
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    ref_us = _t(lambda i, m: ref.shingle_embed_ref(i, m > 0, aj, bj), ids, mask)
    kern = shingle_embed.shingle_embed_sum(ids, mask, aj.reshape(1, -1),
                                           bj.reshape(1, -1), interpret=True)
    oracle = ref.shingle_embed_ref(ids, mask > 0, aj, bj) * 61
    rows.append({"bench": "kernels", "name": "shingle_embed",
                 "shape": "256x61x64", "us_per_call_ref": round(ref_us, 1),
                 "allclose": bool(np.allclose(np.asarray(kern), np.asarray(oracle),
                                              atol=1e-4))})

    q = jnp.asarray(rng.standard_normal((64, 50)).astype(np.float32))
    idx = jnp.asarray(rng.standard_normal((16384, 50)).astype(np.float32))
    ref_us = _t(lambda a_, b_: ref.sim_topk_ref(a_, b_), q, idx)
    ks, ka = sim_topk.sim_topk(q, idx, interpret=True)
    rs, ra = ref.sim_topk_ref(q, idx)
    rows.append({"bench": "kernels", "name": "sim_topk",
                 "shape": "64x16384x50", "us_per_call_ref": round(ref_us, 1),
                 "allclose": bool(np.allclose(np.asarray(ks), np.asarray(rs),
                                              atol=1e-4)
                                  and np.array_equal(np.asarray(ka), np.asarray(ra)))})
    return rows


def main():
    from benchmarks import common
    common.emit(run(), "kernels")


if __name__ == "__main__":
    main()
