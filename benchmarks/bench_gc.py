"""Reclamation throughput (DESIGN.md §7): a retention-style churn loop —
ingest N backup generations per workload, expire the oldest until only
`retain` survive, then collect + compact a FileBackend container.

Reported per cell: delete and compact wall time, reclaimed bytes, the
rebase mix, delete+compact throughput in MB/s of container rewritten,
and the DCR of the surviving generations *after* compaction (bytes the
survivors represent / container bytes actually on disk) — the paper's
DCR metric carried through the churn the append-only v0 store could not
express. Rows also land in BENCH_GC.json so the reclamation perf
trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.bench_gc [--quick] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

from benchmarks import common
from repro import api

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_GC.json"


def run(base_size: int = 6 << 20, versions: int = 6, retain: int = 3,
        detectors=("dedup-only", "finesse", "card")) -> list[dict]:
    rows = []
    for wl in common.WORKLOADS:
        vs = common.make_versions(wl, base_size, versions)
        for kind in detectors:
            cfg = common.detector_config(kind, avg_size=8192)
            with tempfile.TemporaryDirectory() as tmp:
                cfg.backend, cfg.backend_args = "file", {"path": tmp}
                store = api.build_store(cfg)
                t0 = time.perf_counter()
                store.fit(list(vs[:1]))
                handles = []
                for v in vs:
                    session = store.open_stream()
                    session.write(v)
                    handles.append(session.commit().handle)
                ingest_s = time.perf_counter() - t0
                dcr_before = store.stats.dcr
                size_before = store.backend.storage_bytes()

                t0 = time.perf_counter()
                for h in handles[:versions - retain]:
                    store.delete(h)
                delete_s = time.perf_counter() - t0
                t0 = time.perf_counter()
                collect_rep = store.collect()
                collect_s = time.perf_counter() - t0
                run_rep = store.compact()

                survivors = vs[versions - retain:]
                for h, v in zip(handles[versions - retain:], survivors):
                    assert store.restore(h) == v
                dcr_post = (sum(len(v) for v in survivors)
                            / max(1, store.backend.storage_bytes()))
                churn_s = delete_s + collect_s + run_rep.seconds
                rows.append({
                    "bench": "gc", "workload": wl, "detector": kind,
                    "versions": versions, "retain": retain,
                    "ingest_s": round(ingest_s, 3),
                    "delete_s": round(delete_s, 4),
                    "collect_s": round(collect_s, 4),
                    "compact_s": round(run_rep.seconds, 4),
                    "swept_chunks": run_rep.swept_chunks,
                    "rebased_delta": run_rep.rebased_delta,
                    "rebased_raw": run_rep.rebased_raw,
                    "reclaimed_mb": round(run_rep.reclaimed_bytes / 2**20, 3),
                    "skipped": run_rep.skipped,
                    "dead_mb_marked": round(
                        collect_rep.reclaimable_bytes / 2**20, 3),
                    "churn_mbps": round(size_before / 2**20 / max(1e-9,
                                                                  churn_s), 2),
                    "dcr_before": round(dcr_before, 4),
                    "dcr_post": round(dcr_post, 4),
                })
                store.close()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI smoke)")
    ap.add_argument("--json", default=str(JSON_PATH),
                    help="where to write the JSON row dump")
    args = ap.parse_args()
    if args.quick:
        rows = run(base_size=2 << 20, versions=4, retain=2,
                   detectors=("dedup-only", "finesse"))
    else:
        rows = run()
    common.emit(rows, "gc")
    Path(args.json).write_text(json.dumps(rows, indent=2) + "\n")
    print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
