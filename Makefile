# Developer/CI entry points. Tier-1 verify is the `test` target
# (ROADMAP.md); `ci` = install dev deps + tier-1 + the lifecycle suite.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: dev-deps test test-fast test-lifecycle ci bench bench-smoke \
        observe-smoke chaos-smoke gc-bench ingest-bench restore-bench \
        serve-bench verify-bench objstore-bench cache-bench serve-slo \
        quickstart

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt

test:
	$(PYTHON) -m pytest -x -q

# tier-1 minus the slow subprocess mesh tests (inner-loop development)
test-fast:
	$(PYTHON) -m pytest -x -q -m "not subprocess_mesh"

# space-reclamation suite on its own (also part of the tier-1 collection)
test-lifecycle:
	$(PYTHON) -m pytest tests/test_lifecycle.py tests/test_lifecycle_property.py -q

ci: dev-deps test test-lifecycle

bench:
	$(PYTHON) -m benchmarks.run --quick

# tiny-input run of EVERY section; exits nonzero if any section raises,
# so the perf plumbing cannot silently rot
bench-smoke:
	$(PYTHON) -m benchmarks.run --smoke

# tiny ingest+restore with tracing on: validates the Prometheus
# exposition (label escaping, TYPE lines, cumulative buckets), the JSON
# snapshot, the JSONL trace sink and the dump CLI (DESIGN.md §12)
observe-smoke:
	$(PYTHON) -m benchmarks.observe_smoke

# integrity gate (DESIGN.md §13): injected bit rot must be 100%
# detected + repaired, every registered crashpoint must reopen to a
# scrub-clean store, journal damage must be typed; nonzero exit on any
# undetected corruption
chaos-smoke:
	$(PYTHON) -m benchmarks.chaos_smoke

# delete+compact throughput smoke; writes BENCH_GC.json for perf tracking
gc-bench:
	$(PYTHON) -m benchmarks.bench_gc --quick

# end-to-end ingest MB/s + stage breakdown; writes BENCH_INGEST.json
ingest-bench:
	$(PYTHON) -m benchmarks.bench_ingest

# cold/warm/ranged/post-compaction restore MB/s; writes BENCH_RESTORE.json
restore-bench:
	$(PYTHON) -m benchmarks.bench_restore

# concurrent serving engine: aggregate MB/s + p50/p99 latency at 1/2/4
# restore threads (DESIGN.md §10.7); appends rows to BENCH_RESTORE.json
serve-bench:
	$(PYTHON) -m benchmarks.bench_restore --threads 1,2,4

# verified-read overhead (DESIGN.md §13.2): cold+warm restore with
# per-chunk crc32c off vs on; warm overhead guarded at ±15%; appends
# rows to BENCH_RESTORE.json
verify-bench:
	$(PYTHON) -m benchmarks.bench_restore --verify-reads

# object-store serving: coalesced ranged GETs vs per-chunk baseline under
# injected latency (DESIGN.md §11.3); writes BENCH_OBJSTORE.json
objstore-bench:
	$(PYTHON) -m benchmarks.bench_objstore

# cache hierarchy (DESIGN.md §14): scan A/B lru vs arc, cold-race
# singleflight collapse, disk tier over a latency+bandwidth-limited
# object store; writes BENCH_CACHE.json
cache-bench:
	$(PYTHON) -m benchmarks.bench_cache

# multi-tenant SLO load harness (DESIGN.md §15.5): open-loop mixed
# workload across 4 tenants, baseline + backend fault drill; gates on
# zero integrity errors / hangs / late reads and on the breaker
# opening then recovering; writes BENCH_SERVE.json
serve-slo:
	$(PYTHON) -m benchmarks.bench_serve --quick --check

quickstart:
	$(PYTHON) examples/quickstart.py
