# Developer/CI entry points. Tier-1 verify is the `test` target
# (ROADMAP.md); `ci` = install dev deps + tier-1.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: dev-deps test ci bench quickstart

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt

test:
	$(PYTHON) -m pytest -x -q

ci: dev-deps test

bench:
	$(PYTHON) -m benchmarks.run --quick

quickstart:
	$(PYTHON) examples/quickstart.py
