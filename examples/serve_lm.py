"""Batched autoregressive serving with KV caches: prefill a batch of
prompts token-by-token, then decode continuations, reporting tokens/s.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --prompt-len 16 --gen 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import make_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dec = jax.jit(model.decode_step)

    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    cache = model.init_cache(args.batch, args.prompt_len + args.gen)

    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):          # prefill via the decode path
        logits, cache = dec(params, prompts[:, i:i + 1], cache)
    prefill_s = time.time() - t0

    out = []
    t0 = time.time()
    tok = jnp.argmax(logits, axis=-1)[:, None]
    for i in range(args.gen):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = dec(params, tok, cache)
        if args.temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None]
    gen_s = time.time() - t0

    toks = np.stack(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} steps in {prefill_s:.2f}s")
    print(f"decode:  {args.gen} steps in {gen_s:.2f}s "
          f"({args.batch*args.gen/max(gen_s,1e-9):.1f} tok/s)")
    print("sample token ids:", toks[0][:12].tolist())
    assert np.isfinite(np.asarray(logits, np.float32)).all()


if __name__ == "__main__":
    main()
