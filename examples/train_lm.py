"""Train a small LM end-to-end with CARD-deduplicated checkpointing.

Reduced granite-8b (llama-style) by default; `--params 100m` builds a
~100M-parameter variant (slow on 1 CPU core — a few hundred steps take a
while; reduce --steps accordingly).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import optim
from repro.checkpoint import DedupCheckpointStore
from repro.configs import get_config
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.models import make_model
from repro.train import make_train_step
from repro.train.step import init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--params", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config("granite-8b").reduced()
    if args.params == "100m":
        cfg = dataclasses.replace(cfg, num_layers=8, d_model=768,
                                  num_heads=12, num_kv_heads=4, d_ff=2048,
                                  vocab_size=32000)
    model = make_model(cfg)
    print(f"model: {cfg.name} ~{cfg.param_count()/1e6:.1f}M params")

    tx = optim.adamw(optim.cosine_schedule(3e-3, 20, args.steps),
                     weight_decay=0.1, max_grad_norm=1.0)
    state = init_state(model.init(jax.random.PRNGKey(0)), tx)
    step_fn = jax.jit(make_train_step(model, tx))
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, global_batch=args.batch, seq_len=args.seq))

    store = DedupCheckpointStore()
    losses = []
    t0 = time.time()
    for step in range(args.steps):
        state, metrics = step_fn(state, pipe.batch(step))
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        if (step + 1) % args.checkpoint_every == 0:
            s = store.save(jax.device_get(state.params), step + 1)
            print(f"  [ckpt] step {step+1}: store DCR {s.dcr:.2f} "
                  f"({s.bytes_stored >> 20} MiB for {s.bytes_in >> 20} MiB raw)",
                  flush=True)
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss must decrease"
    print(f"done: loss {np.mean(losses[:5]):.3f} -> {np.mean(losses[-5:]):.3f}; "
          f"checkpoint store DCR {store.stats.dcr:.2f}")


if __name__ == "__main__":
    main()
