"""End-to-end driver (the paper's scenario): a backup service ingesting
nightly versions of three datasets, with CARD's context model trained on
the first night, per-night stats, and full restore validation.

    PYTHONPATH=src python examples/dedup_backup_run.py [--size-mb 8] [--nights 5]
"""
import argparse
import time

from repro.core import CARDDetector, ChunkerConfig, DedupStore
from repro.data import make_workload, WorkloadConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=6)
    ap.add_argument("--nights", type=int, default=5)
    ap.add_argument("--avg-chunk", type=int, default=16384)
    args = ap.parse_args()

    for wl in ("sql_dump", "vmdk", "kernel"):
        versions = make_workload(wl, WorkloadConfig(
            base_size=args.size_mb << 20, versions=args.nights))
        store = DedupStore(CARDDetector(use_kernel=False),
                           ChunkerConfig(avg_size=args.avg_chunk))
        t0 = time.time()
        store.fit(versions[:1])           # offline context-model training
        fit_s = time.time() - t0
        print(f"\n=== {wl}: {args.nights} nights x {args.size_mb} MiB "
              f"(model fit {fit_s:.1f}s) ===")
        prev_stored = 0
        for night, v in enumerate(versions):
            store.ingest(v)
            s = store.stats
            stored_tonight = s.bytes_stored - prev_stored
            prev_stored = s.bytes_stored
            print(f"night {night}: ingested {len(v) >> 20} MiB, "
                  f"stored {stored_tonight >> 10} KiB new, "
                  f"cumulative DCR {s.dcr:.2f} "
                  f"(dup {s.dup_chunks} / delta {s.delta_chunks} / raw {s.raw_chunks})")
        for night in range(args.nights):
            assert store.restore(night) == versions[night]
        print(f"restore: all {args.nights} nights byte-exact | "
              f"total detect {store.stats.detect_seconds:.2f}s "
              f"delta-io {store.stats.delta_seconds:.2f}s")


if __name__ == "__main__":
    main()
