"""End-to-end driver (the paper's scenario): a backup service ingesting
nightly versions of three datasets, with CARD's context model trained on
the first night, a per-night IngestReport from each committed stream
session, and full restore validation — optionally against the on-disk
container backend.

With ``--retain K`` the run continues into the retention phase
(DESIGN.md §7): the oldest nights are expired via ``store.delete``, the
mark-sweep ``collect()`` classifies what became reclaimable, and
``compact()`` rewrites the container — rebasing surviving patches whose
base night was expired — reporting the measured bytes given back.

The serving phase (DESIGN.md §9) then reads the newest night back the
way a restore service would: a full planned ``restore`` with its
``RestoreReport`` telemetry, a streaming ``restore_iter`` pass, and
random partial-object reads via ``restore_range`` (only the chunks the
range overlaps are decoded, via the recipe's persisted prefix sums).

    PYTHONPATH=src python examples/dedup_backup_run.py [--size-mb 8] \
        [--nights 5] [--backend file --store-dir /tmp/containers] \
        [--retain 3] [--policy never] [--range-reads 64]
"""
import argparse
import time

import numpy as np

from repro import api
from repro.data import make_workload, WorkloadConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=6)
    ap.add_argument("--nights", type=int, default=5)
    ap.add_argument("--avg-chunk", type=int, default=16384)
    ap.add_argument("--backend", choices=("memory", "file"), default="memory")
    ap.add_argument("--store-dir", default="/tmp/repro_containers")
    ap.add_argument("--retain", type=int, default=0,
                    help="keep only the newest K nights (0 = keep all)")
    ap.add_argument("--policy", default="never",
                    choices=("never", "eager", "threshold"),
                    help="auto-compaction policy consulted on each delete")
    ap.add_argument("--range-reads", type=int, default=64,
                    help="random 64 KiB partial reads in the serving phase")
    args = ap.parse_args()

    for wl in ("sql_dump", "vmdk", "kernel"):
        versions = make_workload(wl, WorkloadConfig(
            base_size=args.size_mb << 20, versions=args.nights))
        cfg = api.DedupConfig.from_dict({
            "detector": "card",
            "detector_args": {"use_kernel": False},
            "chunker_args": {"avg_size": args.avg_chunk},
            "backend": args.backend,
            "backend_args": ({"path": f"{args.store_dir}/{wl}"}
                             if args.backend == "file" else {}),
            "policy": args.policy,
        })
        store = api.build_store(cfg)
        t0 = time.time()
        store.fit(versions[:1])           # offline context-model training
        fit_s = time.time() - t0
        print(f"\n=== {wl}: {args.nights} nights x {args.size_mb} MiB "
              f"({args.backend} backend, model fit {fit_s:.1f}s) ===")
        handles = []
        for night, v in enumerate(versions):
            session = store.open_stream()
            session.write(v)
            rep = session.commit()
            handles.append(rep.handle)
            print(f"night {night}: ingested {rep.bytes_in >> 20} MiB, "
                  f"stored {rep.bytes_stored >> 10} KiB new, "
                  f"night DCR {rep.dcr:.2f} / cumulative {store.stats.dcr:.2f} "
                  f"(dup {rep.dup_chunks} / delta {rep.delta_chunks} / "
                  f"raw {rep.raw_chunks})")
        for night, h in enumerate(handles):
            assert store.restore(h) == versions[night]
        print(f"restore: all {args.nights} nights byte-exact | "
              f"total detect {store.stats.detect_seconds:.2f}s "
              f"delta-io {store.stats.delta_seconds:.2f}s")

        if 0 < args.retain < args.nights:
            expire = handles[:args.nights - args.retain]
            t0 = time.time()
            for h in expire:
                store.delete(h)     # eager/threshold policies compact here
            marked = store.collect()
            print(f"retention: expired nights 0-{len(expire) - 1}, "
                  f"marked {marked.reclaimable_bytes >> 10} KiB reclaimable "
                  f"({marked.pinned_chunks} chunks pinned as delta bases)")
            if args.policy == "never":
                run = store.compact()
                print(f"compaction epoch {run.epoch}: swept "
                      f"{run.swept_chunks} chunks, rebased "
                      f"{run.rebased_delta} patches + {run.rebased_raw} to "
                      f"raw, reclaimed {store.stats.reclaimed_bytes >> 10} "
                      f"KiB in {time.time() - t0:.2f}s")
            elif store.backend.epoch > 0:
                print(f"policy '{args.policy}' compacted during deletes: "
                      f"epoch {store.backend.epoch}, reclaimed "
                      f"{store.stats.reclaimed_bytes >> 10} KiB "
                      f"in {time.time() - t0:.2f}s")
            else:
                print(f"policy '{args.policy}' did not trigger compaction "
                      f"({store.stats.dead_bytes >> 10} KiB still awaiting "
                      f"an explicit compact())")
            for night in range(args.nights - args.retain, args.nights):
                assert store.restore(handles[night]) == versions[night]
            post = store.collect()          # re-mark: post-compaction depths
            print(f"restore: surviving {args.retain} nights still byte-exact "
                  f"| live {store.stats.live_bytes >> 20} MiB on disk, "
                  f"chain depths {post.chain_depth_hist}")

        # serving phase (DESIGN.md §9): read the newest night back the
        # way a restore service would
        h, newest = handles[-1], versions[-1]
        full = store.restore(h)
        rep = store.last_restore
        print(f"serve: full restore {rep.bytes_out >> 20} MiB in "
              f"{rep.seconds:.3f}s (read {rep.read_seconds:.3f}s / decode "
              f"{rep.decode_seconds:.3f}s, cache {rep.cache_hits} hit / "
              f"{rep.cache_misses} miss, "
              f"read-amp {rep.read_amplification:.2f})")
        streamed = b"".join(store.restore_iter(h))
        assert full == streamed == newest
        rng = np.random.default_rng(0)
        t0 = time.time()
        for off in rng.integers(0, max(1, len(newest) - (64 << 10)),
                                args.range_reads):
            off = int(off)
            assert (store.restore_range(h, off, 64 << 10)
                    == newest[off:off + (64 << 10)])
        print(f"serve: {args.range_reads} random 64 KiB ranged reads "
              f"byte-exact in {time.time() - t0:.3f}s "
              f"(last touched {store.last_restore.chunks} of "
              f"{len(store.backend.recipe(h))} recipe chunks)")

        # concurrent serving phase (DESIGN.md §10): several clients
        # restoring the surviving nights at once against one store —
        # sharded decode cache, pread reader pool, per-thread telemetry
        import threading
        retained = args.retain if 0 < args.retain < args.nights \
            else args.nights                    # --retain 0 keeps all
        survivors = handles[args.nights - retained:]
        errors = []

        def client(seed):
            rng = np.random.default_rng(seed)
            try:
                for _ in range(3):
                    night = int(rng.integers(0, len(survivors)))
                    got = store.restore(survivors[night])
                    if got != versions[args.nights - retained + night]:
                        raise AssertionError("concurrent restore mismatch")
            except Exception as e:
                errors.append(e)

        t0 = time.time()
        clients = [threading.Thread(target=client, args=(s,))
                   for s in range(4)]
        for c in clients:
            c.start()
        for c in clients:
            c.join()
        assert not errors, errors
        stats = store.stats
        print(f"serve: 4 concurrent clients x 3 restores byte-exact in "
              f"{time.time() - t0:.3f}s (lifetime {stats.restores} "
              f"restores, {stats.restore_bytes_out >> 20} MiB served, "
              f"{stats.restore_prefetch_bytes >> 10} KiB read hidden "
              f"behind decode)")
        store.close()

        # object-store phase (DESIGN.md §11): the same newest nights kept
        # as immutable container objects behind a ranged-GET object API —
        # here the directory-backed fake with 2 ms injected per-request
        # latency and a scheduled transient GET fault, absorbed by the
        # backend's retry-with-backoff
        import tempfile
        with tempfile.TemporaryDirectory() as odir:
            ocfg = {"detector": "dedup-only",
                    "chunker_args": {"avg_size": args.avg_chunk},
                    "backend": "objectstore",
                    "backend_args": {"path": odir}}
            ostore = api.build_store(api.DedupConfig.from_dict(ocfg))
            for v in versions[-2:]:
                with ostore.open_stream() as s:
                    s.write(v)
            oh = s.report.handle
            ostore.close()
            # reopen against the surviving object tree (journal replay),
            # now with injected latency and a scheduled transient GET
            # fault, and serve the newest night cold
            ocfg["backend_args"] = {"path": odir, "latency": 0.002,
                                    "fault_hook":
                                        api.FaultSchedule({"get": [2]})}
            ostore = api.build_store(api.DedupConfig.from_dict(ocfg))
            assert ostore.restore(oh) == versions[-1]
            orep = ostore.last_restore
            print(f"objstore: newest night byte-exact over the object API "
                  f"in {orep.seconds:.3f}s — {orep.requests} coalesced "
                  f"ranged GETs for {len(ostore.backend.recipe(oh))} "
                  f"recipe chunks, {ostore.backend.retries} transient "
                  f"fault(s) retried, "
                  f"{ostore.backend.client.bytes_got >> 10} KiB fetched")
            ostore.close()

    # observability quickstart (DESIGN.md §12): every store carries a
    # metrics registry — Prometheus text via store.metrics()
    # .to_prometheus(), JSON via .to_json() — and setting
    # DedupConfig.trace_path / trace_ring_events turns on per-operation
    # trace spans (ring buffer + JSONL sink; pretty-print or follow the
    # sink with `python -m repro.api.observe dump|tail TRACE`).
    import tempfile
    versions = make_workload("sql_dump", WorkloadConfig(
        base_size=1 << 20, versions=2))
    with tempfile.TemporaryDirectory() as tdir:
        trace = f"{tdir}/trace.jsonl"
        tstore = api.build_store(api.DedupConfig.from_dict({
            "detector": "dedup-only",
            "chunker_args": {"avg_size": args.avg_chunk},
            "trace_path": trace, "trace_ring_events": 256}))
        for v in versions:
            with tstore.open_stream() as s:
                s.write(v)
        assert tstore.restore(s.report.handle) == versions[-1]
        text = tstore.metrics().to_prometheus()
        families = [ln.split()[2] for ln in text.splitlines()
                    if ln.startswith("# TYPE")]
        print(f"\n=== observability (DESIGN.md §12) ===")
        print(f"metrics: {len(families)} families, e.g.")
        picks = ("repro_ingest_stage_seconds_count",
                 "repro_restore_stage_seconds_count",
                 "repro_store_dcr", "repro_reader_requests_total")
        for ln in text.splitlines():
            if ln.startswith(picks):
                print(f"  {ln}")
        spans = tstore.observe.tracer.ops()
        print(f"trace: {sum(spans.values())} spans in the ring — " +
              ", ".join(f"{op} x{n}" for op, n in sorted(spans.items())
                        if "." not in op))
        tstore.close()
        with open(trace) as f:
            print(f"trace sink: {sum(1 for ln in f if ln.strip())} JSONL "
                  f"spans (follow live with "
                  f"`python -m repro.api.observe tail -f {{trace_path}}`)")

    # corruption drill (DESIGN.md §13): the operational failure mode the
    # integrity layer exists for — a bit rots in a stored container,
    # verified reads refuse to serve it, scrub prices the blast radius,
    # repair quarantines the damage, and the untouched night survives
    import tempfile
    from repro.api.faults import flip_bit
    versions = make_workload("sql_dump", WorkloadConfig(
        base_size=1 << 20, versions=2))
    with tempfile.TemporaryDirectory() as ddir:
        dcfg = {"detector": "dedup-only",
                "chunker_args": {"avg_size": args.avg_chunk},
                "backend": "file", "backend_args": {"path": ddir},
                "verify_reads": True}
        dstore = api.build_store(api.DedupConfig.from_dict(dcfg))
        handles = []
        for v in versions:
            with dstore.open_stream() as s:
                s.write(v)
            handles.append(s.report.handle)
        dstore.backend.flush()
        print(f"\n=== corruption drill (DESIGN.md §13) ===")
        rep = dstore.scrub()
        print(f"scrub (healthy): {rep.chunks} chunks, {rep.verified} "
              f"verified in {rep.seconds:.3f}s — clean={rep.clean}")

        # one bit rots in the chunk log
        log = f"{ddir}/chunks.log"
        import os as _os
        flip_bit(log, _os.path.getsize(log) // 2, bit=3)
        dstore.backend._cache.retain(lambda cid: False)
        try:
            dstore.restore(handles[-1])
            served = "SERVED CORRUPT BYTES"           # must not happen
        except api.CorruptChunkError as e:
            served = f"refused (cid {e.cid}, crc {e.actual:#010x} != "\
                     f"{e.expected:#010x})"
        print(f"verified read: {served}")

        rep = dstore.scrub()
        print(f"scrub (rotten): corrupt={list(rep.corrupt)} "
              f"lost={list(rep.lost)} blast_radius={rep.blast_radius} "
              f"streams_lost={list(rep.streams_lost)}")
        fix = dstore.scrub(repair=True)
        print(f"repair: quarantined {len(fix.quarantined)} chunk(s), "
              f"retired {len(fix.retired_streams)} stream(s) — "
              f"clean now: {dstore.scrub().clean}")
        survivors = [h for h in handles if h not in fix.retired_streams]
        for h in survivors:
            dstore.restore(h)       # raises if repair broke a good night
        print(f"survivors: {len(survivors)}/{len(handles)} nights still "
              f"byte-exact")
        dstore.close()


if __name__ == "__main__":
    main()
