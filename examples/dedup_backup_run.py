"""End-to-end driver (the paper's scenario): a backup service ingesting
nightly versions of three datasets, with CARD's context model trained on
the first night, a per-night IngestReport from each committed stream
session, and full restore validation — optionally against the on-disk
container backend.

With ``--retain K`` the run continues into the retention phase
(DESIGN.md §7): the oldest nights are expired via ``store.delete``, the
mark-sweep ``collect()`` classifies what became reclaimable, and
``compact()`` rewrites the container — rebasing surviving patches whose
base night was expired — reporting the measured bytes given back.

    PYTHONPATH=src python examples/dedup_backup_run.py [--size-mb 8] \
        [--nights 5] [--backend file --store-dir /tmp/containers] \
        [--retain 3] [--policy never]
"""
import argparse
import time

from repro import api
from repro.data import make_workload, WorkloadConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=6)
    ap.add_argument("--nights", type=int, default=5)
    ap.add_argument("--avg-chunk", type=int, default=16384)
    ap.add_argument("--backend", choices=("memory", "file"), default="memory")
    ap.add_argument("--store-dir", default="/tmp/repro_containers")
    ap.add_argument("--retain", type=int, default=0,
                    help="keep only the newest K nights (0 = keep all)")
    ap.add_argument("--policy", default="never",
                    choices=("never", "eager", "threshold"),
                    help="auto-compaction policy consulted on each delete")
    args = ap.parse_args()

    for wl in ("sql_dump", "vmdk", "kernel"):
        versions = make_workload(wl, WorkloadConfig(
            base_size=args.size_mb << 20, versions=args.nights))
        cfg = api.DedupConfig.from_dict({
            "detector": "card",
            "detector_args": {"use_kernel": False},
            "chunker_args": {"avg_size": args.avg_chunk},
            "backend": args.backend,
            "backend_args": ({"path": f"{args.store_dir}/{wl}"}
                             if args.backend == "file" else {}),
            "policy": args.policy,
        })
        store = api.build_store(cfg)
        t0 = time.time()
        store.fit(versions[:1])           # offline context-model training
        fit_s = time.time() - t0
        print(f"\n=== {wl}: {args.nights} nights x {args.size_mb} MiB "
              f"({args.backend} backend, model fit {fit_s:.1f}s) ===")
        handles = []
        for night, v in enumerate(versions):
            session = store.open_stream()
            session.write(v)
            rep = session.commit()
            handles.append(rep.handle)
            print(f"night {night}: ingested {rep.bytes_in >> 20} MiB, "
                  f"stored {rep.bytes_stored >> 10} KiB new, "
                  f"night DCR {rep.dcr:.2f} / cumulative {store.stats.dcr:.2f} "
                  f"(dup {rep.dup_chunks} / delta {rep.delta_chunks} / "
                  f"raw {rep.raw_chunks})")
        for night, h in enumerate(handles):
            assert store.restore(h) == versions[night]
        print(f"restore: all {args.nights} nights byte-exact | "
              f"total detect {store.stats.detect_seconds:.2f}s "
              f"delta-io {store.stats.delta_seconds:.2f}s")

        if 0 < args.retain < args.nights:
            expire = handles[:args.nights - args.retain]
            t0 = time.time()
            for h in expire:
                store.delete(h)     # eager/threshold policies compact here
            marked = store.collect()
            print(f"retention: expired nights 0-{len(expire) - 1}, "
                  f"marked {marked.reclaimable_bytes >> 10} KiB reclaimable "
                  f"({marked.pinned_chunks} chunks pinned as delta bases)")
            if args.policy == "never":
                run = store.compact()
                print(f"compaction epoch {run.epoch}: swept "
                      f"{run.swept_chunks} chunks, rebased "
                      f"{run.rebased_delta} patches + {run.rebased_raw} to "
                      f"raw, reclaimed {store.stats.reclaimed_bytes >> 10} "
                      f"KiB in {time.time() - t0:.2f}s")
            elif store.backend.epoch > 0:
                print(f"policy '{args.policy}' compacted during deletes: "
                      f"epoch {store.backend.epoch}, reclaimed "
                      f"{store.stats.reclaimed_bytes >> 10} KiB "
                      f"in {time.time() - t0:.2f}s")
            else:
                print(f"policy '{args.policy}' did not trigger compaction "
                      f"({store.stats.dead_bytes >> 10} KiB still awaiting "
                      f"an explicit compact())")
            for night in range(args.nights - args.retain, args.nights):
                assert store.restore(handles[night]) == versions[night]
            post = store.collect()          # re-mark: post-compaction depths
            print(f"restore: surviving {args.retain} nights still byte-exact "
                  f"| live {store.stats.live_bytes >> 20} MiB on disk, "
                  f"chain depths {post.chain_depth_hist}")
        store.close()


if __name__ == "__main__":
    main()
