"""End-to-end driver (the paper's scenario): a backup service ingesting
nightly versions of three datasets, with CARD's context model trained on
the first night, a per-night IngestReport from each committed stream
session, and full restore validation — optionally against the on-disk
container backend.

    PYTHONPATH=src python examples/dedup_backup_run.py [--size-mb 8] \
        [--nights 5] [--backend file --store-dir /tmp/containers]
"""
import argparse
import time

from repro import api
from repro.data import make_workload, WorkloadConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=6)
    ap.add_argument("--nights", type=int, default=5)
    ap.add_argument("--avg-chunk", type=int, default=16384)
    ap.add_argument("--backend", choices=("memory", "file"), default="memory")
    ap.add_argument("--store-dir", default="/tmp/repro_containers")
    args = ap.parse_args()

    for wl in ("sql_dump", "vmdk", "kernel"):
        versions = make_workload(wl, WorkloadConfig(
            base_size=args.size_mb << 20, versions=args.nights))
        cfg = api.DedupConfig.from_dict({
            "detector": "card",
            "detector_args": {"use_kernel": False},
            "chunker_args": {"avg_size": args.avg_chunk},
            "backend": args.backend,
            "backend_args": ({"path": f"{args.store_dir}/{wl}"}
                             if args.backend == "file" else {}),
        })
        store = api.build_store(cfg)
        t0 = time.time()
        store.fit(versions[:1])           # offline context-model training
        fit_s = time.time() - t0
        print(f"\n=== {wl}: {args.nights} nights x {args.size_mb} MiB "
              f"({args.backend} backend, model fit {fit_s:.1f}s) ===")
        handles = []
        for night, v in enumerate(versions):
            session = store.open_stream()
            session.write(v)
            rep = session.commit()
            handles.append(rep.handle)
            print(f"night {night}: ingested {rep.bytes_in >> 20} MiB, "
                  f"stored {rep.bytes_stored >> 10} KiB new, "
                  f"night DCR {rep.dcr:.2f} / cumulative {store.stats.dcr:.2f} "
                  f"(dup {rep.dup_chunks} / delta {rep.delta_chunks} / "
                  f"raw {rep.raw_chunks})")
        for night, h in enumerate(handles):
            assert store.restore(h) == versions[night]
        print(f"restore: all {args.nights} nights byte-exact | "
              f"total detect {store.stats.detect_seconds:.2f}s "
              f"delta-io {store.stats.delta_seconds:.2f}s")
        store.close()


if __name__ == "__main__":
    main()
