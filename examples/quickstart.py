"""Quickstart: dedup + delta-compress a 3-version backup stream with CARD,
compare against Finesse / N-transform, verify byte-exact restore.

Pipelines are built declaratively through the repro.api registry
(`DedupConfig.from_dict` -> `build_store`), ingestion goes through stream
sessions, and each committed stream returns its own IngestReport.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro import api
from repro.data import make_workload, WorkloadConfig


def main():
    versions = make_workload("sql_dump", WorkloadConfig(base_size=2 << 20, versions=3))
    print(f"workload: {len(versions)} versions x {len(versions[0]) >> 20} MiB")
    print(f"registered detectors: {api.available_detectors()}")

    for kind in ("dedup-only", "finesse", "n-transform", "card"):
        cfg = api.DedupConfig.from_dict({
            "detector": kind,
            "detector_args": {"use_kernel": False} if kind == "card" else {},
            "chunker_args": {"avg_size": 8192},
        })
        store = api.build_store(cfg)
        store.fit(versions[:1])
        handles = []
        for v in versions:
            with store.open_stream() as session:
                session.write(v)
            handles.append(session.report.handle)
        s = store.stats
        print(f"{store.detector.name:12s} DCR={s.dcr:5.2f}  dup={s.dup_chunks:4d} "
              f"delta={s.delta_chunks:4d} raw={s.raw_chunks:4d} "
              f"detect={s.detect_seconds:5.2f}s")
        assert store.restore(handles[1]) == versions[1], "restore must be byte-exact"
    print("restore verified byte-exact for every detector")


if __name__ == "__main__":
    main()
