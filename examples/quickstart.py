"""Quickstart: dedup + delta-compress a 3-version backup stream with CARD,
compare against Finesse / N-transform, verify byte-exact restore.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CARDDetector, DedupStore, NullDetector,
                        ChunkerConfig, finesse_detector, ntransform_detector)
from repro.data import make_workload, WorkloadConfig


def main():
    versions = make_workload("sql_dump", WorkloadConfig(base_size=2 << 20, versions=3))
    print(f"workload: {len(versions)} versions x {len(versions[0]) >> 20} MiB")

    ccfg = ChunkerConfig(avg_size=8192)
    for mk in (NullDetector, finesse_detector, ntransform_detector, CARDDetector):
        det = mk() if mk is not CARDDetector else CARDDetector(use_kernel=False)
        store = DedupStore(det, ccfg)
        store.fit(versions[:1])
        for v in versions:
            store.ingest(v)
        s = store.stats
        print(f"{det.name:12s} DCR={s.dcr:5.2f}  dup={s.dup_chunks:4d} "
              f"delta={s.delta_chunks:4d} raw={s.raw_chunks:4d} "
              f"detect={s.detect_seconds:5.2f}s")
        assert store.restore(1) == versions[1], "restore must be byte-exact"
    print("restore verified byte-exact for every detector")


if __name__ == "__main__":
    main()
