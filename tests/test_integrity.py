"""End-to-end integrity (DESIGN.md §13): the crc32c checksum itself,
verified reads raising typed CorruptChunkError, scrub/repair with
durable quarantine, pre-checksum format compatibility (RCL1 logs,
6-element journal rows), mid-file journal corruption vs torn tails,
blast radius, and the scrub CLI."""
import json
import os
import struct

import numpy as np
import pytest

from repro import api
from repro.api import integrity
from repro.api import objectstore as osmod
from repro.api.containers import (_LOG_HEADER, _LOG_MAGIC, _REC_HEADER,
                                  _REC_HEADER2, FileBackend,
                                  InMemoryBackend)
from repro.api.faults import flip_bit, flip_byte, truncate_tail


# --- fixtures ----------------------------------------------------------------

def _data(size=150_000, seed=0):
    rng = np.random.default_rng(seed)
    return bytes(rng.integers(0, 256, size, np.uint8))


def _store(tmp_path, backend, name="s", **knobs):
    cfg = api.DedupConfig.from_dict({
        "detector": "card", "backend": backend,
        "backend_args": {"path": str(tmp_path / name)}, **knobs})
    return api.build_store(cfg)


def _ingest(store, data):
    with store.open_stream() as s:
        s.write(data)
    return s.report.handle


def _cold(store):
    store.backend._cache.retain(lambda cid: False)


def _payload_files(tmp_path, backend, name="s"):
    """Every file holding chunk payloads for the given backend kind."""
    root = tmp_path / name
    if backend == "file":
        return [root / "chunks.log"]
    return sorted(root.glob("e*/chunks/*"))


BACKENDS = ["file", "objectstore"]


# --- the checksum ------------------------------------------------------------

def test_crc32c_rfc_vector():
    # RFC 3720 §B.4 test vector for CRC-32C (Castagnoli)
    assert integrity.crc32c(b"123456789") == 0xE3069283
    assert integrity._crc32c_py(b"123456789") == 0xE3069283


def test_crc32c_pure_python_matches_dispatch():
    rng = np.random.default_rng(7)
    for size in (0, 1, 63, 4096):
        blob = bytes(rng.integers(0, 256, size, np.uint8))
        assert integrity.crc32c(blob) == integrity._crc32c_py(blob)


def test_crc32c_accepts_buffer_types():
    blob = b"abcdefgh" * 16
    assert (integrity.crc32c(memoryview(blob))
            == integrity.crc32c(bytearray(blob))
            == integrity.crc32c(blob))


# --- corruption injectors ----------------------------------------------------

def test_flip_bit_bounds(tmp_path):
    p = tmp_path / "f"
    p.write_bytes(b"\x00\x01")
    assert flip_bit(p, 0, bit=0) == 0x01
    assert flip_byte(p, 1) == 0xFE
    with pytest.raises(ValueError):
        flip_bit(p, 2)              # offset past EOF
    with pytest.raises(ValueError):
        flip_bit(p, 0, bit=8)


def test_truncate_tail(tmp_path):
    p = tmp_path / "f"
    p.write_bytes(b"x" * 10)
    assert truncate_tail(p, 4) == 6
    assert truncate_tail(p, 100) == 0


# --- scrub on a healthy store ------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_scrub_clean_and_fully_verified(tmp_path, backend):
    store = _store(tmp_path, backend)
    data = _data()
    store.fit([data])
    h = _ingest(store, data)
    rep = store.scrub()
    assert rep.clean
    assert rep.chunks > 0 and rep.verified == rep.chunks
    assert rep.unverifiable == 0 and rep.bytes_checked > 0
    assert rep.streams == 1 and not rep.repaired
    assert store.restore(h) == data
    store.close()


def test_scrub_memory_backend():
    store = api.build_store(api.DedupConfig.from_dict(
        {"detector": "card", "backend": "memory"}))
    assert isinstance(store.backend, InMemoryBackend)
    data = _data(60_000)
    store.fit([data])
    _ingest(store, data)
    rep = store.scrub()
    assert rep.clean and rep.verified == rep.chunks and rep.unverifiable == 0
    store.close()


# --- verified reads + scrub detection of injected bit rot --------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_bitflip_detected_and_repaired(tmp_path, backend):
    """The acceptance drill: flip payload bits, restore raises the typed
    error, scrub finds the damage, repair leaves a scrub-clean store
    that stays clean across reopen."""
    store = _store(tmp_path, backend, verify_reads=True)
    data = _data()
    store.fit([data])
    h = _ingest(store, data)
    store.backend.flush()

    target = _payload_files(tmp_path, backend)[0]
    flip_bit(target, os.path.getsize(target) // 2, bit=3)
    _cold(store)

    with pytest.raises(api.CorruptChunkError) as ei:
        store.restore(h)
    err = ei.value
    assert isinstance(err, IOError)     # documented supertype
    assert err.expected != err.actual and err.cid >= 0
    assert f"{err.cid}" in str(err)

    rep = store.scrub()
    assert not rep.clean and len(rep.corrupt) >= 1
    assert set(rep.corrupt) <= set(rep.lost)
    assert rep.streams_lost == (h,)
    for cid in rep.corrupt:
        assert rep.blast_radius[cid] == 1

    fix = store.scrub(repair=True)
    assert fix.repaired
    assert set(fix.quarantined) >= set(rep.lost)
    assert fix.retired_streams == (h,)
    assert store.scrub().clean
    with pytest.raises((KeyError, IndexError)):
        store.restore(h)
    store.close()

    # quarantine + retire are durable: a fresh process agrees
    store2 = _store(tmp_path, backend)
    assert store2.scrub().clean
    assert h not in store2.backend.live_handles()
    store2.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_scrub_detects_without_verify_reads(tmp_path, backend):
    """verify_reads is a read-path knob; scrub checksums regardless."""
    store = _store(tmp_path, backend)        # verify_reads defaults off
    data = _data(seed=3)
    store.fit([data])
    _ingest(store, data)
    store.backend.flush()
    target = _payload_files(tmp_path, backend)[0]
    flip_bit(target, os.path.getsize(target) // 2)
    _cold(store)
    rep = store.scrub()
    assert len(rep.corrupt) >= 1
    store.close()


def test_repair_spares_untouched_streams(tmp_path):
    """Two independent streams; corrupting one leaves the other
    restorable byte-identically after repair."""
    store = _store(tmp_path, "file", verify_reads=True)
    a, b = _data(seed=1), _data(seed=2)
    store.fit([a])
    ha = _ingest(store, a)
    hb = _ingest(store, b)
    store.backend.flush()
    # find a chunk only stream b references, flip its payload
    ra = set(store.backend.recipe(ha))
    only_b = [c for c in store.backend.recipe(hb) if c not in ra]
    assert only_b
    _, _, off, ln = store.backend._index[only_b[0]]
    log = tmp_path / "s" / "chunks.log"
    flip_bit(log, off + ln // 2)
    _cold(store)
    fix = store.scrub(repair=True)
    assert hb in fix.retired_streams and ha not in fix.retired_streams
    assert store.scrub().clean
    assert store.restore(ha) == a
    store.close()


def test_blast_radius_counts_sharing_streams(tmp_path):
    """Identical data ingested twice dedups onto the same chunks; one
    corrupt shared chunk takes out both streams — and says so."""
    store = _store(tmp_path, "file")
    data = _data(seed=5)
    store.fit([data])
    h1 = _ingest(store, data)
    h2 = _ingest(store, data)
    store.backend.flush()
    shared = [c for c in store.backend.recipe(h1)
              if c in set(store.backend.recipe(h2))]
    assert shared
    _, _, off, ln = store.backend._index[shared[0]]
    flip_bit(tmp_path / "s" / "chunks.log", off + ln // 2)
    _cold(store)
    rep = store.scrub()
    assert shared[0] in rep.corrupt
    assert rep.blast_radius[shared[0]] == 2
    assert set(rep.streams_lost) == {h1, h2}
    store.close()


def test_refcount_drift_is_structural(tmp_path):
    store = _store(tmp_path, "file")
    data = _data(60_000, seed=9)
    store.fit([data])
    _ingest(store, data)
    assert store.scrub().clean
    store._refs.live_bytes += 12345      # simulate unrecorded accounting
    rep = store.scrub()
    assert any("refcount drift" in s for s in rep.structural_errors)
    store.close()


# --- pre-checksum format compatibility ---------------------------------------

def _downgrade_log_to_v1(path):
    """Rewrite an RCL2 chunk log as RCL1 (strip per-record checksums),
    byte-exactly what a pre-§13 build would have written."""
    raw = path.read_bytes()
    magic, epoch = _LOG_HEADER.unpack_from(raw, 0)
    assert magic == b"RCL2"
    out = bytearray(_LOG_HEADER.pack(_LOG_MAGIC, epoch))
    pos = _LOG_HEADER.size
    while pos < len(raw):
        kind, cid, base, ln, _crc = _REC_HEADER2.unpack_from(raw, pos)
        pos += _REC_HEADER2.size
        out += _REC_HEADER.pack(kind, cid, base, ln)
        out += raw[pos:pos + ln]
        pos += ln
    path.write_bytes(bytes(out))


def test_v1_log_reads_and_scrubs_unverifiable(tmp_path):
    store = _store(tmp_path, "file", verify_reads=True)
    data = _data(seed=4)
    store.fit([data])
    h = _ingest(store, data)
    store.close()
    _downgrade_log_to_v1(tmp_path / "s" / "chunks.log")

    store2 = _store(tmp_path, "file", verify_reads=True)
    assert store2.backend.record_overhead == _REC_HEADER.size
    assert store2.restore(h) == data     # verify_reads skips crc-less records
    rep = store2.scrub()
    assert rep.clean                     # unprovable is not dirty
    assert rep.verified == 0 and rep.unverifiable == rep.chunks

    # appends stay v1 (one file never mixes record formats) ...
    store2.fit([data])                   # fresh process, untrained detector
    h2 = _ingest(store2, _data(40_000, seed=6))
    assert store2.backend.record_overhead == _REC_HEADER.size
    assert store2.scrub().unverifiable == store2.scrub().chunks
    # ... until compaction rewrites the log as RCL2 with fresh checksums
    store2.compact()
    assert store2.backend.record_overhead == _REC_HEADER2.size
    rep2 = store2.scrub()
    assert rep2.unverifiable == 0 and rep2.verified == rep2.chunks
    assert store2.restore(h) == data and store2.restore(h2) is not None
    store2.close()


def test_v1_journal_rows_unverifiable(tmp_path):
    """6-element journal rows (pre-checksum) replay fine and scrub as
    unverifiable."""
    store = _store(tmp_path, "objectstore")
    data = _data(seed=8)
    store.fit([data])
    h = _ingest(store, data)
    store.close()
    root = tmp_path / "s"
    for jp in sorted(root.glob("e*/journal/*.json")):
        entries = json.loads(jp.read_text())
        for e in entries:
            if "chunks" in e:
                e["chunks"] = [row[:6] for row in e["chunks"]]
        jp.write_text(json.dumps(entries))
    store2 = _store(tmp_path, "objectstore", verify_reads=True)
    assert store2.restore(h) == data
    rep = store2.scrub()
    assert rep.clean and rep.unverifiable == rep.chunks
    store2.close()


# --- journal damage: torn tail vs mid-file corruption ------------------------

def test_torn_journal_tail_still_truncated(tmp_path):
    store = _store(tmp_path, "file")
    data = _data(seed=10)
    store.fit([data])
    h = _ingest(store, data)
    store.close()
    recipes = tmp_path / "s" / "recipes.jsonl"
    with open(recipes, "ab") as f:
        f.write(b'{"recipe": [1, 2')        # crash mid-append
    store2 = _store(tmp_path, "file")
    assert store2.restore(h) == data
    assert store2.scrub().clean
    store2.close()


def test_midfile_journal_corruption_is_typed_error(tmp_path):
    store = _store(tmp_path, "file")
    data = _data(seed=11)
    store.fit([data])
    _ingest(store, data)
    _ingest(store, _data(40_000, seed=12))
    store.close()
    recipes = tmp_path / "s" / "recipes.jsonl"
    lines = recipes.read_bytes().splitlines(keepends=True)
    assert len(lines) >= 3
    lines[1] = b"@@not json@@\n"            # damage *before* the tail
    recipes.write_bytes(b"".join(lines))
    with pytest.raises(api.CorruptJournalError) as ei:
        FileBackend(tmp_path / "s")
    assert ei.value.line_no == 2
    assert str(recipes) in str(ei.value)


# --- the scrub CLI -----------------------------------------------------------

def test_cli_scrub_clean_then_dirty_then_repaired(tmp_path, capsys):
    src = tmp_path / "in.bin"
    src.write_bytes(_data(seed=13))
    url = f"obj://{tmp_path / 'o'}"
    assert osmod.main(["cp", str(src), url]) == 0
    assert osmod.main(["scrub", url]) == 0
    out = capsys.readouterr().out
    assert "clean" in out

    target = sorted((tmp_path / "o" / "objects").glob("e*/chunks/*"))[0]
    flip_bit(target, os.path.getsize(target) // 2)
    assert osmod.main(["scrub", url]) == 1
    assert "DIRTY" in capsys.readouterr().out

    assert osmod.main(["scrub", url, "--repair"]) == 0
    assert osmod.main(["scrub", url]) == 0


def test_cli_verify_reports_corrupt_chunk(tmp_path, capsys):
    src = tmp_path / "in.bin"
    src.write_bytes(_data(seed=14))
    url = f"obj://{tmp_path / 'o'}"
    assert osmod.main(["cp", str(src), url]) == 0
    assert osmod.main(["verify", url]) == 0
    capsys.readouterr()
    target = sorted((tmp_path / "o" / "objects").glob("e*/chunks/*"))[0]
    flip_bit(target, os.path.getsize(target) // 2)
    assert osmod.main(["verify", url]) == 1
    assert "FAIL" in capsys.readouterr().out


# --- config plumbing ---------------------------------------------------------

def test_config_rejects_bad_integrity_knobs():
    with pytest.raises(TypeError):
        api.DedupConfig.from_dict({"verify_reads": 1})
    with pytest.raises(ValueError):
        api.DedupConfig.from_dict({"retry_deadline": -1.0})
    cfg = api.DedupConfig.from_dict({"verify_reads": True,
                                     "retry_deadline": 2.5})
    assert cfg.verify_reads is True and cfg.retry_deadline == 2.5
    assert api.DedupConfig.from_dict(cfg.to_dict()) == cfg


def test_lazy_exports():
    assert api.CorruptChunkError is integrity.CorruptChunkError
    assert api.ScrubReport is integrity.ScrubReport
    from repro.api import faults
    assert api.SimulatedCrash is faults.SimulatedCrash
    assert api.RetryBudgetExceeded is faults.RetryBudgetExceeded
