"""Distribution machinery: sharding-rule resolution, spec sanitization,
HLO cost parsing, roofline arithmetic, and an 8-device sharded train step."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.distributed import hlo_cost, roofline, sharding as shd
from repro.launch.mesh import make_mesh


class TestRules:
    def test_kv_heads_never_force_unsharded_axis(self):
        for arch in ("chatglm3-6b", "granite-8b", "whisper-base"):
            cfg = get_config(arch)
            rules = shd.default_rules(cfg)
            if cfg.num_kv_heads % 16:
                assert rules.kv_heads is None

    def test_moe_mode_selection(self):
        assert shd.default_rules(get_config("qwen3-moe-30b-a3b")).moe_mode == "ep"
        # grok: 8 experts x 2 virtual shards = 16 -> EP
        assert shd.default_rules(get_config("grok-1-314b")).moe_mode == "ep"
        assert shd.default_rules(get_config("jamba-v0.1-52b")).moe_mode == "ep"

    def test_multipod_fsdp_spans_pod(self):
        r = shd.default_rules(get_config("grok-1-314b"), multi_pod=True, fsdp=True)
        assert r.p_d_model == ("pod", "data")


class TestSanitize:
    def _mesh(self):
        return make_mesh((1, 1), ("data", "model"))

    def test_drop_and_shift(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        # fake a 16-way model axis via a mesh dict stand-in
        class FakeMesh:
            shape = {"data": 16, "model": 16}
        shapes = {"wq": jax.ShapeDtypeStruct((5120, 40, 128), jax.numpy.bfloat16),
                  "embed": jax.ShapeDtypeStruct((51865, 512), jax.numpy.bfloat16)}
        specs = {"wq": P("data", "model", None), "embed": P("model", "data")}
        out = shd.sanitize_pspecs(shapes, specs, FakeMesh())
        # 40 heads % 16 != 0 -> axis shifts to head_dim (128 % 16 == 0)
        assert out["wq"] == P("data", None, "model")
        # vocab 51865 % 16 != 0, d_model already sharded -> drop
        assert out["embed"] == P(None, "data")

    def test_divisible_untouched(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}
        shapes = {"w": jax.ShapeDtypeStruct((4096, 14336), jax.numpy.bfloat16)}
        specs = {"w": P("data", "model")}
        out = shd.sanitize_pspecs(shapes, specs, FakeMesh())
        assert out["w"] == P("data", "model")


HLO_SNIPPET = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
      %p = (s32[], f32[8,128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
      %w = f32[128,128]{1,0} constant({...})
      %dot.1 = f32[8,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,128]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[16,16]<=[256]T(1,0), to_apply=%add_comp
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,128]) tuple(%ni, %ar)
    }

    %cond (p2: (s32[], f32[8,128])) -> pred[] {
      %p2 = (s32[], f32[8,128]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(12)
      ROOT %cmp = pred[] compare(%i2, %n), direction=LT
    }

    %add_comp (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (arg: f32[8,128]) -> (s32[], f32[8,128]) {
      %arg = f32[8,128]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,128]) tuple(%zero, %arg)
      ROOT %w1 = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body
    }
    """)


class TestHloCost:
    def test_trip_count_multiplies(self):
        agg = hlo_cost.aggregate(HLO_SNIPPET)
        # dot: 2 * 8*128 * 128 flops, x12 loop trips
        assert agg["flops"] == pytest.approx(2 * 8 * 128 * 128 * 12)
        # all-reduce: 8*128*4 bytes x factor 2 x 12 trips
        assert agg["coll_bytes"] == pytest.approx(8 * 128 * 4 * 2 * 12)
        # f32 collective -> TPU projection halves it
        assert agg["coll_bytes_tpu"] == pytest.approx(agg["coll_bytes"] / 2)

    def test_shape_parse_tuple_with_comment(self):
        line = "(s32[], bf16[2,4,8]{2,1,0}, /*index=5*/f32[3]{0})"
        elems, b = hlo_cost._shape_elems_bytes(line)
        assert b == 4 + 2 * 4 * 8 * 2 + 3 * 4


class TestRooflineMath:
    def test_model_flops_train_scales_6nd(self):
        cfg = get_config("granite-8b")
        shape = get_shape("train_4k")
        f = roofline.model_flops(cfg, shape)
        n, d = cfg.active_param_count(), shape.global_batch * shape.seq_len
        assert f >= 6 * n * d
        assert f < 6 * n * d * 1.5  # attention term is a modest addition

    def test_moe_active_vs_total(self):
        cfg = get_config("qwen3-moe-30b-a3b")
        assert cfg.active_param_count() < 0.25 * cfg.param_count()

    def test_decode_bytes_include_cache(self):
        cfg = get_config("granite-8b")
        b_dec = roofline.model_bytes(cfg, get_shape("decode_32k"))
        b_train = roofline.model_bytes(cfg, get_shape("train_4k"))
        assert b_dec > b_train  # KV cache read dominates weights

    def test_dominant_and_fraction(self):
        r = roofline.Roofline(
            flops=1e12, hbm_bytes=1e12, coll_bytes=1e10,
            coll_by_kind={}, model_flops_global=2.56e14,
            model_bytes_global=1e12, chips=256)
        assert r.dominant == "memory"
        assert 0 < r.roofline_fraction <= 1


@pytest.mark.subprocess_mesh
def test_sharded_train_step_8dev():
    """End-to-end: reduced qwen3 (MoE, shard_map EP path) trains on an
    8-device (2 data x 4 model) CPU mesh with the production sharding rules."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import optim
        from repro.configs import get_config
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models import make_model
        from repro.train import make_train_step
        from repro.train.step import init_state

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_config("qwen3-moe-30b-a3b").reduced()
        model = make_model(cfg)
        rules = shd.ShardingRules(batch=("data",), p_d_model=None,
                                  moe_mode="ep")
        tx = optim.adamw(1e-3)
        with mesh, shd.use_rules(rules, mesh):
            params = model.init(jax.random.PRNGKey(0))
            state = init_state(params, tx)
            step = jax.jit(make_train_step(model, tx, num_microbatches=2))
            toks = jax.random.randint(jax.random.PRNGKey(1), (4, 65), 0,
                                      cfg.vocab_size)
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            losses = []
            for i in range(4):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        assert np.isfinite(losses).all(), losses
        assert losses[-1] < losses[0], losses
        print("SHARDED_TRAIN_OK", losses[0], "->", losses[-1])
    """)
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=Path.cwd(), timeout=560)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "SHARDED_TRAIN_OK" in p.stdout
