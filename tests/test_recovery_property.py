"""Torn-tail recovery under arbitrary *joint* truncation (DESIGN.md
§10.6 / ROADMAP hardening item).

Commits are buffered, not fsync'd, so after a crash the OS may have
persisted any prefix of chunks.log and any *independent* prefix of
recipes.jsonl — including a recipe line whose chunks never reached the
log. The property: for every joint truncation point, reopen succeeds,
every stream still reported live restores byte-identically, streams
whose data was torn are retired (never served short/corrupt), and the
directory accepts and persists fresh appends.

The property runs as a deterministic seeded sweep (always, boundary
cuts included) and additionally under hypothesis when installed
(requirements-dev.txt), matching the repo's guarded-hypothesis idiom."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # dev-only dep; sweep still runs
    HAVE_HYPOTHESIS = False

from repro import api
from repro.core import delta


def _reference_container(tmp):
    """Small container with raw chunks, cross-stream delta chains, a
    retire tombstone, and per-recipe lengths; returns the two files'
    bytes plus {handle: stream bytes}."""
    rng = np.random.default_rng(0)
    backend = api.FileBackend(tmp)
    expected = {}
    prev = None
    cid = 0
    for _s in range(3):
        ids, lens, datas = [], [], []
        for j in range(4):
            if prev is not None and j < len(prev[0]) and rng.random() < 0.6:
                mix = bytearray(prev[1][j])
                mix[10:20] = rng.integers(0, 256, 10, np.uint8).tobytes()
                data = bytes(mix)
                backend.put_delta(cid, prev[0][j],
                                  delta.encode(data, prev[1][j]), data=data)
            else:
                data = rng.integers(0, 256, int(rng.integers(80, 400)),
                                    np.uint8).tobytes()
                backend.put_raw(cid, data)
            ids.append(cid)
            lens.append(len(data))
            datas.append(data)
            cid += 1
        expected[backend.add_recipe(ids, lens)] = b"".join(datas)
        prev = (ids, datas)
    backend.retire_recipe(1)            # a tombstone line in the journal
    backend.flush()
    backend.close()
    log = (tmp / "chunks.log").read_bytes()
    recipes = (tmp / "recipes.jsonl").read_bytes()
    return log, recipes, expected


@pytest.fixture(scope="module")
def reference(tmp_path_factory):
    log, recipes, expected = _reference_container(
        tmp_path_factory.mktemp("ref"))
    return {"log": log, "recipes": recipes, "expected": expected}


def _check_joint_cut(reference, tmp, cut_log: int, cut_rec: int) -> None:
    """The recovery property for one joint truncation point."""
    log, recipes, expected = (reference["log"], reference["recipes"],
                              reference["expected"])
    tmp.mkdir(parents=True, exist_ok=True)
    (tmp / "chunks.log").write_bytes(log[:cut_log])
    (tmp / "recipes.jsonl").write_bytes(recipes[:cut_rec])

    backend = api.FileBackend(tmp)      # must never raise
    live = backend.live_handles()
    # slots that exist but are not live were retired — by the original
    # tombstone or by torn-tail recovery; they must STAY retired below
    retired = [h for h in range(backend.num_streams()) if h not in live]
    for h in live:
        recipe = backend.recipe(h)
        # hardening invariant: a live recipe's chunks (and their whole
        # base chains) survived the log truncation
        for c in recipe:
            cur = c
            while cur >= 0:
                assert backend.contains(cur)
                cur = backend.base_of(cur)
        # and it serves the exact original bytes
        assert b"".join(backend.get_many(recipe)) == expected[h]
        lens = backend.recipe_lengths(h)
        if lens is not None:
            assert sum(lens) == len(expected[h])
    # a store opens on the recovered directory (refcount rebuild included)
    store = api.DedupStore(
        api.build_detector(api.DedupConfig.from_dict(
            {"detector": "dedup-only"})), backend=backend)
    for h in live:
        assert store.restore(h) == expected[h]
    # the recovered tail is a clean append boundary: new data commits,
    # survives a reopen, and never collides with surviving chunk ids
    fresh = b"fresh-after-recovery" * 4
    nh = store.ingest(fresh) and store.reports[-1].handle
    assert store.restore(nh) == fresh
    store.close()
    again = api.FileBackend(tmp)
    assert b"".join(again.get_many(again.recipe(nh))) == fresh
    # live handles stay live (the post-recovery ingest must not have
    # reused their cids or otherwise disturbed them) and serve the
    # original bytes
    for h in live:
        assert b"".join(again.get_many(again.recipe(h))) == expected[h]
    # retired handles stay retired: without a persisted retire tombstone
    # (and torn cids kept out of reissue), a recovery-retired recipe
    # whose cids were reused by the fresh ingest would resurrect here —
    # live again, serving the fresh stream's bytes under an old handle
    for h in retired:
        with pytest.raises(KeyError):
            again.recipe(h)
    again.close()


def test_joint_truncation_seeded_sweep(reference, tmp_path):
    log, recipes = reference["log"], reference["recipes"]
    rng = np.random.default_rng(42)
    cuts = {(len(log), len(recipes)), (0, 0),
            (len(log), 0), (0, len(recipes))}
    # boundary-biased pairs: record/line edges are where off-by-ones live
    edges_l = [0, 12, 13, 37, len(log) - 1, len(log)]
    edges_r = [0, 1, len(recipes) - 1, len(recipes)]
    for el in edges_l:
        for er in edges_r:
            cuts.add((min(max(el, 0), len(log)),
                      min(max(er, 0), len(recipes))))
    while len(cuts) < 70:
        cuts.add((int(rng.integers(0, len(log) + 1)),
                  int(rng.integers(0, len(recipes) + 1))))
    for i, (cl, cr) in enumerate(sorted(cuts)):
        _check_joint_cut(reference, tmp_path / f"cut{i}", cl, cr)


if HAVE_HYPOTHESIS:
    @given(data=st.data())
    @settings(max_examples=40, deadline=None)
    def test_joint_truncation_property(reference, tmp_path_factory, data):
        cut_log = data.draw(
            st.integers(0, len(reference["log"])), label="cut_log")
        cut_rec = data.draw(
            st.integers(0, len(reference["recipes"])), label="cut_recipes")
        _check_joint_cut(reference, tmp_path_factory.mktemp("hyp"),
                         cut_log, cut_rec)


def test_recipe_surviving_torn_chunks_is_retired(tmp_path):
    """Directed version of the hardening: recipes.jsonl fully intact,
    chunks.log torn before the last stream's records — that stream's
    recipe must be retired on reopen, earlier streams must still serve."""
    log, recipes, expected = _reference_container(tmp_path / "ref")
    tmp = tmp_path / "cut"
    tmp.mkdir()
    # keep exactly stream 0's records (cids 0..3) by scanning a fresh
    # backend for their end offsets
    probe = api.FileBackend(tmp_path / "ref")
    ends = {cid: probe._index[cid][2] + probe._index[cid][3]
            for cid in probe.chunk_ids()}
    probe.close()
    keep_through = max(ends[c] for c in range(4))
    (tmp / "chunks.log").write_bytes(log[:keep_through])
    (tmp / "recipes.jsonl").write_bytes(recipes)
    backend = api.FileBackend(tmp)
    assert backend.live_handles() == [0]    # 1 was deleted, 2 torn away
    assert b"".join(backend.get_many(backend.recipe(0))) == expected[0]
    with pytest.raises(KeyError):
        backend.recipe(2)
    backend.close()


def test_joint_truncation_on_clean_boundaries_keeps_everything(tmp_path):
    log, recipes, expected = _reference_container(tmp_path / "ref")
    tmp = tmp_path / "cut"
    tmp.mkdir()
    (tmp / "chunks.log").write_bytes(log)
    (tmp / "recipes.jsonl").write_bytes(recipes)
    backend = api.FileBackend(tmp)
    assert sorted(backend.live_handles()) == [0, 2]     # 1 was retired
    for h in backend.live_handles():
        assert b"".join(backend.get_many(backend.recipe(h))) == expected[h]
    backend.close()
