"""Space reclamation subsystem (DESIGN.md §7): stream deletion with
delta-chain refcounting, mark-sweep collect, container compaction with
rebase, reclamation policies, and FileBackend epoch/reopen behaviour."""
import os

import numpy as np
import pytest

from repro import api

CHUNK = 4096
N_CHUNKS = 8


# --- deterministic fixtures --------------------------------------------------

class FixedChunker:
    """Fixed-size chunking — keeps chunk boundaries position-stable so the
    ChainDetector below can build delta chains of exactly known depth."""

    def __init__(self, size=CHUNK):
        self.size = size

    def chunk(self, stream):
        from repro.core import chunking, hashing
        hashes = hashing.gear_hashes_np(np.frombuffer(stream, np.uint8))
        chunks = [chunking.Chunk(off, len(stream[off:off + self.size]),
                                 stream[off:off + self.size])
                  for off in range(0, len(stream), self.size)]
        return chunks, hashes


class ChainDetector:
    """Deltas every chunk against the same-position chunk of the previous
    stream — stream k's chunks sit at delta-chain depth exactly k."""

    name = "chain"

    def __init__(self):
        self._prev = None

    def fit(self, training_streams, cfg):
        pass

    def detect(self, chunks, ids, is_new, stream_hashes):
        ids = np.asarray(ids, np.int64)
        out = np.full(len(chunks), -1, np.int64)
        if self._prev is not None:
            k = min(len(self._prev), len(chunks))
            out[:k] = self._prev[:k]
        out[~np.asarray(is_new, bool)] = -1
        out[out == ids] = -1
        self._prev = ids.copy()
        return out


def _rand(nbytes, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, nbytes, dtype=np.uint8).tobytes()


def _edit(data, seed, nedits=6, span=40):
    """A lightly edited copy (same length, so fixed chunks stay aligned)."""
    rng = np.random.default_rng(seed)
    buf = bytearray(data)
    for _ in range(nedits):
        p = int(rng.integers(0, len(buf) - span))
        buf[p:p + span] = _rand(span, int(rng.integers(1 << 30)))
    return bytes(buf)


def _chain_versions(generations=3, seed=7):
    """v0 random; every later generation edits *every* chunk of the one
    before, so with ChainDetector each generation is all-delta."""
    versions = [_rand(N_CHUNKS * CHUNK, seed)]
    rng = np.random.default_rng(seed + 1)
    for _ in range(generations - 1):
        buf = bytearray(versions[-1])
        for c in range(N_CHUNKS):
            p = c * CHUNK + int(rng.integers(0, CHUNK - 16))
            buf[p:p + 16] = _rand(16, int(rng.integers(1 << 30)))
        versions.append(bytes(buf))
    return versions


def _chain_store(backend=None):
    return api.DedupStore(ChainDetector(), FixedChunker(), backend=backend)


def _ingest(store, data):
    session = store.open_stream()
    session.write(data)
    return session.commit().handle


def _disk_bytes(path):
    return sum(os.stat(path / f).st_size
               for f in ("chunks.log", "recipes.jsonl"))


# --- ISSUE acceptance: end-to-end reclamation on a FileBackend ---------------

def test_end_to_end_reclamation_property(tmp_path):
    """Ingest 3 overlapping streams, delete the one whose chunks serve as
    delta bases for the survivors, collect + compact on a FileBackend:
    survivors restore byte-identical, the on-disk container strictly
    shrinks, and StoreStats.reclaimed_bytes matches the measured delta."""
    shared = _rand(12 * CHUNK, seed=1)
    s1 = shared + _rand(20 * CHUNK, seed=2)     # unique tail dies with s1
    s2 = _edit(shared, seed=3)                  # edit chunks delta-base on s1
    s3 = _edit(shared, seed=4)
    cfg = api.DedupConfig.from_dict(
        {"detector": "finesse", "chunker_args": {"avg_size": CHUNK},
         "backend": "file", "backend_args": {"path": str(tmp_path)}})
    store = api.build_store(cfg)
    store.fit([s1])
    h1, h2, h3 = (_ingest(store, s) for s in (s1, s2, s3))
    assert store.stats.delta_chunks > 0

    store.delete(h1)
    report = store.collect()
    assert report.pinned_chunks > 0         # s1 chunks held only as bases
    assert store.stats.dead_bytes == report.reclaimable_bytes > 0

    store.backend.flush()
    before = _disk_bytes(tmp_path)
    run = store.compact()
    after = _disk_bytes(tmp_path)

    assert run.rebased_delta + run.rebased_raw > 0   # bases actually died
    assert after < before                            # strictly shrinks
    assert run.reclaimed_bytes == before - after
    assert store.stats.reclaimed_bytes == before - after
    assert store.restore(h2) == s2
    assert store.restore(h3) == s3
    with pytest.raises(KeyError):
        store.restore(h1)
    assert store.stats.dead_bytes == 0
    store.close()


# --- refcount invariants -----------------------------------------------------

def test_pinned_base_survives_until_dependent_dies():
    store = _chain_store()
    v0, v1 = _chain_versions(2)
    h0 = _ingest(store, v0)
    h1 = _ingest(store, v1)
    refs = store._refs
    assert all(refs.is_live(c) for c in refs.chunk_ids())

    store.delete(h0)            # v1 patches decode against v0's chunks
    assert len(refs.pinned_cids()) == N_CHUNKS
    assert not refs.dead_cids()                 # nothing is reclaim-unsafe
    assert store.restore(h1) == v1

    store.delete(h1)            # last dependent gone -> whole chain dead
    assert not refs.pinned_cids()
    assert len(refs.dead_cids()) == 2 * N_CHUNKS


def test_dedup_against_dead_chunk_revives_its_chain():
    store = _chain_store()
    v0, v1 = _chain_versions(2)
    _ingest(store, v0)
    h1 = _ingest(store, v1)
    store.delete(h1)
    dead = store.stats.dead_bytes
    assert dead > 0
    h1b = _ingest(store, v1)    # same content -> dedups against dead chunks
    assert store.reports[-1].dup_chunks == N_CHUNKS
    assert store.stats.dead_bytes == 0          # revived, chain and all
    store.compact()
    assert store.restore(h1b) == v1


def test_refcount_underflow_and_double_track_raise():
    t = api.RefcountTable()
    t.track(1, -1, 100)
    with pytest.raises(ValueError, match="already tracked"):
        t.track(1, -1, 100)
    t.incref_recipe(1)
    t.decref_recipe(1)
    with pytest.raises(ValueError, match="underflow"):
        t.decref_recipe(1)


def test_delete_semantics():
    store = _chain_store()
    v0, v1 = _chain_versions(2)
    h0 = _ingest(store, v0)
    _ingest(store, v1)
    store.delete(h0)
    with pytest.raises(KeyError):
        store.restore(h0)
    with pytest.raises(KeyError):
        store.delete(h0)                        # double delete
    with pytest.raises(IndexError):
        store.delete(99)                        # never issued
    with pytest.raises(IndexError):
        store.delete(-1)                        # must not alias the newest
    assert store.restore(1) == v1


def test_chain_depth_histogram_and_rebase_to_live_ancestor():
    """Deleting the middle generation of a depth-2 chain rebases the
    grandchild patches onto the surviving grandparent."""
    store = _chain_store()
    v0, v1, v2 = _chain_versions(3)
    h0 = _ingest(store, v0)
    h1 = _ingest(store, v1)
    h2 = _ingest(store, v2)
    assert store.collect().chain_depth_hist == {0: N_CHUNKS, 1: N_CHUNKS,
                                                2: N_CHUNKS}
    store.delete(h1)
    run = store.compact()
    assert run.swept_chunks == N_CHUNKS
    assert run.rebased_delta == N_CHUNKS        # re-encoded, not raw'd
    assert store.restore(h0) == v0
    assert store.restore(h2) == v2
    assert store.collect().chain_depth_hist == {0: N_CHUNKS, 1: N_CHUNKS}
    assert store.stats.chain_depth_hist == {0: N_CHUNKS, 1: N_CHUNKS}


def test_rebase_skips_multiple_dead_hops_and_onto_rebased_ancestor():
    """v0<-v1<-v2<-v3: deleting v1+v2 rebases v3 across two dead hops onto
    v0; deleting v0+v2 makes v3 rebase onto v1 while v1 itself is being
    rebased to raw in the same run (patches decode against materialized
    bytes, so both are sound)."""
    store = _chain_store()
    versions = _chain_versions(4)
    handles = [_ingest(store, v) for v in versions]
    store.delete(handles[1])
    store.delete(handles[2])
    run = store.compact()
    assert run.swept_chunks == 2 * N_CHUNKS
    assert run.rebased_delta == N_CHUNKS
    assert store.restore(handles[0]) == versions[0]
    assert store.restore(handles[3]) == versions[3]

    store = _chain_store()
    versions = _chain_versions(4)
    handles = [_ingest(store, v) for v in versions]
    store.delete(handles[0])
    store.delete(handles[2])
    run = store.compact()
    assert run.rebased_raw == N_CHUNKS          # v1: no surviving ancestor
    assert run.rebased_delta == N_CHUNKS        # v3: onto freshly-raw'd v1
    assert store.restore(handles[1]) == versions[1]
    assert store.restore(handles[3]) == versions[3]
    assert store.collect().chain_depth_hist == {0: N_CHUNKS, 1: N_CHUNKS}


def test_memory_and_file_backends_agree_on_lifecycle(tmp_path):
    stores = [_chain_store(),
              _chain_store(backend=api.FileBackend(tmp_path))]
    versions = _chain_versions(3)
    outcomes = []
    for store in stores:
        handles = [_ingest(store, v) for v in versions]
        store.delete(handles[0])
        rep = store.collect()
        run = store.compact()
        outcomes.append((rep.live_chunks, rep.pinned_chunks, rep.dead_chunks,
                         run.swept_chunks, run.rebased_delta, run.rebased_raw,
                         [store.restore(h) for h in handles[1:]]))
    assert outcomes[0] == outcomes[1]


# --- policies ----------------------------------------------------------------

def test_policy_registry_and_config_round_trip():
    assert {"eager", "threshold", "never"} <= set(api.available_policies())
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "policy": "threshold",
         "policy_args": {"ratio": 0.5}})
    assert api.DedupConfig.from_dict(cfg.to_dict()) == cfg
    assert isinstance(api.build_policy(cfg), api.ThresholdPolicy)
    with pytest.raises(KeyError, match="available"):
        api.build_policy(api.DedupConfig.from_dict({"policy": "no-such"}))
    with pytest.raises(ValueError, match="ratio"):
        api.ThresholdPolicy(ratio=0.0)


@pytest.mark.parametrize("policy,policy_args,compacts", [
    ("eager", {}, True),
    ("never", {}, False),
    ("threshold", {"ratio": 0.3}, True),    # delete frees ~half the store
    ("threshold", {"ratio": 0.9}, False),
])
def test_policy_governs_auto_compaction(policy, policy_args, compacts):
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "chunker_args": {"avg_size": CHUNK},
         "policy": policy, "policy_args": policy_args})
    store = api.build_store(cfg)
    h0 = _ingest(store, _rand(16 * CHUNK, seed=11))
    _ingest(store, _rand(16 * CHUNK, seed=12))      # disjoint content
    store.delete(h0)
    if compacts:
        assert store.backend.epoch == 1
        assert store.stats.reclaimed_bytes > 0
        assert store.stats.dead_bytes == 0
    else:
        assert store.backend.epoch == 0
        assert store.stats.reclaimed_bytes == 0
        assert store.stats.dead_bytes > 0


# --- FileBackend: compaction epoch, reopen, torn tails (ISSUE satellites) ----

def test_file_backend_reopen_after_compaction(tmp_path):
    backend = api.FileBackend(tmp_path)
    store = _chain_store(backend=backend)
    v0, v1, v2 = _chain_versions(3)
    h0 = _ingest(store, v0)
    h1 = _ingest(store, v1)
    h2 = _ingest(store, v2)
    store.delete(h1)
    store.compact()
    assert backend.epoch == 1
    store.close()

    reopened = api.FileBackend(tmp_path)            # fresh scan of the dir
    assert reopened.epoch == 1
    assert reopened.num_streams() == 3              # handle slots stable
    assert reopened.live_handles() == [h0, h2]
    store2 = _chain_store(backend=reopened)         # refcounts rebuilt
    assert store2.stats.dead_bytes == 0
    assert store2.restore(h0) == v0
    assert store2.restore(h2) == v2
    with pytest.raises(KeyError):
        store2.restore(h1)
    h3 = _ingest(store2, _rand(N_CHUNKS * CHUNK, seed=42))
    assert store2.restore(h3) != v0
    store2.delete(h0)                               # delete a pre-reopen stream
    store2.compact()
    assert reopened.epoch == 2
    assert store2.restore(h2) == v2
    store2.close()


def test_compacted_log_survives_torn_tail(tmp_path):
    """Regression: torn-tail truncation must still work on a log that has
    been compacted (header present, records rewritten)."""
    backend = api.FileBackend(tmp_path)
    store = _chain_store(backend=backend)
    v0, v1, v2 = _chain_versions(3)
    h0 = _ingest(store, v0)
    h1 = _ingest(store, v1)
    store.delete(h1)
    store.compact()
    h2 = _ingest(store, v2)                         # appended post-compaction
    store.close()

    log = tmp_path / "chunks.log"
    recipes = tmp_path / "recipes.jsonl"
    log.write_bytes(log.read_bytes()[:-11])         # torn payload
    recipes.write_bytes(recipes.read_bytes()[:-5])  # torn recipe line

    reopened = api.FileBackend(tmp_path)
    assert reopened.epoch == 1                      # header survived the tear
    assert reopened.live_handles() == [h0]          # torn h2 dropped
    store2 = _chain_store(backend=reopened)
    assert store2.restore(h0) == v0
    h2b = _ingest(store2, v2)                       # appends still work...
    assert store2.restore(h2b) == v2
    store2.close()
    third = api.FileBackend(tmp_path)               # ...and re-scan cleanly
    assert third.epoch == 1
    assert b"".join(third.get(c) for c in third.recipe(h2b)) == v2
    third.close()


def test_delete_tombstone_is_durable_without_close(tmp_path):
    """The retire tombstone must hit disk when delete() returns — a crash
    right after a delete must not resurrect the stream on reopen."""
    backend = api.FileBackend(tmp_path)
    store = _chain_store(backend=backend)
    v0, v1 = _chain_versions(2)
    h0 = _ingest(store, v0)
    h1 = _ingest(store, v1)
    store.delete(h0)
    # no close()/flush(): a second scan of the directory simulates the
    # post-crash reopen
    crashed = api.FileBackend(tmp_path)
    assert crashed.live_handles() == [h1]
    crashed.close()
    store.close()


def test_interrupted_compaction_rename_is_recoverable(tmp_path):
    """A crash between the two compaction renames leaves the epochs one
    apart; reopen must still serve every live stream (the old log is a
    record superset of the compacted one)."""
    backend = api.FileBackend(tmp_path)
    store = _chain_store(backend=backend)
    v0, v1 = _chain_versions(2)
    h0 = _ingest(store, v0)
    h1 = _ingest(store, v1)
    old_log = (tmp_path / "chunks.log").read_bytes()
    store.delete(h0)
    store.compact()
    store.close()
    # simulate the crash: recipes renamed (epoch 1), log still pre-compaction
    (tmp_path / "chunks.log").write_bytes(old_log)

    reopened = api.FileBackend(tmp_path)
    assert reopened.epoch == 1                      # adopts the larger epoch
    store2 = _chain_store(backend=reopened)
    assert store2.restore(h1) == v1
    assert store2.stats.dead_bytes > 0              # old records resurfaced...
    store2.compact()                                # ...and compact again
    assert reopened.epoch == 2
    assert store2.restore(h1) == v1
    store2.close()


def test_failed_log_rename_leaves_backend_usable(tmp_path, monkeypatch):
    """If the chunks.log rename fails after the recipes rename succeeded,
    the backend must keep serving (new recipes + old log is consistent)
    and later commits must reach the on-disk recipes file."""
    backend = api.FileBackend(tmp_path)
    store = _chain_store(backend=backend)
    v0, v1 = _chain_versions(2)
    h0 = _ingest(store, v0)
    h1 = _ingest(store, v1)
    store.delete(h0)

    real_replace = os.replace

    def flaky(src, dst):
        if str(dst).endswith("chunks.log"):
            raise OSError(28, "No space left on device")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", flaky)
    with pytest.raises(OSError, match="No space"):
        store.compact()
    monkeypatch.undo()

    assert store.restore(h1) == v1              # still serving reads
    h2 = _ingest(store, v1)                     # and taking commits
    store.close()
    reopened = api.FileBackend(tmp_path)        # epoch-mismatch reopen
    assert sorted(reopened.live_handles()) == [h1, h2]
    store2 = _chain_store(backend=reopened)
    store2.compact()                            # next compaction succeeds
    assert store2.restore(h1) == v1
    assert store2.restore(h2) == v1
    store2.close()


class StarDetector:
    """Deltas every later stream's chunks against the same-position chunk
    of the FIRST stream — deleting that first stream then pins its whole
    payload while every survivor needs a rebase, the shape that used to
    drive reclaimed_bytes negative (BENCH_GC finesse regression)."""

    name = "star"

    def __init__(self):
        self._first = None

    def fit(self, training_streams, cfg):
        pass

    def detect(self, chunks, ids, is_new, stream_hashes):
        ids = np.asarray(ids, np.int64)
        out = np.full(len(chunks), -1, np.int64)
        if self._first is None:
            self._first = ids.copy()
            return out
        k = min(len(self._first), len(chunks))
        out[:k] = self._first[:k]
        out[~np.asarray(is_new, bool)] = -1
        out[out == ids] = -1
        return out


def test_compaction_skips_rewrite_that_would_grow_container(tmp_path):
    """Rebasing many small patches to raw can cost more bytes than the
    sweep reclaims; the sizing pass must skip the rewrite and report
    reclaimed_bytes == 0 — never negative."""
    backend = api.FileBackend(tmp_path)
    store = api.DedupStore(StarDetector(), FixedChunker(), backend=backend)
    v0 = _rand(N_CHUNKS * CHUNK, seed=11)
    later = []
    for i in range(4):                  # touch EVERY chunk, tiny patches
        buf = bytearray(v0)
        for c in range(N_CHUNKS):
            p = c * CHUNK + (i * 97) % (CHUNK - 16)
            buf[p:p + 16] = _rand(16, seed=100 + 10 * i + c)
        later.append(bytes(buf))
    h0 = _ingest(store, v0)
    handles = [_ingest(store, v) for v in later]
    assert store.stats.delta_chunks == 4 * N_CHUNKS   # star topology held

    store.delete(h0)
    size_before = backend.storage_bytes()
    epoch_before = backend.epoch
    run = store.compact()
    assert run.skipped
    assert run.reclaimed_bytes == 0                   # pinned: never < 0
    assert run.swept_chunks == 0
    assert backend.storage_bytes() == size_before     # nothing mutated
    assert backend.epoch == epoch_before
    for h, v in zip(handles, later):
        assert store.restore(h) == v

    # once enough of the star is gone the rewrite pays and runs for real
    for h in handles[:3]:
        store.delete(h)
    run2 = store.compact()
    assert not run2.skipped
    assert run2.reclaimed_bytes >= 0                  # the regression pin
    assert store.restore(handles[3]) == later[3]
    store.close()


# The any-interleaving restore/refcount property lives in
# tests/test_lifecycle_property.py (hypothesis-gated, repo convention).
