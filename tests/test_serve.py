"""Multi-tenant serving front end (DESIGN.md §15): RWLock timeouts, the
shared retry helper, request deadlines, admission control / typed
shedding, quota accounting (the hypothesis property lives in
test_serve_property.py), namespace
isolation, per-tenant caches, and the circuit breaker's
open → half-open → closed cycle with its metric families."""
import random
import threading
import time

import pytest

from repro import api
from repro.api.concurrency import (DeadlineExceededError, LockTimeout, RWLock,
                                   check_deadline, deadline_scope,
                                   remaining_time)
from repro.api.faults import RetryBudgetExceeded, TransientError, with_retries
from repro.api.serve import (CircuitBreaker, CircuitOpenError, DedupServer,
                             OverloadError, QuotaExceededError, TenantConfig)

JOIN_S = 10.0


# --- RWLock timeouts ----------------------------------------------------------

def test_rwlock_read_timeout_under_writer():
    lock = RWLock()
    lock.acquire_write()
    t0 = time.perf_counter()
    with pytest.raises(LockTimeout) as ei:
        lock.acquire_read(timeout=0.05)
    assert 0.04 <= time.perf_counter() - t0 < JOIN_S
    assert ei.value.side == "read"
    lock.release_write()
    with lock.read(timeout=1.0):        # lock usable afterwards
        pass


def test_rwlock_write_timeout_under_reader():
    lock = RWLock()
    lock.acquire_read()
    with pytest.raises(LockTimeout) as ei:
        lock.acquire_write(timeout=0.05)
    assert ei.value.side == "write"
    lock.release_read()
    with lock.write(timeout=1.0):
        pass


def test_rwlock_writer_timeout_unblocks_waiting_readers():
    # writer preference holds readers off while a writer waits; when the
    # writer *times out* it must wake them, or they hang forever on a
    # wait() nobody will ever notify
    lock = RWLock()
    lock.acquire_read()
    timed_out = threading.Event()

    def writer():
        try:
            lock.acquire_write(timeout=0.15)
        except LockTimeout:
            timed_out.set()

    w = threading.Thread(target=writer, daemon=True)
    w.start()
    time.sleep(0.03)                    # writer is now waiting
    got = threading.Event()

    def reader():
        lock.acquire_read()             # parked behind the waiting writer
        got.set()
        lock.release_read()

    r = threading.Thread(target=reader, daemon=True)
    r.start()
    time.sleep(0.03)
    assert not got.is_set()             # preference: reader held off
    assert timed_out.wait(JOIN_S)
    assert got.wait(JOIN_S)             # woken by the timed-out writer
    w.join(JOIN_S)
    r.join(JOIN_S)
    lock.release_read()


def test_rwlock_timeout_reports_wait_to_observer():
    waits = []
    lock = RWLock(observer=lambda side, s: waits.append((side, s)))
    lock.acquire_write()
    with pytest.raises(LockTimeout):
        lock.acquire_read(timeout=0.02)
    assert [side for side, _ in waits] == ["write", "read"]
    assert waits[-1][1] >= 0.02         # the failed wait is the signal


# --- faults.with_retries ------------------------------------------------------

def test_with_retries_absorbs_faults_then_succeeds():
    calls, sleeps, attempts, backoffs = [], [], [], []

    def fn(tag):
        calls.append(tag)
        if len(calls) < 3:
            raise TransientError(503, "flaky")
        return f"ok:{tag}"

    out = with_retries(fn, ("x",), max_retries=5, backoff=0.01,
                       rng=random.Random(7), sleep=sleeps.append,
                       on_attempt=lambda s, ok: attempts.append(ok),
                       on_backoff=lambda d, a: backoffs.append(a))
    assert out == "ok:x" and calls == ["x", "x", "x"]
    assert attempts == [False, False, True]
    assert backoffs == [1, 2]
    # decorrelated jitter bounds: uniform(backoff, min(cap, 3*prev))
    assert len(sleeps) == 2
    assert all(0.01 <= d <= 0.01 * (1 << 5) for d in sleeps)


def test_with_retries_attempt_budget_reraises_last():
    def fn():
        raise TransientError(429, "always")

    with pytest.raises(TransientError) as ei:
        with_retries(fn, max_retries=2, backoff=0.001,
                     sleep=lambda d: None)
    assert not isinstance(ei.value, RetryBudgetExceeded)
    assert ei.value.status == 429


def test_with_retries_deadline_budget():
    def fn():
        raise TransientError()

    with pytest.raises(RetryBudgetExceeded) as ei:
        with_retries(fn, max_retries=100, backoff=0.01, deadline=0.05,
                     sleep=lambda d: None)
    assert ei.value.attempts >= 1
    assert ei.value.slept <= 0.05


def test_with_retries_non_transient_propagates_immediately():
    calls = []

    def fn():
        calls.append(1)
        raise ValueError("not retryable")

    with pytest.raises(ValueError):
        with_retries(fn, sleep=lambda d: None)
    assert len(calls) == 1


# --- deadline scopes ----------------------------------------------------------

def test_deadline_scope_nested_keeps_tighter_budget():
    assert remaining_time() is None
    with deadline_scope(30.0):
        with deadline_scope(0.01):
            assert remaining_time() <= 0.01
        assert remaining_time() > 1.0   # outer budget restored
        with deadline_scope(60.0):      # looser inner scope: ignored
            assert remaining_time() <= 30.0
    assert remaining_time() is None


def test_check_deadline_raises_once_expired():
    with deadline_scope(0.0):
        with pytest.raises(DeadlineExceededError) as ei:
            check_deadline("restore")
    assert ei.value.op == "restore"
    check_deadline("unbounded")         # no scope: never raises


def test_deadline_scope_is_thread_local():
    seen = []

    def other():
        seen.append(remaining_time())

    with deadline_scope(0.001):
        th = threading.Thread(target=other)
        th.start()
        th.join(JOIN_S)
    assert seen == [None]


# --- server fixtures ----------------------------------------------------------

def _obj_server(tmp_path, *, latency=0.0, fault_hook=None, max_retries=2,
                retry_deadline=None, tenant=None, workers=4,
                max_object_bytes=None, breaker=None, avg_chunk=None):
    backend_args = {"path": str(tmp_path / "obj"), "latency": latency,
                    "fault_hook": fault_hook, "max_retries": max_retries,
                    "cache_bytes": 1}     # ~no decode cache: reads hit I/O
    if retry_deadline is not None:
        backend_args["retry_deadline"] = retry_deadline
    if max_object_bytes is not None:
        backend_args["max_object_bytes"] = max_object_bytes
    cfg = {"detector": "dedup-only", "backend": "objectstore",
           "backend_args": backend_args}
    if avg_chunk is not None:
        cfg["chunker_args"] = {"avg_size": avg_chunk}
    store = api.build_store(api.DedupConfig.from_dict(cfg))
    return DedupServer(store, workers=workers, breaker=breaker,
                       default_tenant=tenant or TenantConfig())


def _payload(n, seed=0):
    return random.Random(seed).randbytes(n)


# --- directed server behavior -------------------------------------------------

def test_namespace_isolation_and_roundtrip(tmp_path):
    srv = _obj_server(tmp_path)
    try:
        data_a, data_b = b"alpha" * 4000, b"bravo" * 4000
        ra = srv.ingest("a", data_a)
        rb = srv.ingest("b", data_b)
        assert srv.restore("a", ra.handle) == data_a
        assert srv.restore_range("b", rb.handle, 10, 25) == data_b[10:35]
        with pytest.raises(KeyError):
            srv.restore("a", rb.handle)     # foreign handle == missing
        with pytest.raises(KeyError):
            srv.delete("b", ra.handle)
        assert srv.delete("a", ra.handle) >= 0
        with pytest.raises(KeyError):
            srv.restore("a", ra.handle)     # gone after delete
    finally:
        srv.close(close_store=True)


def test_quota_admission_and_settlement(tmp_path):
    srv = _obj_server(tmp_path,
                      tenant=TenantConfig(quota_bytes=64 << 10))
    try:
        rep = srv.ingest("t", b"q" * 4000)
        stats = srv.tenant_stats("t")
        # the charge settles to the store's actual, not the raw upper bound
        assert stats["bytes_stored"] == rep.bytes_stored <= 4000
        assert stats["reserved"] == 0
        # a duplicate stream dedupes: its settled charge is far below raw
        rep2 = srv.ingest("t", b"q" * 4000)
        assert rep2.bytes_stored < 4000
        assert (srv.tenant_stats("t")["bytes_stored"]
                == rep.bytes_stored + rep2.bytes_stored)
        with pytest.raises(QuotaExceededError):
            srv.ingest("t", _payload(80 << 10))
        assert srv.tenant_stats("t")["reserved"] == 0   # rejected: uncharged
        assert srv.tenant_stats("t")["shed"] == {"quota": 1}
        # freeing the streams returns their quota headroom
        srv.delete("t", rep.handle)
        srv.delete("t", rep2.handle)
        assert srv.tenant_stats("t")["bytes_stored"] == 0
    finally:
        srv.close(close_store=True)


def test_admission_sheds_overload_when_queue_full(tmp_path):
    gate = threading.Event()
    armed = threading.Event()

    def hook(op, key, n):
        if armed.is_set() and op == "get":
            gate.wait(JOIN_S)
        return None

    srv = _obj_server(tmp_path, fault_hook=hook,
                      tenant=TenantConfig(max_inflight=1, max_queue=1))
    try:
        data = _payload(30000, seed=3)
        rep = srv.ingest("t", data)
        armed.set()                     # every GET now parks on the gate
        f1 = srv.submit("t", "restore", rep.handle)
        f2 = srv.submit("t", "restore", rep.handle)
        with pytest.raises(OverloadError) as ei:    # queue (1+1) is full
            srv.submit("t", "restore", rep.handle)
        assert ei.value.pending == 2 and ei.value.limit == 2
        assert srv.tenant_stats("t")["shed"] == {"overload": 1}
        armed.clear()
        gate.set()                      # drain: admitted work completes
        assert f1.result(JOIN_S) == data
        assert f2.result(JOIN_S) == data
        snap = srv.store.metrics().to_prometheus()
        assert 'repro_tenant_shed_total{reason="overload",tenant="t"} 1' \
            in snap
    finally:
        gate.set()
        srv.close(close_store=True)


def test_deadline_expiry_mid_restore_is_typed_and_prompt(tmp_path):
    # per-GET latency makes the restore span many slow reads; the §15.3
    # probes must shed it mid-plan with the typed error, long before the
    # full restore would have finished — and never corrupt later serving
    srv = _obj_server(tmp_path, latency=0.03, max_object_bytes=8192,
                      avg_chunk=2048)
    try:
        data = _payload(256 << 10, seed=5)      # ~30 objects => many GETs
        rep = srv.ingest("t", data)
        t0 = time.perf_counter()
        with pytest.raises(DeadlineExceededError):
            srv.restore("t", rep.handle, timeout=0.06)
        assert time.perf_counter() - t0 < 2.0   # shed, not served late
        assert srv.tenant_stats("t")["shed"] == {"deadline": 1}
        assert srv.restore("t", rep.handle) == data     # store unharmed
    finally:
        srv.close(close_store=True)


def test_deadline_expiry_sheds_commit_before_writes(tmp_path):
    srv = _obj_server(tmp_path, latency=0.02)
    try:
        before = srv.store.stats.bytes_stored
        with pytest.raises(DeadlineExceededError):
            srv.ingest("t", _payload(256 << 10, seed=7), timeout=1e-4)
        assert srv.store.stats.bytes_stored == before   # nothing committed
        assert srv.tenant_stats("t")["bytes_stored"] == 0
        assert srv.tenant_stats("t")["reserved"] == 0
    finally:
        srv.close(close_store=True)


def test_store_restore_respects_ambient_deadline_scope(tmp_path):
    # the deadline machinery works below the server too: a bare store
    # call inside an expired scope sheds instead of running
    srv = _obj_server(tmp_path, latency=0.02, max_object_bytes=8192)
    try:
        rep = srv.ingest("t", _payload(96 << 10, seed=9))
        with deadline_scope(0.01):
            with pytest.raises(DeadlineExceededError):
                srv.store.restore(rep.handle)
    finally:
        srv.close(close_store=True)


def test_tenant_cache_serves_repeat_restores_without_backend_io(tmp_path):
    srv = _obj_server(tmp_path,
                      tenant=TenantConfig(cache_bytes=4 << 20))
    try:
        data = _payload(40000, seed=11)
        rep = srv.ingest("t", data)
        assert srv.restore("t", rep.handle) == data     # cold: hits backend
        gets = srv.store.backend.client.op_counts.get("get", 0)
        assert srv.restore("t", rep.handle) == data     # warm: tenant cache
        assert srv.store.backend.client.op_counts.get("get", 0) == gets
        stats = srv.tenant_stats("t")
        assert stats["cache_hits"] == 1 and stats["cache_misses"] == 1
        srv.delete("t", rep.handle)     # delete invalidates the cache entry
        assert srv.tenant_stats("t")["cache_hits"] == 1
        with pytest.raises(KeyError):
            srv.restore("t", rep.handle)
    finally:
        srv.close(close_store=True)


def test_breaker_opens_gates_writes_and_recovers(tmp_path):
    storm = threading.Event()

    def hook(op, key, n):
        if storm.is_set() and op == "get":
            return TransientError(503, f"storm {op} #{n}")
        return None

    breaker = CircuitBreaker(fail_threshold=2, window_seconds=5.0,
                             cooldown_seconds=0.05, probe_successes=1)
    srv = _obj_server(tmp_path, fault_hook=hook, max_retries=0,
                      breaker=breaker)
    try:
        data = b"stormy" * 3000
        rep = srv.ingest("t", data)
        storm.set()
        for _ in range(2):
            with pytest.raises(TransientError):
                srv.restore("t", rep.handle)
        assert breaker.state() == "open"
        with pytest.raises(CircuitOpenError):       # read-only degradation
            srv.ingest("t", b"rejected")
        with pytest.raises(CircuitOpenError):
            srv.delete("t", rep.handle)
        time.sleep(0.06)                # cooldown elapses lazily
        storm.clear()
        assert srv.restore("t", rep.handle) == data     # half-open probe
        assert breaker.state() == "closed"
        assert breaker.transitions == {"closed": 1, "half_open": 1,
                                       "open": 1}
        srv.ingest("t", b"writable again")          # write gate reopened
        snap = srv.store.metrics().to_prometheus()
        assert 'repro_server_breaker_transitions_total{to="open"} 1' in snap
        assert ('repro_server_breaker_transitions_total{to="half_open"} 1'
                in snap)
        assert 'repro_server_breaker_transitions_total{to="closed"} 1' in snap
        assert "repro_server_breaker_state 0" in snap
        assert srv.tenant_stats("t")["shed"]["circuit"] == 2
    finally:
        srv.close(close_store=True)


def test_breaker_halfopen_failure_reopens():
    t = [0.0]
    br = CircuitBreaker(fail_threshold=1, cooldown_seconds=10.0,
                        probe_successes=2, clock=lambda: t[0])
    br.record_failure()
    assert br.state() == "open"
    t[0] = 11.0
    assert br.state() == "half_open"
    br.record_failure()                 # failed probe: back to open
    assert br.state() == "open"
    t[0] = 22.0
    assert br.state() == "half_open"
    br.record_success()
    assert br.state() == "half_open"    # needs probe_successes=2
    br.record_success()
    assert br.state() == "closed"
    assert br.transitions["open"] == 2


def test_submit_rejects_unknown_op_and_closed_server(tmp_path):
    srv = _obj_server(tmp_path)
    with pytest.raises(ValueError):
        srv.submit("t", "scrub")
    srv.close(close_store=True)
    with pytest.raises(RuntimeError):
        srv.submit("t", "restore", 0)
    srv.close()                         # idempotent


def test_build_server_from_config(tmp_path):
    cfg = api.DedupConfig.from_dict({
        "detector": "dedup-only",
        "backend": "objectstore",
        "backend_args": {"path": str(tmp_path / "o")},
        "server_workers": 2,
        "tenant_args": {"quota_bytes": 1 << 20, "max_inflight": 3},
    })
    srv = api.build_server(cfg)
    try:
        assert isinstance(srv, DedupServer)
        rep = srv.ingest("t", b"configured" * 100)
        assert srv.restore("t", rep.handle) == b"configured" * 100
        assert srv.tenant_stats("t")["quota_bytes"] == 1 << 20
    finally:
        srv.close(close_store=True)

