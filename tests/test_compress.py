"""int8 gradient compression: bounded per-step error, unbiased under error
feedback, and trains a model to a similar loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.distributed import compress


def test_quantize_roundtrip_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((1000,)) * 0.01, jnp.float32)
    codes, scale = compress._quantize_leaf(g)
    deq = compress._dequantize_leaf(codes, scale, g.shape, jnp.float32)
    blockmax = float(jnp.max(jnp.abs(g)))
    assert float(jnp.max(jnp.abs(deq - g))) <= blockmax / 127.0 + 1e-9


@given(st.integers(min_value=1, max_value=1000), st.floats(0.001, 100.0))
@settings(max_examples=20, deadline=None)
def test_quantize_any_shape(n, scale):
    rng = np.random.default_rng(n)
    g = jnp.asarray(rng.standard_normal((n,)) * scale, jnp.float32)
    codes, s = compress._quantize_leaf(g)
    deq = compress._dequantize_leaf(codes, s, g.shape, jnp.float32)
    assert deq.shape == g.shape
    assert np.isfinite(np.asarray(deq)).all()


def test_error_feedback_accumulates_unbiased():
    """Sum of effective grads -> sum of true grads (EF corrects drift)."""
    rng = np.random.default_rng(1)
    true_sum = jnp.zeros(512)
    eff_sum = jnp.zeros(512)
    res = None
    for i in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(512) * 1e-3, jnp.float32)}
        eff, res = compress.compress_decompress(g, res)
        true_sum = true_sum + g["w"]
        eff_sum = eff_sum + eff["w"]
    # residual bounds the gap (not growing with steps)
    gap = float(jnp.max(jnp.abs(true_sum - eff_sum)))
    assert gap <= float(jnp.max(jnp.abs(res["w"]))) + 1e-6


def test_training_with_compression_converges():
    from repro import optim
    from repro.configs import get_config
    from repro.models import make_model
    from repro.train import make_train_step
    from repro.train.step import init_state

    cfg = get_config("granite-8b").reduced()
    model = make_model(cfg)
    tx = optim.adamw(3e-3)
    toks = jax.random.randint(jax.random.PRNGKey(0), (4, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def run(hook):
        state = init_state(model.init(jax.random.PRNGKey(1)), tx)
        step = jax.jit(make_train_step(model, tx)) if hook is None else \
            make_train_step(model, tx, compress_grads=hook)
        losses = []
        for _ in range(8):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        return losses

    plain = run(None)
    comp = run(compress.GradCompressor())
    assert comp[-1] < comp[0]
    assert abs(comp[-1] - plain[-1]) < 0.5 * plain[0]
