"""MoE dispatch invariants (hypothesis): with sufficient capacity and
identity experts, combine(dispatch(x)) reproduces x; virtual-expert
splitting is exact; capacity drops are monotone."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import layers as L


@given(st.integers(2, 64), st.integers(2, 8), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_dispatch_combine_identity(t, e, k):
    """Full capacity + unit gates -> combine inverts dispatch exactly."""
    k = min(k, e)
    rng = np.random.default_rng(t * e + k)
    xt = jnp.asarray(rng.standard_normal((t, 16)), jnp.float32)
    router = jnp.asarray(rng.standard_normal((16, e)), jnp.float32)
    cap = t * k  # no drops possible
    buf, slot, stt, gf, keep, probs, expert = L._route_and_dispatch(
        xt, router, e, k, cap)
    assert bool(keep.all())
    # identity experts: y == dispatched input
    y = buf.reshape(e * cap, 16)
    out = L._combine(y, slot, stt, gf, keep, t, 16)
    # sum_j gate_j * x == x (gates renormalized to 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(xt),
                               rtol=1e-4, atol=1e-5)


def test_virtual_expert_split_exact():
    """moe_ffn_shards=2 computes the SAME function as unsplit experts."""
    import dataclasses
    # ample capacity so no token is dropped in one half but kept in the other
    cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                              capacity_factor=8.0)  # gelu experts, shards=2
    cfg1 = dataclasses.replace(cfg, moe_ffn_shards=1)
    rng = jax.random.PRNGKey(0)
    p2 = L.init_moe(rng, cfg)                       # [E*2, D, F/2]
    ev, d, fv = p2["e_in"].shape
    e = ev // 2
    # fold virtual pairs back into full-width experts
    p1 = {
        "router": p2["router"],
        "e_in": p2["e_in"].reshape(e, 2, d, fv).transpose(0, 2, 1, 3).reshape(e, d, 2 * fv),
        "e_down": p2["e_down"].reshape(e, 2, fv, d).reshape(e, 2 * fv, d),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d), jnp.float32)
    y2, aux2 = L.moe(p2, x, cfg)
    y1, aux1 = L.moe(p1, x, cfg1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(aux2), float(aux1), rtol=1e-4)


def test_capacity_drop_monotone():
    """Lower capacity factor -> no more tokens processed than higher."""
    import dataclasses
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    params = L.init_moe(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model), jnp.float32)
    norms = []
    for cf in (0.25, 1.0, 4.0):
        c = dataclasses.replace(cfg, capacity_factor=cf)
        y, _ = L.moe(params, x, c)
        norms.append(float(jnp.linalg.norm(y)))
    assert norms[0] <= norms[1] + 1e-3
    # full capacity == huge capacity (nothing left to drop)
    y_full, _ = L.moe(params, x, dataclasses.replace(cfg, capacity_factor=64.0))
    y_big, _ = L.moe(params, x, dataclasses.replace(cfg, capacity_factor=128.0))
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_big), atol=1e-6)
