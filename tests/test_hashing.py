"""Hashing substrate: parallel formulations must match serial ground truth."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import hashing


@given(st.binary(min_size=1, max_size=2000))
@settings(max_examples=25, deadline=None)
def test_gear_parallel_matches_serial(data):
    buf = np.frombuffer(data, dtype=np.uint8)
    assert np.array_equal(hashing.gear_hashes_np(buf),
                          hashing.gear_hashes_serial_np(buf))


@pytest.mark.parametrize("n", [1, 31, 32, 33, 1000, 8192, 10000])
def test_gear_jnp_matches_np(n):
    rng = np.random.Generator(np.random.PCG64(n))
    data = rng.integers(0, 256, size=n, dtype=np.uint8)
    assert np.array_equal(np.asarray(hashing.gear_hashes_j(jnp.asarray(data))),
                          hashing.gear_hashes_np(data))


@pytest.mark.parametrize("window", [8, 48])
def test_rabin_jnp_matches_np(window):
    rng = np.random.Generator(np.random.PCG64(5))
    data = rng.integers(0, 256, size=3000, dtype=np.uint8)
    assert np.array_equal(
        np.asarray(hashing.rabin_fps_j(jnp.asarray(data), window)),
        hashing.rabin_fps_np(data, window))


def test_rabin_window_locality():
    """A single-byte edit only perturbs fingerprints within `window` of it."""
    rng = np.random.Generator(np.random.PCG64(6))
    data = rng.integers(0, 256, size=2000, dtype=np.uint8)
    edit = data.copy()
    edit[1000] ^= 0xFF
    a = hashing.rabin_fps_np(data)
    b = hashing.rabin_fps_np(edit)
    diff = np.flatnonzero(a != b)
    assert diff.min() >= 1000
    assert diff.max() < 1000 + hashing.RABIN_WINDOW


@given(st.binary(min_size=4, max_size=500),
       st.integers(min_value=1, max_value=6))
@settings(max_examples=25, deadline=None)
def test_segment_poly_matches_direct(data, nseg):
    buf = np.frombuffer(data, dtype=np.uint8)
    bounds = np.linspace(0, len(buf), nseg + 1).astype(np.int64)
    seg = hashing.segment_poly_hashes_np(buf, bounds)
    direct = np.array([hashing.poly_hash_np(buf[a:b])
                       for a, b in zip(bounds[:-1], bounds[1:])], dtype=np.uint32)
    assert np.array_equal(seg, direct)


def test_modinv():
    assert (int(hashing.POLY_P) * int(hashing.POLY_P_INV)) % (1 << 32) == 1


def test_multiply_shift_unit_range():
    x = jnp.arange(100, dtype=jnp.uint32) * jnp.uint32(2654435761)
    a, b = hashing.multiply_shift_params(16)
    v = np.asarray(hashing.multiply_shift_unit_j(x, jnp.asarray(a), jnp.asarray(b)))
    assert v.shape == (100, 16)
    assert (v >= -1).all() and (v < 1).all()
    assert abs(v.mean()) < 0.1  # roughly centred
