"""Object-client conformance (DESIGN.md §11.2): one parametrized
contract suite run against every client that claims the
``LocalObjectStore`` surface — put/get/get_range/head/list/
delete_object semantics, KeyError on absent keys, idempotent deletes,
short ranged reads at object end.

``LocalObjectStore`` always runs. ``S3ObjectClient`` runs against a
real bucket only when boto3 is importable AND ``REPRO_S3_TEST_BUCKET``
is set (an opt-in — CI has neither network nor credentials); otherwise
its parametrization skips cleanly, keeping the seam honest without
making the suite flaky."""
import os
import uuid

import pytest


def _local_client(tmp_path):
    from repro.api.objectstore import LocalObjectStore
    return LocalObjectStore(tmp_path / "objects")


def _s3_client(tmp_path):
    pytest.importorskip("boto3")
    bucket = os.environ.get("REPRO_S3_TEST_BUCKET")
    if not bucket:
        pytest.skip("REPRO_S3_TEST_BUCKET not set (opt-in integration)")
    from repro.api.objectstore import S3ObjectClient
    return S3ObjectClient(bucket, prefix=f"conformance-{uuid.uuid4().hex}")


@pytest.fixture(params=["local", "s3"])
def client(request, tmp_path):
    make = _local_client if request.param == "local" else _s3_client
    cl = make(tmp_path)
    yield cl
    for key, _ in cl.list(""):
        cl.delete_object(key)


class TestObjectClientConformance:
    def test_put_get_roundtrip(self, client):
        client.put("a/b/c", b"payload bytes")
        assert client.get("a/b/c") == b"payload bytes"

    def test_put_overwrites(self, client):
        client.put("k", b"old")
        client.put("k", b"new and longer")
        assert client.get("k") == b"new and longer"

    def test_get_missing_raises_keyerror(self, client):
        with pytest.raises(KeyError):
            client.get("never/put")

    def test_get_range_middle(self, client):
        client.put("r", b"0123456789")
        assert client.get_range("r", 2, 5) == b"23456"

    def test_get_range_short_at_end(self, client):
        # short read, not an error — callers treat short as truncation
        client.put("r", b"0123456789")
        assert client.get_range("r", 7, 100) == b"789"

    def test_get_range_missing_raises_keyerror(self, client):
        with pytest.raises(KeyError):
            client.get_range("never/put", 0, 4)

    def test_head_size_and_absence(self, client):
        client.put("h", b"12345")
        assert client.head("h") == 5
        assert client.head("absent") is None

    def test_list_prefix_sorted_with_sizes(self, client):
        client.put("p/a", b"1")
        client.put("p/b", b"22")
        client.put("q/c", b"333")
        assert client.list("p/") == [("p/a", 1), ("p/b", 2)]
        listed = client.list("")
        assert ("q/c", 3) in listed and listed == sorted(listed)

    def test_delete_removes_and_is_idempotent(self, client):
        client.put("d", b"x")
        client.delete_object("d")
        assert client.head("d") is None
        client.delete_object("d")           # deleting a missing key is OK
        with pytest.raises(KeyError):
            client.get("d")

    def test_empty_object(self, client):
        client.put("empty", b"")
        assert client.get("empty") == b""
        assert client.head("empty") == 0

    def test_binary_safety(self, client):
        blob = bytes(range(256)) * 17
        client.put("bin", blob)
        assert client.get("bin") == blob
        assert client.get_range("bin", 255, 2) == blob[255:257]


def test_local_rejects_traversal_keys(tmp_path):
    cl = _local_client(tmp_path)
    with pytest.raises(ValueError):
        cl.put("../escape", b"x")


def test_local_tmp_files_invisible_to_list(tmp_path):
    # a torn PUT (crash before rename) must never surface as an object
    cl = _local_client(tmp_path)
    cl.put("seen", b"x")
    (cl.root / "torn.tmp").write_bytes(b"half")
    assert [k for k, _ in cl.list("")] == ["seen"]
