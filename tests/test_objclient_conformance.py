"""Object-client conformance (DESIGN.md §11.2): one parametrized
contract suite run against every client that claims the
``LocalObjectStore`` surface — put/get/get_range/head/list/
delete_object semantics, KeyError on absent keys, idempotent deletes,
short ranged reads at object end.

``LocalObjectStore`` always runs. The ``S3ObjectClient`` adapter runs
twice: against ``_StubS3`` — a moto-style in-process fake of the exact
boto3 surface the adapter uses (injected via the ``client=`` seam, so
no boto3 needed) — on every CI run, and against a real bucket only when
boto3 is importable AND ``REPRO_S3_TEST_BUCKET`` is set (an opt-in — CI
has neither network nor credentials); the real-bucket parametrization
skips cleanly otherwise, keeping the seam honest without making the
suite flaky."""
import os
import uuid

import pytest


class _NoSuchKey(Exception):
    """boto3 raises a generated class literally named ``NoSuchKey``;
    the adapter matches on ``type(e).__name__``, so the stub's must be
    named identically."""


_NoSuchKey.__name__ = "NoSuchKey"


class _ClientError(Exception):
    """botocore-shaped error: carries the HTTP status where the adapter
    looks for it (``response["ResponseMetadata"]["HTTPStatusCode"]``)."""

    def __init__(self, code: int, op: str, key: str) -> None:
        super().__init__(f"stub {op} failed with {code} for {key!r}")
        self.response = {"ResponseMetadata": {"HTTPStatusCode": code}}


class _Body:
    def __init__(self, data: bytes) -> None:
        self._data = data

    def read(self) -> bytes:
        return self._data


class _Paginator:
    def __init__(self, buckets: dict) -> None:
        self._buckets = buckets

    def paginate(self, Bucket: str, Prefix: str = ""):
        keys = sorted(k for k in self._buckets.get(Bucket, {})
                      if k.startswith(Prefix))
        # multiple small pages, like the real service: the adapter's
        # page loop is exercised, not just its first iteration
        for i in range(0, len(keys), 2):
            yield {"Contents": [
                {"Key": k, "Size": len(self._buckets[Bucket][k])}
                for k in keys[i:i + 2]]}
        if not keys:
            yield {}                    # empty listings have no Contents


class _StubS3:
    """In-process fake of the boto3 S3 client surface ``S3ObjectClient``
    uses: put/get/head/delete_object + the list_objects_v2 paginator,
    with inclusive-end Range parsing and clamped (short, never erroring)
    reads past object end — the S3 behaviors the §11 contract leans on."""

    def __init__(self) -> None:
        self._buckets: dict[str, dict[str, bytes]] = {}

    def put_object(self, Bucket: str, Key: str, Body: bytes) -> dict:
        self._buckets.setdefault(Bucket, {})[Key] = bytes(Body)
        return {"ResponseMetadata": {"HTTPStatusCode": 200}}

    def get_object(self, Bucket: str, Key: str, Range: str | None = None
                   ) -> dict:
        data = self._buckets.get(Bucket, {}).get(Key)
        if data is None:
            raise _NoSuchKey(f"NoSuchKey: {Key!r}")
        if Range is not None:
            spec = Range.removeprefix("bytes=")
            start_s, _, end_s = spec.partition("-")
            start, end = int(start_s), int(end_s)
            data = data[start:end + 1]      # inclusive end, clamped
        return {"Body": _Body(data),
                "ResponseMetadata": {"HTTPStatusCode": 200}}

    def head_object(self, Bucket: str, Key: str) -> dict:
        data = self._buckets.get(Bucket, {}).get(Key)
        if data is None:
            raise _ClientError(404, "head_object", Key)
        return {"ContentLength": len(data),
                "ResponseMetadata": {"HTTPStatusCode": 200}}

    def get_paginator(self, op: str) -> _Paginator:
        assert op == "list_objects_v2", op
        return _Paginator(self._buckets)

    def delete_object(self, Bucket: str, Key: str) -> dict:
        self._buckets.get(Bucket, {}).pop(Key, None)    # idempotent
        return {"ResponseMetadata": {"HTTPStatusCode": 204}}


def _local_client(tmp_path):
    from repro.api.objectstore import LocalObjectStore
    return LocalObjectStore(tmp_path / "objects")


def _s3_stub_client(tmp_path):
    from repro.api.objectstore import S3ObjectClient
    return S3ObjectClient("conformance-bucket", prefix="pfx",
                          client=_StubS3())


def _s3_client(tmp_path):
    pytest.importorskip("boto3")
    bucket = os.environ.get("REPRO_S3_TEST_BUCKET")
    if not bucket:
        pytest.skip("REPRO_S3_TEST_BUCKET not set (opt-in integration)")
    from repro.api.objectstore import S3ObjectClient
    return S3ObjectClient(bucket, prefix=f"conformance-{uuid.uuid4().hex}")


_CLIENTS = {"local": _local_client, "s3-stub": _s3_stub_client,
            "s3": _s3_client}


@pytest.fixture(params=sorted(_CLIENTS))
def client(request, tmp_path):
    cl = _CLIENTS[request.param](tmp_path)
    yield cl
    for key, _ in cl.list(""):
        cl.delete_object(key)


class TestObjectClientConformance:
    def test_put_get_roundtrip(self, client):
        client.put("a/b/c", b"payload bytes")
        assert client.get("a/b/c") == b"payload bytes"

    def test_put_overwrites(self, client):
        client.put("k", b"old")
        client.put("k", b"new and longer")
        assert client.get("k") == b"new and longer"

    def test_get_missing_raises_keyerror(self, client):
        with pytest.raises(KeyError):
            client.get("never/put")

    def test_get_range_middle(self, client):
        client.put("r", b"0123456789")
        assert client.get_range("r", 2, 5) == b"23456"

    def test_get_range_short_at_end(self, client):
        # short read, not an error — callers treat short as truncation
        client.put("r", b"0123456789")
        assert client.get_range("r", 7, 100) == b"789"

    def test_get_range_missing_raises_keyerror(self, client):
        with pytest.raises(KeyError):
            client.get_range("never/put", 0, 4)

    def test_head_size_and_absence(self, client):
        client.put("h", b"12345")
        assert client.head("h") == 5
        assert client.head("absent") is None

    def test_list_prefix_sorted_with_sizes(self, client):
        client.put("p/a", b"1")
        client.put("p/b", b"22")
        client.put("q/c", b"333")
        assert client.list("p/") == [("p/a", 1), ("p/b", 2)]
        listed = client.list("")
        assert ("q/c", 3) in listed and listed == sorted(listed)

    def test_delete_removes_and_is_idempotent(self, client):
        client.put("d", b"x")
        client.delete_object("d")
        assert client.head("d") is None
        client.delete_object("d")           # deleting a missing key is OK
        with pytest.raises(KeyError):
            client.get("d")

    def test_empty_object(self, client):
        client.put("empty", b"")
        assert client.get("empty") == b""
        assert client.head("empty") == 0

    def test_binary_safety(self, client):
        blob = bytes(range(256)) * 17
        client.put("bin", blob)
        assert client.get("bin") == blob
        assert client.get_range("bin", 255, 2) == blob[255:257]


def test_local_rejects_traversal_keys(tmp_path):
    cl = _local_client(tmp_path)
    with pytest.raises(ValueError):
        cl.put("../escape", b"x")


def test_local_tmp_files_invisible_to_list(tmp_path):
    # a torn PUT (crash before rename) must never surface as an object
    cl = _local_client(tmp_path)
    cl.put("seen", b"x")
    (cl.root / "torn.tmp").write_bytes(b"half")
    assert [k for k, _ in cl.list("")] == ["seen"]
