"""End-to-end dedup + delta pipeline: DCR ordering, restore fidelity,
paper-claim direction (CARD finds more redundancy than content-only)."""
import numpy as np
import pytest

from repro.core import chunking, context_model, features, pipeline
from repro.data import workloads

CCFG = chunking.ChunkerConfig(avg_size=8192)
WCFG = workloads.WorkloadConfig(base_size=1 << 20, versions=4)


def _card(**kw):
    return pipeline.CARDDetector(
        feat_cfg=features.FeatureConfig(k=32, m=64, n=2),
        model_cfg=context_model.ContextModelConfig(m=64, d=50, steps=150),
        use_kernel=False, **kw)


@pytest.fixture(scope="module")
def versions():
    return {name: workloads.make_workload(name, WCFG)
            for name in ["kernel", "sql_dump", "vmdk"]}


def test_delta_improves_over_dedup_only(versions):
    for name, vs in versions.items():
        plain = pipeline.run_workload(pipeline.NullDetector(), vs, CCFG)
        card = pipeline.run_workload(_card(), vs, CCFG)
        assert card.dcr > plain.dcr, name
        assert card.delta_chunks > 0, name


def test_card_beats_or_matches_finesse(versions):
    for name, vs in versions.items():
        fin = pipeline.run_workload(pipeline.finesse_detector(), vs, CCFG)
        card = pipeline.run_workload(_card(), vs, CCFG)
        assert card.dcr >= 0.95 * fin.dcr, (name, card.dcr, fin.dcr)


def test_restore_byte_identical(versions):
    vs = versions["kernel"]
    store = pipeline.DedupStore(_card(), CCFG)
    store.fit(vs[:1])
    for v in vs:
        store.ingest(v)
    for i, v in enumerate(vs):
        assert store.restore(i) == v


def test_restore_byte_identical_baselines(versions):
    vs = versions["sql_dump"][:3]
    for det in [pipeline.finesse_detector(), pipeline.ntransform_detector()]:
        store = pipeline.DedupStore(det, CCFG)
        store.fit(vs[:1])
        for v in vs:
            store.ingest(v)
        for i, v in enumerate(vs):
            assert store.restore(i) == v


def test_exact_dup_detection(versions):
    """Ingesting the same stream twice stores (almost) nothing new."""
    v = versions["vmdk"][0]
    store = pipeline.DedupStore(pipeline.NullDetector(), CCFG)
    store.ingest(v)
    before = store.stats.bytes_stored
    store.ingest(v)
    assert store.stats.bytes_stored == before
    assert store.restore(1) == v


def test_banded_lsh_agrees_with_exact(versions):
    vs = versions["sql_dump"][:3]
    exact = pipeline.run_workload(_card(), vs, CCFG)
    banded = pipeline.run_workload(_card(use_lsh_bands=True), vs, CCFG)
    # banding is approximate but should find most of what exact finds
    assert banded.delta_chunks >= 0.5 * exact.delta_chunks
    assert banded.dcr >= 0.9 * exact.dcr
