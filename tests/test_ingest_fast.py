"""Fused ingest fast path (DESIGN.md §8): bit-identity against the
per-chunk numpy oracle, zero steady-state recompilation, device-scan
chunking equivalence, group-commit storage equivalence."""
import numpy as np
import pytest

from repro.core import chunking, features, hashing
from repro.kernels import ingest


def _fused_features(chunks, stream_hashes, offsets, cfg=None):
    ext = features.FeatureExtractor(cfg, use_kernel=False, fused=True)
    return ext(chunks, stream_hashes, np.asarray(offsets))


def _oracle_features(chunks, stream_hashes, offsets, cfg=None):
    """The per-chunk numpy oracle: subchunk_maxgear_np per chunk ->
    shingle_ids -> unique -> reference embed."""
    ext = features.FeatureExtractor(cfg, use_kernel=False, fused=False)
    sub = np.stack([
        features.subchunk_maxgear_np(
            np.asarray(stream_hashes)[o:o + len(c)], ext.cfg.k)
        for c, o in zip(chunks, offsets)])
    return ext.features_from_subhashes(sub)


def _case(sizes, seed=0):
    rng = np.random.Generator(np.random.PCG64(seed))
    stream = rng.integers(0, 256, size=sum(sizes), dtype=np.uint8)
    offsets = np.cumsum([0] + list(sizes[:-1]))
    chunks = [stream[o:o + s].tobytes() for o, s in zip(offsets, sizes)]
    return chunks, hashing.gear_hashes_np(stream), offsets


def test_fused_matches_per_chunk_oracle_ragged():
    """Ragged chunk sizes including shorter than the 32B gear warm-up."""
    chunks, h, offs = _case([1, 2, 31, 32, 33, 5, 700, 8192, 40000, 17])
    got = _fused_features(chunks, h, offs)
    want = _oracle_features(chunks, h, offs)
    np.testing.assert_allclose(got, want, atol=3e-7)


def test_fused_subchunk_stage_is_bit_identical():
    """The integer stages (sub-chunk LSH, shingle ids) must be exact —
    compare through the whole pipeline with the embed replaced by the
    identity-revealing unique-id sort."""
    chunks, h, offs = _case([5, 100, 31, 8192, 999], seed=3)
    k = features.FeatureConfig().k
    sub_oracle = np.stack([
        features.subchunk_maxgear_np(h[o:o + len(c)], k)
        for c, o in zip(chunks, offs)])
    # the batched jnp reference shares the fused path's segment math
    lmax = max(len(c) for c in chunks)
    gear = np.zeros((len(chunks), lmax), np.uint32)
    for i, (c, o) in enumerate(zip(chunks, offs)):
        gear[i, :len(c)] = h[o:o + len(c)]
    lens = np.asarray([len(c) for c in chunks], np.int32)
    import jax.numpy as jnp
    sub_j = np.asarray(features.batch_subchunk_maxgear_j(
        jnp.asarray(gear), jnp.asarray(lens), k))
    assert np.array_equal(sub_oracle, sub_j)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fused_property_sweep(seed):
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(min_value=1, max_value=3000),
                    min_size=1, max_size=12),
           st.integers(min_value=0, max_value=2**31 - 1))
    def prop(sizes, s):
        chunks, h, offs = _case(sizes, seed=s + seed)
        got = _fused_features(chunks, h, offs)
        want = _oracle_features(chunks, h, offs)
        np.testing.assert_allclose(got, want, atol=3e-7)

    prop()


def test_steady_state_zero_recompiles():
    """Same-bucket streams must hit a warm jit cache: no new traces of
    the scan or extract programs after the first stream of a bucket."""
    from repro import api
    cfg = api.DedupConfig.from_dict({
        "detector": "card",
        "detector_args": {"feat": {"k": 8, "m": 16, "n": 2},
                          "model": {"m": 16, "d": 8, "steps": 4},
                          "use_kernel": False},
        "chunker_args": {"avg_size": 1024}})
    store = api.build_store(cfg)
    rng = np.random.Generator(np.random.PCG64(0))
    streams = [rng.integers(0, 256, size=60_000, dtype=np.uint8).tobytes()
               for _ in range(4)]
    store.fit(streams[:1])
    store.ingest(streams[0])
    store.ingest(streams[1])            # same bucket: warms every program
    before = ingest.trace_count()
    store.ingest(streams[2])
    store.ingest(streams[3])
    assert ingest.trace_count() == before, "steady-state ingest retraced"


def test_lmax_floor_prevents_longest_chunk_retrace():
    """The Lmax bucket is pinned at the chunker's max_size (wired through
    CARDDetector.fit), so a stream whose observed longest chunk straddles
    a pow2 boundary must not retrace the extract program."""
    from repro.core import features
    ext = features.FeatureExtractor(
        features.FeatureConfig(k=8, m=16, n=2), use_kernel=False)
    rng = np.random.Generator(np.random.PCG64(2))

    def feats(sizes, seed):
        chunks, h, offs = _case(sizes, seed=seed)
        return ext(chunks, h, offs, lmax_floor=4096)

    feats([1500, 900, 1200], seed=1)        # warm: longest 1500
    before = ingest.trace_count()
    feats([2500, 700], seed=2)              # longest 2500: same 4096 bucket
    assert ingest.trace_count() == before


def test_device_scan_matches_host_chunking():
    """chunk_with's device gear scan must reproduce the host chunker
    bit-for-bit: same hashes, same boundaries."""
    from repro.api.store import chunk_with
    rng = np.random.Generator(np.random.PCG64(7))
    stream = rng.integers(0, 256, size=200_000, dtype=np.uint8).tobytes()
    cfg = chunking.ChunkerConfig(avg_size=4096)
    host = chunking.chunk_stream(stream, cfg)
    dev_chunks, scan = chunk_with(cfg, stream)
    assert [(c.offset, c.length) for c in host] == \
           [(c.offset, c.length) for c in dev_chunks]
    assert np.array_equal(
        np.asarray(scan),
        hashing.gear_hashes_np(np.frombuffer(stream, np.uint8)))


def test_fused_and_unfused_stores_bit_identical(tmp_path):
    """End-to-end pin: verdicts, per-stream accounting, container records
    and restored bytes agree between the fused fast path and the
    per-chunk baseline."""
    from repro import api
    rng = np.random.Generator(np.random.PCG64(5))
    base = rng.integers(0, 256, size=300_000, dtype=np.uint8)
    v2 = base.copy()
    v2[1000:1100] = rng.integers(0, 256, size=100, dtype=np.uint8)
    v3 = np.concatenate([base[:150_000],
                         rng.integers(0, 256, size=500, dtype=np.uint8),
                         base[150_000:]])
    versions = [v.tobytes() for v in (base, v2, v3)]

    def build(fused, path):
        cfg = api.DedupConfig.from_dict({
            "detector": "card",
            "detector_args": {"feat": {"k": 16, "m": 32, "n": 2},
                              "model": {"m": 32, "d": 16, "steps": 8},
                              "use_kernel": False, "fused": fused},
            "chunker_args": {"avg_size": 4096},
            "backend": "file", "backend_args": {"path": str(path)}})
        store = api.build_store(cfg)
        store.fit(versions[:1])
        for v in versions:
            store.ingest(v)
        return store

    s_f = build(True, tmp_path / "fused")
    s_u = build(False, tmp_path / "unfused")
    for rf, ru in zip(s_f.reports, s_u.reports):
        assert (rf.chunks, rf.dup_chunks, rf.delta_chunks, rf.raw_chunks,
                rf.bytes_stored) == (ru.chunks, ru.dup_chunks,
                                     ru.delta_chunks, ru.raw_chunks,
                                     ru.bytes_stored)
    assert sorted(s_f.backend.chunk_ids()) == sorted(s_u.backend.chunk_ids())
    for cid in s_f.backend.chunk_ids():
        assert s_f.backend.record(cid) == s_u.backend.record(cid)
    for h, v in enumerate(versions):
        assert s_f.restore(h) == v
        assert s_u.restore(h) == v
    s_f.close()
    s_u.close()


def test_put_many_file_backend_matches_per_chunk(tmp_path):
    """Group commit writes the same records the per-chunk puts would, and
    a reopened backend serves them identically."""
    from repro.api import containers
    rng = np.random.Generator(np.random.PCG64(9))
    payloads = [rng.integers(0, 256, size=int(s), dtype=np.uint8).tobytes()
                for s in rng.integers(10, 5000, size=8)]

    a = containers.FileBackend(tmp_path / "a")
    a.put_raw(0, payloads[0])
    a.put_delta(1, 0, payloads[1], data=payloads[2])
    a.put_raw(2, payloads[3])
    a.flush()

    b = containers.FileBackend(tmp_path / "b")
    b.put_many([(0, -1, payloads[0], None),
                (1, 0, payloads[1], payloads[2]),
                (2, -1, payloads[3], None)])
    b.flush()

    for cid in (0, 1, 2):
        assert a.record(cid) == b.record(cid)
        assert a.payload_size(cid) == b.payload_size(cid)
        assert a.base_of(cid) == b.base_of(cid)

    reopened = containers.FileBackend(tmp_path / "b")
    for cid in (0, 2):
        assert reopened.record(cid) == a.record(cid)
    a.close(); b.close(); reopened.close()


def test_put_many_failed_write_leaves_no_phantom_index(tmp_path):
    """A group-commit write that fails (ENOSPC) must not leave index
    entries pointing at never-written offsets — contains() lying would
    let later commits delta-encode against phantom bases."""
    from repro.api import containers

    backend = containers.FileBackend(tmp_path)

    class FailingLog:
        def __init__(self, f):
            self._f = f

        def write(self, data):
            raise OSError(28, "No space left on device")

        def __getattr__(self, attr):
            return getattr(self._f, attr)

    backend._log = FailingLog(backend._log)
    with pytest.raises(OSError, match="No space"):
        backend.put_many([(0, -1, b"x" * 100, None),
                          (1, 0, b"patch", b"y" * 100)])
    assert not backend.contains(0)
    assert not backend.contains(1)
    assert backend.max_chunk_id() == -1


def test_streamscan_indexes_like_numpy():
    rng = np.random.Generator(np.random.PCG64(4))
    data = rng.integers(0, 256, size=5000, dtype=np.uint8)
    scan, cand_s, cand_l = ingest.scan_stream(data, 0xFF, 0xF)
    ref = hashing.gear_hashes_np(data)
    assert len(scan) == 5000
    assert np.array_equal(scan[100:200], ref[100:200])
    assert np.array_equal(np.asarray(scan), ref)
    assert np.array_equal(cand_s, (ref & np.uint32(0xFF)) == 0)
    assert np.array_equal(cand_l, (ref & np.uint32(0xF)) == 0)
