"""Object-store backend subsystem (DESIGN.md §11): the LocalObjectStore
fake, ObjectStoreBackend parity with FileBackend, range-coalescing
request counts, retry-under-fault byte identity, journal/container
recovery, compaction, the coalesce-gap knob, and the cp/ls/stat/verify
CLI round-trip."""
import json
import os
import threading

import numpy as np
import pytest

from repro.api import objectstore as osmod
from repro.api.config import DedupConfig, build_store
from repro.api.containers import FileBackend
from repro.api.objectstore import (FaultSchedule, LocalObjectStore,
                                   ObjectStoreBackend, TransientError)
from repro.core import delta


# --- fixtures ----------------------------------------------------------------

def _blobs(n, size=3000, seed=0):
    rng = np.random.default_rng(seed)
    return {i: bytes(rng.integers(0, 256, size, np.uint8)) for i in range(n)}


def _populate(backend, blobs, raw_n):
    """First ``raw_n`` chunks raw, the rest delta-chained onto them;
    two recipes (one per half) with lengths. Returns (h0, h1)."""
    n = len(blobs)
    backend.put_many([(i, -1, blobs[i], None) for i in range(raw_n)])
    backend.put_many([(i, i - raw_n,
                       delta.encode(blobs[i], blobs[i - raw_n]), blobs[i])
                      for i in range(raw_n, n)])
    h0 = backend.add_recipe(list(range(raw_n)),
                            [len(blobs[i]) for i in range(raw_n)])
    h1 = backend.add_recipe(list(range(raw_n, n)),
                            [len(blobs[i]) for i in range(raw_n, n)])
    backend.flush()
    return h0, h1


def _cold(backend):
    """Drop every cached materialization so reads hit the object tree."""
    backend._cache.retain(lambda cid: False)


# --- LocalObjectStore (the fake itself) --------------------------------------

def test_local_object_store_api(tmp_path):
    cl = LocalObjectStore(tmp_path / "o")
    cl.put("a/b", b"hello world")
    assert cl.get("a/b") == b"hello world"
    assert cl.get_range("a/b", 6, 5) == b"world"
    assert cl.get_range("a/b", 6, 100) == b"world"     # short at end
    assert cl.head("a/b") == 11
    assert cl.head("missing") is None
    cl.put("a/c", b"x")
    assert cl.list("a/") == [("a/b", 11), ("a/c", 1)]
    cl.delete_object("a/b")
    cl.delete_object("a/b")                             # idempotent
    with pytest.raises(KeyError):
        cl.get("a/b")
    assert cl.requests == 11 and cl.op_counts["get"] == 4
    assert cl.bytes_put == 12 and cl.bytes_got == 11 + 5 + 5


def test_local_object_store_faults_and_counters(tmp_path):
    sched = FaultSchedule({"get": [2]}, status=429)
    cl = LocalObjectStore(tmp_path / "o", fault_hook=sched)
    cl.put("k", b"data")
    assert cl.get("k") == b"data"
    with pytest.raises(TransientError) as ei:
        cl.get("k")
    assert ei.value.status == 429
    assert cl.get("k") == b"data"       # schedule exhausted, healthy again
    assert cl.op_counts["get"] == 3     # the failed attempt still counted


# --- backend parity with FileBackend -----------------------------------------

@pytest.mark.parametrize("latency", [0.0, 0.002])
def test_parity_with_file_backend(tmp_path, latency):
    """Same records in, byte-identical materializations out — cold via
    get_many, cold via per-chunk get, and again after reopen."""
    blobs = _blobs(30)
    fb = FileBackend(tmp_path / "file")
    ob = ObjectStoreBackend(tmp_path / "obj", latency=latency,
                            max_object_bytes=1 << 14)
    for b in (fb, ob):
        _populate(b, blobs, 15)
    order = list(range(len(blobs)))
    _cold(fb), _cold(ob)
    assert ob.get_many(order) == fb.get_many(order)
    _cold(ob)
    assert [ob.get(i) for i in order] == [blobs[i] for i in order]
    assert ob.recipe(0) == fb.recipe(0)
    assert ob.recipe_lengths(1) == fb.recipe_lengths(1)
    assert ob.max_chunk_id() == fb.max_chunk_id()
    fb.close(), ob.close()

    re = ObjectStoreBackend(tmp_path / "obj", latency=latency,
                            max_object_bytes=1 << 14)
    assert re.get_many(order) == [blobs[i] for i in order]
    assert re.max_chunk_id() == 29 and re.live_handles() == [0, 1]
    re.close()


def test_get_many_equals_get(tmp_path):
    blobs = _blobs(24, seed=3)
    b = ObjectStoreBackend(tmp_path / "obj", max_object_bytes=1 << 13)
    _populate(b, blobs, 12)
    _cold(b)
    batched = b.get_many(list(range(24)))
    _cold(b)
    singles = [b.get(i) for i in range(24)]
    assert batched == singles == [blobs[i] for i in range(24)]
    b.close()


def test_record_and_payload_size(tmp_path):
    blobs = _blobs(4)
    b = ObjectStoreBackend(tmp_path / "obj")
    _populate(b, blobs, 2)
    kind, base, payload = b.record(3)
    assert (kind, base) == (1, 1) and payload != blobs[3]   # the patch
    assert b.payload_size(0) == len(blobs[0]) and b.base_of(3) == 1
    assert b.record_overhead == 0
    b.close()


# --- range coalescing: the request-count story (§11.3) -----------------------

def test_coalescing_cuts_request_count(tmp_path):
    """A cold sequential restore must cost a handful of ranged GETs, not
    one per chunk — the 1 MiB default gap folds a whole container object
    into O(size/max_run) requests (≥5x under the bench's gate; here the
    layout is exactly sequential so it collapses to the object count)."""
    blobs = _blobs(64, size=2000, seed=5)
    b = ObjectStoreBackend(tmp_path / "obj", max_object_bytes=1 << 15)
    _populate(b, blobs, 32)
    _cold(b)
    before = b.client.op_counts.get("get", 0)
    assert b.get_many(list(range(64))) == [blobs[i] for i in range(64)]
    coalesced = b.client.op_counts["get"] - before
    assert coalesced * 5 <= 64, f"{coalesced} GETs for 64 chunks"
    assert b.read_requests == coalesced
    b.close()

    # gap 0 merges only exactly-adjacent records; interleaving the two
    # recipes' payloads in the log leaves holes, so requests multiply
    b0 = ObjectStoreBackend(tmp_path / "obj", coalesce_gap=0,
                            max_object_bytes=1 << 15)
    _cold(b0)
    before = b0.client.op_counts.get("get", 0)
    every_other = list(range(0, 64, 2))
    assert b0.get_many(every_other) == [blobs[i] for i in every_other]
    assert b0.client.op_counts["get"] - before > coalesced
    b0.close()


def test_coalesce_gap_knob_forwarding(tmp_path):
    cfg = DedupConfig.from_dict({
        "detector": "dedup-only", "backend": "objectstore",
        "backend_args": {"path": str(tmp_path / "o")},
        "restore_coalesce_gap": 123})
    store = build_store(cfg)
    assert store.backend._merge_gap == 123
    store.close()
    cfg = DedupConfig.from_dict({
        "detector": "dedup-only", "backend": "file",
        "backend_args": {"path": str(tmp_path / "f")},
        "restore_coalesce_gap": 0})
    store = build_store(cfg)
    assert store.backend._merge_gap == 0
    store.close()
    with pytest.raises(ValueError):
        DedupConfig.from_dict({"restore_coalesce_gap": -1})
    with pytest.raises(ValueError):
        DedupConfig.from_dict({"restore_coalesce_gap": "big"})


# --- faults, retries, and byte identity --------------------------------------

def test_retries_make_restores_byte_identical(tmp_path):
    """A transient-error schedule under the retry budget is invisible:
    restores stay byte-identical and the backend reports the absorbed
    faults. Exercised with latency too, so sleeps and retries overlap."""
    blobs = _blobs(30, seed=7)
    plain = ObjectStoreBackend(tmp_path / "a", max_object_bytes=1 << 14)
    _populate(plain, blobs, 15)
    plain.close()

    faulty = ObjectStoreBackend(
        tmp_path / "a", latency=0.001, retry_backoff=0.001,
        max_object_bytes=1 << 14,
        fault_hook=FaultSchedule({"get": [2, 3, 6]}))
    _cold(faulty)
    assert faulty.get_many(list(range(30))) == [blobs[i] for i in range(30)]
    assert faulty.retries > 0
    faulty.close()


def test_retry_budget_exhaustion_raises(tmp_path):
    blobs = _blobs(4)
    b = ObjectStoreBackend(tmp_path / "o")
    _populate(b, blobs, 2)
    b.close()
    re = ObjectStoreBackend(tmp_path / "o", max_retries=0,
                            retry_backoff=0.001)
    _cold(re)
    # scan is done; now fail every further GET with no retry budget
    re.client.fault_hook = FaultSchedule({"get": list(range(1, 50))})
    with pytest.raises(TransientError):
        re.get_many(list(range(4)))
    re.close()


def test_retry_deadline_raises_typed_budget_error(tmp_path):
    """§13.5: a total-sleep deadline bounds the hang; exceeding it
    raises RetryBudgetExceeded with forensics, never sleeps past it."""
    from repro.api.faults import RetryBudgetExceeded
    blobs = _blobs(2)
    b = ObjectStoreBackend(tmp_path / "o")
    _populate(b, blobs, 1)
    b.close()
    re = ObjectStoreBackend(tmp_path / "o", max_retries=10,
                            retry_backoff=0.01, retry_deadline=0.05)
    _cold(re)
    re.client.fault_hook = FaultSchedule({"get": list(range(1, 100))})
    with pytest.raises(RetryBudgetExceeded) as ei:
        re.get_many([0, 1])
    err = ei.value
    assert isinstance(err, TransientError)      # generic callers keep working
    assert err.deadline == 0.05
    assert 0 <= err.slept <= err.deadline       # never overslept
    assert err.attempts >= 1
    assert isinstance(err.last, TransientError)
    assert "deadline" in str(err)
    re.close()


def test_retry_deadline_unhit_is_invisible(tmp_path):
    """A generous deadline changes nothing: transient faults under the
    attempt budget are still absorbed byte-identically."""
    blobs = _blobs(6)
    b = ObjectStoreBackend(tmp_path / "o")
    _populate(b, blobs, 3)
    b.close()
    re = ObjectStoreBackend(tmp_path / "o", retry_backoff=0.001,
                            retry_deadline=30.0)
    _cold(re)
    # scan is done; fail the first two GETs the restore itself issues
    re.client.fault_hook = FaultSchedule({"get": [1, 2]})
    assert re.get_many(list(range(6))) == [blobs[i] for i in range(6)]
    assert re.retries > 0
    re.close()


def test_decorrelated_jitter_bounds(tmp_path):
    """Every sampled backoff lies in [base, min(cap, 3*previous)] — the
    decorrelated-jitter envelope — and is not a constant ladder."""
    b = ObjectStoreBackend(tmp_path / "o", retry_backoff=0.01,
                           max_retries=6)
    base, cap = b._backoff, b._backoff_cap
    assert cap == pytest.approx(0.01 * 2 ** 6)
    rng = b._retry_rng
    prev = base
    seen = []
    for _ in range(200):
        delay = rng.uniform(base, min(cap, prev * 3))
        assert base <= delay <= min(cap, prev * 3)
        assert delay <= cap
        seen.append(delay)
        prev = delay
    assert len(set(seen)) > 100     # jittered, not a deterministic ladder
    b.close()


def test_concurrent_readers_under_latency(tmp_path):
    """Several threads restoring at once over a slow client: all byte
    identical, no cross-thread cache/pin corruption."""
    blobs = _blobs(24, seed=11)
    b = ObjectStoreBackend(tmp_path / "o", latency=0.001,
                           max_object_bytes=1 << 13)
    _populate(b, blobs, 12)
    _cold(b)
    errors = []

    def reader(lo, hi):
        want = list(range(lo, hi))
        try:
            for _ in range(3):
                if b.get_many(want) != [blobs[i] for i in want]:
                    errors.append((lo, hi))
        except Exception as e:          # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(0, 12)),
               threading.Thread(target=reader, args=(12, 24)),
               threading.Thread(target=reader, args=(6, 18))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errors
    b.close()


# --- recovery (§11.4) --------------------------------------------------------

def test_lost_journal_drops_commit_whole(tmp_path):
    """Killing the journal PUT of the second commit loses exactly that
    commit — its chunks leave the index and its handle slot never
    existed (chunk rows and recipe ride the same journal object, so
    they vanish together and no surviving recipe can alias the ids).
    The first stream still restores; the orphan container is swept."""
    blobs = _blobs(20, seed=13)
    b = ObjectStoreBackend(tmp_path / "o", max_object_bytes=1 << 20)
    b.put_many([(i, -1, blobs[i], None) for i in range(10)])
    h0 = b.add_recipe(list(range(10)), [len(blobs[i]) for i in range(10)])
    b.flush()
    b.put_many([(i, -1, blobs[i], None) for i in range(10, 20)])
    h1 = b.add_recipe(list(range(10, 20)),
                      [len(blobs[i]) for i in range(10, 20)])
    b.flush()
    b.close()
    cl = LocalObjectStore(tmp_path / "o")
    (key1,) = [k for k, _ in cl.list("e00000000/journal/")
               if k.endswith("00000001.json")]
    cl.delete_object(key1)

    re = ObjectStoreBackend(tmp_path / "o")
    assert re.get_many(list(range(10))) == [blobs[i] for i in range(10)]
    assert re.recipe(h0) == list(range(10))
    with pytest.raises(IndexError):     # the slot is gone, not retired
        re.recipe(h1)
    assert re.num_streams() == 1 and not re.contains(15)
    # the orphaned second container object was swept
    assert not any("/chunks/" in k and k.endswith("00000001")
                   for k, _ in re.client.list(""))
    re.close()
    re2 = ObjectStoreBackend(tmp_path / "o")   # recovery state is stable
    with pytest.raises(IndexError):
        re2.recipe(h1)
    assert re2.max_chunk_id() == 9
    re2.close()


def test_lost_container_retires_dependent_recipes(tmp_path):
    """A vanished container object loses its chunks AND every delta
    dependent; recipes touching any of them retire, others survive."""
    blobs = _blobs(20, seed=17)
    b = ObjectStoreBackend(tmp_path / "o", max_object_bytes=1 << 20)
    h0, h1 = _populate(b, blobs, 10)    # h1 deltas against h0's chunks
    b.put_many([(20, -1, blobs[0], None)])
    h2 = b.add_recipe([20], [len(blobs[0])])
    b.flush()                           # second container object
    b.close()
    cl = LocalObjectStore(tmp_path / "o")
    cl.delete_object("e00000000/chunks/00000000")   # h0+h1's payloads

    re = ObjectStoreBackend(tmp_path / "o")
    for h in (h0, h1):
        with pytest.raises(KeyError):
            re.recipe(h)
    assert re.recipe(h2) == [20] and re.get(20) == blobs[0]
    assert re.chunk_ids() == [20]
    re.close()


def test_orphan_container_and_stale_epoch_are_swept(tmp_path):
    blobs = _blobs(6, seed=19)
    b = ObjectStoreBackend(tmp_path / "o")
    _populate(b, blobs, 3)
    b.close()
    cl = LocalObjectStore(tmp_path / "o")
    # a crash after the container PUT but before its journal PUT...
    cl.put("e00000000/chunks/00000042", b"orphaned bytes")
    # ...and an interrupted compaction's half-written next epoch
    cl.put("e00000001/chunks/00000000", b"stale epoch bytes")
    re = ObjectStoreBackend(tmp_path / "o")
    keys = [k for k, _ in re.client.list("")]
    assert "e00000000/chunks/00000042" not in keys
    assert not any(k.startswith("e00000001/") for k in keys)
    assert re.get_many(list(range(6))) == [blobs[i] for i in range(6)]
    re.close()


def test_fresh_root_without_manifest_starts_clean(tmp_path):
    cl = LocalObjectStore(tmp_path / "o")
    cl.put("e00000000/chunks/00000000", b"debris from a pre-manifest crash")
    b = ObjectStoreBackend(tmp_path / "o")
    assert b.chunk_ids() == [] and b.num_streams() == 0
    assert json.loads(cl.get("manifest.json")) == {"epoch": 0}
    assert not any("debris" in k for k, _ in cl.list(""))
    b.close()


# --- compaction over the object tree -----------------------------------------

def test_store_compaction_on_objectstore(tmp_path):
    """Full store lifecycle on the object backend: ingest, delete,
    collect, compact — the epoch flips, the old epoch's objects are
    gone, survivors restore byte-identically after reopen."""
    cfg = DedupConfig.from_dict({
        "detector": "dedup-only", "backend": "objectstore",
        "backend_args": {"path": str(tmp_path / "o"),
                         "max_object_bytes": 1 << 15},
        "chunker_args": {"avg_size": 2048}})
    store = build_store(cfg)
    rng = np.random.default_rng(23)
    base = rng.integers(0, 256, 80 << 10, np.uint8).tobytes()
    edited = base[: 40 << 10] + rng.integers(0, 256, 40 << 10,
                                             np.uint8).tobytes()
    handles = []
    for data in (base, edited):
        with store.open_stream() as s:
            s.write(data)
        handles.append(s.report.handle)
    assert store.restore(handles[0]) == base
    store.delete(handles[0])
    store.collect()
    store.compact()
    assert store.backend.epoch == 1
    assert not any(k.startswith("e00000000/")
                   for k, _ in store.backend.client.list(""))
    assert store.restore(handles[1]) == edited
    store.close()

    store2 = build_store(cfg)
    assert store2.restore(handles[1]) == edited
    with pytest.raises(KeyError):
        store2.restore(handles[0])
    store2.close()


# --- store-level telemetry ---------------------------------------------------

def test_restore_report_counts_requests(tmp_path):
    cfg = DedupConfig.from_dict({
        "detector": "dedup-only", "backend": "objectstore",
        "backend_args": {"path": str(tmp_path / "o")},
        "chunker_args": {"avg_size": 2048}})
    store = build_store(cfg)
    rng = np.random.default_rng(29)
    data = rng.integers(0, 256, 64 << 10, np.uint8).tobytes()
    with store.open_stream() as s:
        s.write(data)
    h = s.report.handle
    _cold(store.backend)
    assert store.restore(h) == data
    cold = store.last_restore
    assert cold.requests > 0
    assert store.restore(h) == data     # cache-warm: no new physical reads
    assert store.last_restore.requests == 0
    assert store.stats.restore_requests == cold.requests
    store.close()


# --- the CLI -----------------------------------------------------------------

def _write(p, data):
    p.write_bytes(data)
    return str(p)


def test_cli_cp_ls_stat_verify_roundtrip(tmp_path, capsys):
    rng = np.random.default_rng(31)
    a = rng.integers(0, 256, 200 << 10, np.uint8).tobytes()
    b = a[: 150 << 10] + rng.integers(0, 256, 50 << 10, np.uint8).tobytes()
    src_a = _write(tmp_path / "a.bin", a)
    src_b = _write(tmp_path / "b.bin", b)
    root = tmp_path / "bk"

    assert osmod.main(["cp", src_a, src_b, f"obj://{root}"]) == 0
    assert osmod.main(["ls", f"obj://{root}"]) == 0
    out = capsys.readouterr().out
    assert "a.bin" in out and "b.bin" in out
    assert osmod.main(["stat", f"obj://{root}"]) == 0
    assert "physical bytes" in capsys.readouterr().out
    assert osmod.main(["verify", f"obj://{root}"]) == 0
    assert "2/2 objects verified" in capsys.readouterr().out

    # near-duplicate b deduped against a across one invocation
    cat = json.loads((root / "catalog.json").read_text())
    assert cat["files"]["b.bin"]["stored"] < len(b) // 2

    out_path = tmp_path / "restored.bin"
    assert osmod.main(["cp", f"obj://{root}/a.bin", str(out_path)]) == 0
    assert out_path.read_bytes() == a


def test_cli_cross_invocation_dedup_and_verify_failure(tmp_path, capsys):
    rng = np.random.default_rng(37)
    data = rng.integers(0, 256, 120 << 10, np.uint8).tobytes()
    src = _write(tmp_path / "orig.bin", data)
    src2 = _write(tmp_path / "copy.bin", data)
    root = tmp_path / "bk"
    assert osmod.main(["cp", src, f"obj://{root}"]) == 0
    # a second PROCESS-level invocation: the digest table reloads from
    # the catalog, so a byte-identical file stores almost nothing
    assert osmod.main(["cp", src2, f"obj://{root}"]) == 0
    cat = json.loads((root / "catalog.json").read_text())
    assert cat["files"]["copy.bin"]["stored"] < len(data) // 20
    capsys.readouterr()

    # tamper with the recorded SHA: verify must fail that object only
    cat["files"]["copy.bin"]["sha256"] = "0" * 64
    (root / "catalog.json").write_text(json.dumps(cat))
    assert osmod.main(["verify", f"obj://{root}"]) == 1
    out = capsys.readouterr().out
    assert "FAIL  copy.bin" in out and "ok    orig.bin" in out


def test_cli_cp_overwrite_replaces_object(tmp_path, capsys):
    rng = np.random.default_rng(41)
    v1 = rng.integers(0, 256, 50 << 10, np.uint8).tobytes()
    v2 = rng.integers(0, 256, 60 << 10, np.uint8).tobytes()
    root = tmp_path / "bk"
    src = tmp_path / "f.bin"
    for v in (v1, v2):
        src.write_bytes(v)
        assert osmod.main(["cp", str(src), f"obj://{root}"]) == 0
    out_path = tmp_path / "out.bin"
    assert osmod.main(["cp", f"obj://{root}/f.bin", str(out_path)]) == 0
    assert out_path.read_bytes() == v2
    assert osmod.main(["verify", f"obj://{root}", "f.bin"]) == 0


def test_cli_rejects_ambiguous_transfers(tmp_path):
    with pytest.raises(SystemExit):
        osmod.main(["cp", "local1", "local2"])
    with pytest.raises(SystemExit):
        osmod.main(["cp", f"obj://{tmp_path}/x", f"obj://{tmp_path}/y"])
    with pytest.raises(SystemExit):
        osmod.main(["ls", f"obj://{tmp_path}/nostore"])
