"""Restore/serving fast path (DESIGN.md §9): planner, bounded decode
cache, ranged reads, backend parity, and the empty-stream regression."""
import numpy as np
import pytest

from repro import api
from repro.api.restore import DecodeCache, RecipeLayout, plan_chains
from repro.core import delta

AVG = 2048


def _versions(n=3, size=96 << 10, seed=0):
    """Version chain with heavy cross-version similarity (delta chains)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 256, size, np.uint8)
    out = []
    for v in range(n):
        cur = base.copy()
        for _ in range(24):
            p = int(rng.integers(0, size - 256))
            cur[p:p + 128] = rng.integers(0, 256, 128, np.uint8)
        out.append(cur.tobytes())
        base = cur
    return out


def _card_cfg(extra=None):
    d = {"detector": "card",
         "detector_args": {"feat": {"k": 16, "m": 32, "n": 2},
                           "model": {"m": 32, "d": 20, "steps": 40},
                           "use_kernel": False},
         "chunker_args": {"avg_size": AVG}}
    d.update(extra or {})
    return api.DedupConfig.from_dict(d)


def _ingest(store, versions):
    store.fit(list(versions[:1]))
    handles = []
    for v in versions:
        with store.open_stream() as s:
            s.write(v)
        handles.append(s.report.handle)
    return handles


# --- planner ------------------------------------------------------------------

def _toy_entries(edges):
    """edges: cid -> base (-1 raw). Offsets/lengths synthesized per cid."""
    def entry(cid):
        return (edges[cid], cid * 100, 10)
    return entry


def test_plan_decodes_every_chain_node_exactly_once():
    # two targets sharing a chain suffix: 5->4->3->0(raw), 7->3->0
    edges = {0: -1, 3: 0, 4: 3, 5: 4, 7: 3}
    plan = plan_chains([5, 7], _toy_entries(edges), lambda c: False)
    assert sorted(plan.decode_order) == [0, 3, 4, 5, 7]
    # topological: every base decodes before its dependents
    pos = {c: i for i, c in enumerate(plan.decode_order)}
    for cid, base in edges.items():
        if base >= 0 and cid in pos:
            assert pos[base] < pos[cid]
    # shared suffix read once, reads ascend by offset
    assert [r[2] for r in plan.reads] == sorted(
        {0, 3, 4, 5, 7}, key=lambda c: c * 100)
    assert plan.dependents == {0: 1, 3: 2, 4: 1}


def test_plan_stops_at_cached_base_and_pins_it():
    edges = {0: -1, 1: 0, 2: 1}
    plan = plan_chains([2], _toy_entries(edges), lambda c: c == 1)
    assert plan.decode_order == [2]
    assert plan.cached_bases == [1]
    assert plan.dependents == {1: 1}
    # a cached *target* is not a pinnable base
    plan2 = plan_chains([1], _toy_entries(edges), lambda c: c == 1)
    assert plan2.decode_order == [] and plan2.cached_bases == []


def test_plan_dedups_duplicate_targets():
    edges = {0: -1, 1: 0}
    plan = plan_chains([1, 1, 0, 1], _toy_entries(edges), lambda c: False)
    assert plan.targets == [1, 0]
    assert plan.decode_order == [0, 1]
    assert len(plan.reads) == 2


# --- decode cache -------------------------------------------------------------

def test_decode_cache_lru_eviction_under_budget():
    cache = DecodeCache(budget_bytes=100)
    cache.put(1, b"a" * 40)
    cache.put(2, b"b" * 40)
    cache.get(1)                    # refresh: 2 is now LRU
    cache.put(3, b"c" * 40)         # evicts 2
    assert 1 in cache and 3 in cache and 2 not in cache
    assert cache.bytes <= 100
    assert cache.peak_bytes <= 100


def test_decode_cache_pin_blocks_eviction_until_unpin():
    cache = DecodeCache(budget_bytes=100)
    cache.put(1, b"a" * 60, pin=True)
    cache.put(2, b"b" * 60)         # over budget, but 1 is pinned -> 2 evicted
    assert 1 in cache and 2 not in cache
    cache.put(3, b"c" * 30)
    assert 1 in cache               # still pinned
    cache.unpin(1)                  # now evictable; next pressure drops it
    cache.put(4, b"d" * 30)
    assert 1 not in cache and 3 in cache and 4 in cache
    with pytest.raises(ValueError):
        cache.unpin(1)
    with pytest.raises(KeyError):
        cache.pin(99)


def test_decode_cache_counts_hits_and_misses():
    cache = DecodeCache(budget_bytes=100)
    cache.put(1, b"x")
    assert cache.get(1) == b"x" and cache.get(2) is None
    assert (cache.hits, cache.misses) == (1, 1)


# --- recipe layout ------------------------------------------------------------

def test_recipe_layout_windows():
    lay = RecipeLayout([10, 20, 30])
    assert lay.total_bytes == 60
    assert lay.chunk_window(0, 10) == (0, 0, 0)
    assert lay.chunk_window(9, 2) == (0, 1, 9)      # straddles 0/1
    assert lay.chunk_window(10, 1) == (1, 1, 0)
    assert lay.chunk_window(59, 100) == (2, 2, 29)  # clamped to tail
    assert lay.chunk_window(60, 5)[1] == -1         # past the end: empty
    assert lay.chunk_window(5, 0)[1] == -1
    with pytest.raises(ValueError):
        lay.chunk_window(-1, 5)
    assert RecipeLayout([]).total_bytes == 0


# --- end-to-end byte identity -------------------------------------------------

@pytest.mark.parametrize("backend", ["memory", "file"])
def test_restore_surfaces_byte_identical(tmp_path, backend):
    extra = {}
    if backend == "file":
        extra = {"backend": "file", "backend_args": {"path": str(tmp_path)}}
    store = api.build_store(_card_cfg(extra))
    versions = _versions()
    handles = _ingest(store, versions)
    assert store.stats.delta_chunks > 0     # chains actually exist
    rng = np.random.default_rng(1)
    for h, v in zip(handles, versions):
        assert store.restore(h) == v
        assert b"".join(store.restore_iter(h, batch_chunks=5)) == v
        assert store.stream_length(h) == len(v)
        for _ in range(16):
            off = int(rng.integers(0, len(v) + AVG))
            ln = int(rng.integers(0, 3 * AVG))
            assert store.restore_range(h, off, ln) == v[off:off + ln]
    store.close()


def test_restore_range_survives_compaction(tmp_path):
    """Compaction rebases patches but never materialized bytes, so the
    persisted prefix sums — and any cached layout — stay valid."""
    store = api.build_store(_card_cfg(
        {"backend": "file", "backend_args": {"path": str(tmp_path)}}))
    versions = _versions(4)
    handles = _ingest(store, versions)
    keep, v_keep = handles[-1], versions[-1]
    probe = (store.restore_range(keep, 1000, 5000),
             store.stream_length(keep))     # populate the layout cache
    for h in handles[:-1]:
        store.delete(h)
    run = store.compact()
    assert not run.skipped and run.swept_chunks > 0
    assert store.restore(keep) == v_keep
    assert store.restore_range(keep, 1000, 5000) == probe[0] \
        == v_keep[1000:6000]
    assert store.stream_length(keep) == probe[1] == len(v_keep)
    store.close()


def test_reopened_store_serves_ranges_without_decoding_all(tmp_path):
    store = api.build_store(_card_cfg(
        {"backend": "file", "backend_args": {"path": str(tmp_path)}}))
    versions = _versions()
    handles = _ingest(store, versions)
    store.close()

    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "chunker_args": {"avg_size": AVG},
         "backend": "file", "backend_args": {"path": str(tmp_path)}})
    cold = api.build_store(cfg)
    h, v = handles[0], versions[0]
    got = cold.restore_range(h, len(v) // 2, AVG)
    assert got == v[len(v) // 2:len(v) // 2 + AVG]
    # persisted prefix sums: a ranged read must not fetch the whole stream
    assert cold.last_restore.bytes_read < len(v) // 2
    assert cold.last_restore.chunks < len(cold.backend.recipe(h))
    cold.close()


def test_legacy_recipes_without_lengths_still_serve_ranges(tmp_path):
    """Pre-§9 recipe lines (bare id arrays) have no persisted lengths;
    the store falls back to materializing the chunks once."""
    backend = api.FileBackend(tmp_path)
    backend.put_raw(0, b"a" * 100)
    backend.put_raw(1, b"b" * 50)
    h = backend.add_recipe([0, 1, 0])       # legacy signature: no lengths
    backend.close()

    reopened = api.FileBackend(tmp_path)
    assert reopened.recipe_lengths(h) is None
    store = api.DedupStore(api.build_detector(api.DedupConfig.from_dict(
        {"detector": "dedup-only"})), backend=reopened)
    assert store.stream_length(h) == 250
    assert store.restore_range(h, 90, 70) == b"a" * 10 + b"b" * 50 + b"a" * 10
    store.close()


# --- bounded decode cache on the serving path ---------------------------------

def test_file_backend_cache_stays_under_budget_on_large_restore(tmp_path):
    """Restoring a store larger than the decode-cache budget must not
    materialize the dataset in RAM (the seed behaviour): peak cache bytes
    stay under the configured budget, bytes stay identical."""
    budget = 256 << 10
    rng = np.random.default_rng(7)
    # incompressible streams, several multiples of the budget in total
    versions = [rng.integers(0, 256, 384 << 10, np.uint8).tobytes()
                for _ in range(4)]
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "chunker_args": {"avg_size": AVG},
         "backend": "file", "backend_args": {"path": str(tmp_path)}})
    store = api.build_store(cfg)
    handles = [store.ingest(v) and store.reports[-1].handle
               for v in versions]
    store.close()

    cfg.restore_cache_bytes = budget
    cold = api.build_store(cfg)
    assert cold.backend._cache.budget_bytes == budget
    total = sum(len(v) for v in versions)
    assert total > 4 * budget
    for h, v in zip(handles, versions):
        assert cold.restore(h) == v
    assert cold.backend.cache_peak_bytes <= budget
    assert cold.stats.restore_bytes_out == total
    cold.close()


def test_restore_telemetry_cold_then_warm(tmp_path):
    store = api.build_store(_card_cfg(
        {"backend": "file", "backend_args": {"path": str(tmp_path)}}))
    versions = _versions()
    handles = _ingest(store, versions)
    store.close()

    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "chunker_args": {"avg_size": AVG},
         "backend": "file", "backend_args": {"path": str(tmp_path)}})
    cold = api.build_store(cfg)
    h, v = handles[-1], versions[-1]
    assert cold.restore(h) == v
    first = cold.last_restore
    assert first.bytes_read > 0 and first.cache_misses > 0
    assert first.bytes_out == len(v)
    assert cold.restore(h) == v             # warm: chains cached
    second = cold.last_restore
    assert second.cache_hits > 0 and second.bytes_read == 0
    assert cold.stats.restores == 2
    assert cold.stats.restore_bytes_out == 2 * len(v)
    cold.close()


# --- backend parity -----------------------------------------------------------

def _both_backends(tmp_path):
    mem = api.InMemoryBackend()
    fil = api.FileBackend(tmp_path)
    return [("memory", mem), ("file", fil)]


def test_backends_raise_identical_errors_for_bad_handles(tmp_path):
    versions = _versions(2)
    for name, backend in _both_backends(tmp_path):
        store = api.DedupStore(
            api.build_detector(api.DedupConfig.from_dict(
                {"detector": "dedup-only"})), backend=backend)
        h = store.ingest(versions[0]) and store.reports[-1].handle
        for surface in (store.restore,
                        lambda hh: store.restore_iter(hh),
                        lambda hh: store.restore_range(hh, 0, 1),
                        backend.recipe, backend.recipe_lengths):
            with pytest.raises(IndexError):
                surface(h + 99)             # never issued
            with pytest.raises(IndexError):
                surface(-1)                 # no negative aliasing
        store.delete(h)
        for surface in (store.restore,
                        lambda hh: store.restore_iter(hh),
                        lambda hh: store.restore_range(hh, 0, 1),
                        backend.recipe, backend.recipe_lengths):
            with pytest.raises(KeyError):
                surface(h)                  # retired
        store.close()


def _random_chain_backend(backend, rng, n_chunks):
    """Random delta-chain topology: every chunk is raw or a patch against
    an arbitrary earlier chunk (arbitrary fan-out, arbitrary depth)."""
    datas = {}
    for cid in range(n_chunks):
        data = rng.integers(0, 256, int(rng.integers(64, 2048)),
                            np.uint8).tobytes()
        if cid and rng.random() < 0.75:
            base = int(rng.integers(0, cid))
            # borrow runs from the base so the patch is non-trivial
            mix = bytearray(datas[base])
            edit = rng.integers(0, 256, 64, np.uint8).tobytes()
            pos = int(rng.integers(0, max(1, len(mix) - 64)))
            mix[pos:pos + 64] = edit
            data = bytes(mix)
            backend.put_delta(cid, base, delta.encode(data, datas[base]),
                              data=data)
        else:
            backend.put_raw(cid, data)
        datas[cid] = data
    return datas


@pytest.mark.parametrize("seed", range(5))
def test_get_many_matches_get_on_random_chain_topologies(tmp_path, seed):
    """Property test: planned batch materialization is byte-for-byte the
    per-chunk path, over random chain topologies, orders and cache
    states, on both backends (cold reopen for the file one)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 60))
    mem = api.InMemoryBackend()
    fil = api.FileBackend(tmp_path / f"s{seed}", cache_bytes=32 << 10)
    datas_m = _random_chain_backend(mem, np.random.default_rng(seed), n)
    datas_f = _random_chain_backend(fil, np.random.default_rng(seed), n)
    assert datas_m == datas_f
    fil.close()
    cold = api.FileBackend(tmp_path / f"s{seed}", cache_bytes=32 << 10)
    for backend, datas in ((mem, datas_m), (cold, datas_f)):
        for _ in range(4):
            k = int(rng.integers(1, n + 1))
            query = [int(c) for c in rng.integers(0, n, k)]
            want = [datas[c] for c in query]
            assert backend.get_many(query) == want
            assert [backend.get(c) for c in query] == want
    with pytest.raises(KeyError):
        cold.get_many([0, n + 5])
    cold.close()


def test_get_many_failure_leaks_no_pins(tmp_path):
    """A plan that dies mid-decode (corrupt patch) must release every pin
    it took — leaked pins would make cache entries unevictable forever."""
    backend = api.FileBackend(tmp_path, cache_bytes=1 << 10)
    backend.put_raw(0, b"A" * 600)
    patch = delta.encode(b"A" * 590 + b"B" * 10, b"A" * 600)
    backend.put_delta(1, 0, patch)
    backend.flush()
    _, _, offset, _ = backend._index[1]
    with open(tmp_path / "chunks.log", "r+b") as f:
        f.seek(offset)
        f.write(b"\x07")                    # invalid opcode: decode raises
    with pytest.raises(ValueError):
        backend.get_many([1])
    assert not backend._cache._pins
    with open(tmp_path / "chunks.log", "r+b") as f:
        f.seek(offset)
        f.write(patch[:1])                  # repair; backend still serves
    assert backend.get_many([1]) == [b"A" * 590 + b"B" * 10]
    backend.close()


# --- empty stream regression --------------------------------------------------

@pytest.mark.parametrize("detector", ["card", "dedup-only", "finesse"])
def test_empty_stream_commit_and_restore(tmp_path, detector):
    """``ingest(b"")`` must commit a zero-chunk recipe and restore to
    b"" on both staged (card) and legacy detector paths (regression:
    the staged score() crashed on an empty batch)."""
    for backend_extra in ({}, {"backend": "file",
                              "backend_args": {"path": str(
                                  tmp_path / detector)}}):
        d = {"detector": detector, "chunker_args": {"avg_size": AVG}}
        d.update(backend_extra)
        store = api.build_store(api.DedupConfig.from_dict(d))
        if detector == "card":
            store.fit([_versions(1)[0]])
        store.ingest(b"")
        report = store.reports[-1]
        assert (report.bytes_in, report.chunks, report.bytes_stored) == (0, 0, 0)
        h = report.handle
        assert store.restore(h) == b""
        assert list(store.restore_iter(h)) == []
        assert store.restore_range(h, 0, 100) == b""
        assert store.stream_length(h) == 0
        # a later non-empty stream is unaffected
        v = _versions(1)[0]
        store.ingest(v)
        assert store.restore(store.reports[-1].handle) == v
        store.close()


def test_empty_stream_survives_file_reopen(tmp_path):
    cfg = api.DedupConfig.from_dict(
        {"detector": "dedup-only", "chunker_args": {"avg_size": AVG},
         "backend": "file", "backend_args": {"path": str(tmp_path)}})
    store = api.build_store(cfg)
    store.ingest(b"")
    h = store.reports[-1].handle
    store.close()
    reopened = api.build_store(cfg)
    assert reopened.restore(h) == b""
    assert reopened.stream_length(h) == 0
    reopened.close()
