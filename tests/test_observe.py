"""Observability layer (DESIGN.md §12): metrics registry correctness
(per-thread shards, torn-free snapshots, derived views), the Prometheus
and JSON exporters plus the strict round-trip parser, lock wait-time
histograms under forced writer contention, the IoTelemetry explicit-fold
contract for pooled executors, trace spans over real ingest/restore/GC
paths, the fault-retry metrics of the object-store backend, and the
zero-division guards in benchmarks/common."""
import gc
import json
import os
import threading
import time

import pytest

from repro import api
from repro.api import observe
from repro.api.concurrency import IoTelemetry, RWLock
from repro.api.observe import (MetricsRegistry, Tracer,
                               parse_prometheus_text)


# ---------------------------------------------------------------------------
# registry basics


def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    reg.counter("repro_t_ops_total", "ops", labels={"op": "get"}).inc()
    reg.counter("repro_t_ops_total", "ops", labels={"op": "get"}).inc(4)
    reg.counter("repro_t_ops_total", "ops", labels={"op": "put"}).inc(2)
    reg.gauge("repro_t_depth", "queue depth").set(7)
    h = reg.histogram("repro_t_lat_seconds", "latency",
                      bounds=observe.SECONDS_BUCKETS)
    for v in (1e-6, 0.001, 0.5, 100.0):
        h.observe(v)
    snap = reg.snapshot()
    c = snap["repro_t_ops_total"]
    assert c["type"] == "counter"
    by_label = {tuple(sorted(s["labels"].items())): s["value"]
                for s in c["samples"]}
    assert by_label[(("op", "get"),)] == 5
    assert by_label[(("op", "put"),)] == 2
    assert snap["repro_t_depth"]["samples"][0]["value"] == 7
    hist = snap["repro_t_lat_seconds"]["samples"][0]
    assert hist["count"] == 4
    assert hist["sum"] == pytest.approx(100.501001)
    assert sum(n for _, n in hist["buckets"]) == hist["count"]


def test_histogram_bucket_placement_and_overflow():
    reg = MetricsRegistry()
    bounds = observe.log2_bounds(0, 3)          # 1, 2, 4, 8
    h = reg.histogram("repro_t_w", "", bounds=bounds)
    h.observe(1.0)      # le=1 bucket (bisect_left: boundary inclusive)
    h.observe(3.0)      # le=4
    h.observe(999.0)    # +Inf overflow
    sample = reg.snapshot()["repro_t_w"]["samples"][0]
    got = dict(sample["buckets"])
    assert got[1.0] == 1 and got[4.0] == 1
    assert sample["count"] == 3                 # +Inf implied by count


def test_kind_and_bounds_conflicts_raise():
    reg = MetricsRegistry()
    reg.counter("repro_t_x_total", "")
    with pytest.raises(ValueError):
        reg.gauge("repro_t_x_total", "")
    reg.histogram("repro_t_h", "", bounds=observe.COUNT_BUCKETS)
    with pytest.raises(ValueError):
        reg.histogram("repro_t_h", "", bounds=observe.BYTES_BUCKETS)


def test_derived_view_and_callback():
    reg = MetricsRegistry()
    state = {"n": 0}

    def export():
        reg.counter("repro_t_view_total", "view").set_total(state["n"])
    reg.register_callback(export)
    state["n"] = 41
    # a native inc on the same series adds on top of the exported view
    reg.counter("repro_t_view_total", "view").inc()
    [s] = reg.snapshot()["repro_t_view_total"]["samples"]
    assert s["value"] == 42
    state["n"] = 100
    [s] = reg.snapshot()["repro_t_view_total"]["samples"]
    assert s["value"] == 101


# ---------------------------------------------------------------------------
# concurrency: exact totals, no torn reads


def test_concurrent_counters_exact():
    reg = MetricsRegistry()
    threads_n, per_thread = 8, 10_000

    def worker():
        c = reg.counter("repro_t_hammer_total", "")
        for _ in range(per_thread):
            c.inc()
        reg.fold_current()

    ts = [threading.Thread(target=worker) for _ in range(threads_n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(60)
    [s] = reg.snapshot()["repro_t_hammer_total"]["samples"]
    assert s["value"] == threads_n * per_thread


def test_snapshot_while_hammering_is_consistent():
    """A reader snapshotting mid-hammer must never see a torn histogram
    (count != bucket sum) and counter/histogram totals must be
    monotonic across snapshots."""
    reg = MetricsRegistry()
    stop = threading.Event()

    def hammer():
        c = reg.counter("repro_t_mono_total", "")
        h = reg.histogram("repro_t_mono_seconds", "",
                          bounds=observe.SECONDS_BUCKETS)
        while not stop.is_set():
            for _ in range(100):
                c.inc()
                h.observe(0.001)

    ts = [threading.Thread(target=hammer, daemon=True) for _ in range(4)]
    for t in ts:
        t.start()
    last_c = last_n = -1.0
    for _ in range(50):
        snap = reg.snapshot()
        fam = snap.get("repro_t_mono_seconds")
        if fam:
            [s] = fam["samples"]
            assert sum(n for _, n in s["buckets"]) == s["count"]
            assert s["count"] >= last_n
            last_n = s["count"]
        cfam = snap.get("repro_t_mono_total")
        if cfam:
            [s] = cfam["samples"]
            assert s["value"] >= last_c
            last_c = s["value"]
    stop.set()
    for t in ts:
        t.join(60)
    assert last_c > 0 and last_n > 0


def test_lock_wait_histogram_under_writer_contention():
    """A reader blocked behind a held write lock lands in a visible
    wait-time bucket; uncontended acquires land near zero."""
    reg = MetricsRegistry()

    def obs(side, seconds):
        reg.histogram("repro_lock_wait_seconds", "",
                      labels={"side": side},
                      bounds=observe.SECONDS_BUCKETS).observe(seconds)

    lock = RWLock(observer=obs)
    with lock.read():       # uncontended
        pass
    lock.acquire_write()
    waited = []

    def reader():
        t0 = time.perf_counter()
        with lock.read():
            waited.append(time.perf_counter() - t0)

    t = threading.Thread(target=reader)
    t.start()
    time.sleep(0.05)
    lock.release_write()
    t.join(60)
    reg.fold_current()
    samples = {s["labels"]["side"]: s for s in
               reg.snapshot()["repro_lock_wait_seconds"]["samples"]}
    assert samples["read"]["count"] == 2
    assert samples["write"]["count"] == 1
    # the blocked read's wait dominates the histogram sum
    assert samples["read"]["sum"] >= 0.9 * waited[0] >= 0.02


# ---------------------------------------------------------------------------
# IoTelemetry explicit-fold contract (satellite: pooled executors)


def test_iotelemetry_fold_current_exact_and_idempotent():
    tel = IoTelemetry()

    def task():
        c = tel.local()
        c.bytes_read += 100
        c.requests += 1
        tel.fold_current()
        tel.fold_current()              # idempotent
        c2 = tel.local()                # fresh record after the fold
        assert c2 is not c
        c2.bytes_read += 11
        tel.fold_current()

    t = threading.Thread(target=task)
    t.start()
    t.join(60)
    gc.collect()                        # the GC fold must not double-count
    assert tel.total("bytes_read") == 111
    assert tel.total("requests") == 1


def test_iotelemetry_scoped_folds_on_exit():
    tel = IoTelemetry()

    def task():
        with tel.scoped() as c:
            c.bytes_read += 7
        # folded immediately: a pool thread that never exits still
        # published its counters
        assert tel.total("bytes_read") == 7

    t = threading.Thread(target=task)
    t.start()
    t.join(60)
    assert tel.total("bytes_read") == 7


def test_registry_fold_current_from_pool_thread():
    reg = MetricsRegistry()
    from concurrent.futures import ThreadPoolExecutor
    with ThreadPoolExecutor(max_workers=1) as ex:
        def task():
            reg.counter("repro_t_pool_total", "").inc(5)
            reg.fold_current()
        ex.submit(task).result(60)
        # pool thread still alive, but the fold already published
        [s] = reg.snapshot()["repro_t_pool_total"]["samples"]
        assert s["value"] == 5


# ---------------------------------------------------------------------------
# exporters + strict parser


def test_prometheus_label_escaping_roundtrip():
    reg = MetricsRegistry()
    nasty = 'a\\b"c\nd'
    reg.counter("repro_t_esc_total", 'help with "quotes"\nand newline',
                labels={"path": nasty}).inc(3)
    text = reg.to_prometheus()
    assert '\\\\b\\"c\\nd' in text
    parsed = parse_prometheus_text(text)
    [(name, labels, value)] = [s for s in parsed["samples"]
                               if s[0] == "repro_t_esc_total"]
    assert labels == {"path": nasty} and value == 3.0


def test_prometheus_histogram_exposition_shape():
    reg = MetricsRegistry()
    h = reg.histogram("repro_t_sh_seconds", "x",
                      bounds=observe.log2_bounds(0, 2))
    h.observe(1.5)
    h.observe(10.0)
    text = reg.to_prometheus()
    assert "# TYPE repro_t_sh_seconds histogram" in text
    parsed = parse_prometheus_text(text)
    buckets = {l["le"]: v for n, l, v in parsed["samples"]
               if n == "repro_t_sh_seconds_bucket"}
    assert buckets["2"] == 1.0          # cumulative
    assert buckets["4"] == 1.0
    assert buckets["+Inf"] == 2.0
    [count] = [v for n, _, v in parsed["samples"]
               if n == "repro_t_sh_seconds_count"]
    assert count == 2.0


@pytest.mark.parametrize("bad", [
    "repro_x_total{le=} 1",             # malformed label
    "repro_x_total 1",                  # sample without a TYPE line
    "# TYPE repro_x_total counter\n9bad_name 1",
    '# TYPE repro_x_total counter\nrepro_x_total{a="b} 1',
])
def test_parser_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_prometheus_text(bad)


def test_json_snapshot_loads_clean():
    reg = MetricsRegistry()
    reg.counter("repro_t_j_total", "").inc()
    reg.histogram("repro_t_j_seconds", "",
                  bounds=observe.SECONDS_BUCKETS).observe(0.5)
    snap = json.loads(reg.to_json())
    assert snap["repro_t_j_total"]["type"] == "counter"
    [s] = snap["repro_t_j_seconds"]["samples"]
    assert s["count"] == 1 == sum(n for _, n in s["buckets"])


# ---------------------------------------------------------------------------
# tracer


def test_tracer_ring_bound_and_sink_roundtrip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tr = Tracer(ring_events=4, path=path)
    for i in range(10):
        tr.record("op", 0.001, i=i)
    ring = tr.events()
    assert len(ring) == 4 and [e["i"] for e in ring] == [6, 7, 8, 9]
    tr.close()
    with open(path) as f:
        sink = [json.loads(line) for line in f if line.strip()]
    assert len(sink) == 10              # sink keeps everything
    assert all(e["op"] == "op" and "tid" in e and "s" in e for e in sink)


def test_tracer_span_parent_links():
    tr = Tracer(ring_events=16)
    with tr.span("parent", phase="x") as labels:
        labels["extra"] = 1
    parent_id = tr.events()[-1]["id"]
    child = tr.record("parent.child", 0.5, parent=parent_id)
    events = {e["op"]: e for e in tr.events()}
    assert events["parent"]["extra"] == 1
    assert events["parent.child"]["parent"] == parent_id
    assert child != parent_id
    assert tr.ops() == {"parent": 1, "parent.child": 1}


# ---------------------------------------------------------------------------
# config knobs


def test_config_trace_knobs_roundtrip(tmp_path):
    cfg = api.DedupConfig.from_dict({
        "detector": "dedup-only",
        "trace_path": str(tmp_path / "t.jsonl"),
        "trace_ring_events": 64})
    assert cfg.trace_ring_events == 64
    with pytest.raises(TypeError):
        api.DedupConfig.from_dict({"detector": "dedup-only",
                                   "trace_path": 7})
    with pytest.raises(ValueError):
        api.DedupConfig.from_dict({"detector": "dedup-only",
                                   "trace_ring_events": -1})


# ---------------------------------------------------------------------------
# acceptance: instrumented store paths (the ISSUE's criterion)


def _traced_store(tmp_path, **extra):
    cfg = api.DedupConfig.from_dict({
        "detector": "dedup-only",
        "chunker_args": {"avg_size": 4096},
        "backend": "file",
        "backend_args": {"path": str(tmp_path / "containers")},
        "trace_ring_events": 1024,
        **extra})
    return api.build_store(cfg)


def test_ingest_metrics_and_spans(tmp_path):
    store = _traced_store(tmp_path)
    data = os.urandom(64 << 10)
    with store.open_stream() as s:
        s.write(data)
    parsed = parse_prometheus_text(store.metrics().to_prometheus())
    assert parsed["types"]["repro_ingest_stage_seconds"] == "histogram"
    assert parsed["types"]["repro_ingest_commits_total"] == "counter"
    assert parsed["types"]["repro_store_dcr"] == "gauge"
    stages = {l["stage"] for n, l, v in parsed["samples"]
              if n == "repro_ingest_stage_seconds_count" and v >= 1}
    assert stages == {"chunk", "extract", "score", "observe", "delta",
                      "store"}
    ops = store.observe.tracer.ops()
    assert ops["ingest"] == 1
    for stage in ("chunk", "extract", "score", "observe", "delta",
                  "store"):
        assert ops[f"ingest.{stage}"] == 1, stage
    store.close()


def test_restore_metrics_cache_hits_and_spans(tmp_path):
    store = _traced_store(tmp_path)
    data = os.urandom(64 << 10)
    with store.open_stream() as s:
        s.write(data)
    h = s.report.handle
    assert store.restore(h) == data     # cold
    assert store.restore(h) == data     # warm: decode-cache hits
    parsed = parse_prometheus_text(store.metrics().to_prometheus())
    assert parsed["types"]["repro_restore_stage_seconds"] == "histogram"
    assert parsed["types"]["repro_restore_requests"] == "histogram"
    by = {(n, tuple(sorted(l.items()))): v
          for n, l, v in parsed["samples"]}
    assert by[("repro_restore_ops_total", (("surface", "full"),))] == 2
    assert by[("repro_reader_cache_lookups_total",
               (("outcome", "hit"),))] > 0
    stages = {l["stage"] for n, l, v in parsed["samples"]
              if n == "repro_restore_stage_seconds_count" and v >= 1}
    assert stages == {"total", "read", "decode"}
    ops = store.observe.tracer.ops()
    for op in ("restore", "restore.plan", "restore.read",
               "restore.decode", "restore.prefetch"):
        assert ops[op] == 2, op
    restores = [e for e in store.observe.tracer.events()
                if e["op"] == "restore"]
    assert restores[-1]["hit_ratio"] > 0        # warm pass hit the cache
    assert restores[-1]["surface"] == "full"
    store.close()


def test_restore_surfaces_labelled(tmp_path):
    store = _traced_store(tmp_path)
    data = os.urandom(48 << 10)
    with store.open_stream() as s:
        s.write(data)
    h = s.report.handle
    assert b"".join(store.restore_iter(h)) == data
    assert store.restore_range(h, 1000, 2000) == data[1000:3000]
    by = {tuple(sorted(l.items())): v for n, l, v in
          parse_prometheus_text(store.metrics().to_prometheus())["samples"]
          if n == "repro_restore_ops_total"}
    assert by[(("surface", "iter"),)] == 1
    assert by[(("surface", "range"),)] == 1
    store.close()


def test_gc_metrics_and_spans(tmp_path):
    store = _traced_store(tmp_path)
    for _ in range(2):
        with store.open_stream() as s:
            s.write(os.urandom(48 << 10))
    store.delete(s.report.handle)
    store.collect()
    store.compact()
    parsed = parse_prometheus_text(store.metrics().to_prometheus())
    phases = {l["phase"] for n, l, v in parsed["samples"]
              if n == "repro_gc_phase_seconds_count" and v >= 1}
    assert {"delete", "collect", "compact", "compact.sizing",
            "compact.rewrite"} <= phases
    by = {n: v for n, l, v in parsed["samples"] if not l}
    assert by["repro_gc_freed_bytes_total"] > 0
    ops = store.observe.tracer.ops()
    for op in ("gc.delete", "gc.collect", "gc.compact"):
        assert ops.get(op, 0) >= 1, op
    store.close()


def test_store_views_match_stats(tmp_path):
    store = _traced_store(tmp_path)
    with store.open_stream() as s:
        s.write(os.urandom(64 << 10))
    stats = store.stats
    by = {(n, tuple(sorted(l.items()))): v for n, l, v in
          parse_prometheus_text(store.metrics().to_prometheus())["samples"]}
    assert by[("repro_ingest_bytes_total", (("dir", "in"),))] \
        == stats.bytes_in
    assert by[("repro_ingest_bytes_total", (("dir", "stored"),))] \
        == stats.bytes_stored
    assert by[("repro_store_dcr", ())] == pytest.approx(stats.dcr)
    store.close()


def test_tracing_disabled_by_default(tmp_path):
    cfg = api.DedupConfig.from_dict({
        "detector": "dedup-only",
        "chunker_args": {"avg_size": 4096}})
    store = api.build_store(cfg)
    assert store.observe.tracer is None
    with store.open_stream() as s:
        s.write(os.urandom(16 << 10))
    assert store.restore(s.report.handle)
    # metrics still collected even with tracing off
    assert "repro_ingest_commits_total" in store.metrics().snapshot()
    store.close()


def test_objectstore_retry_metrics(tmp_path):
    cfg = api.DedupConfig.from_dict({
        "detector": "dedup-only",
        "chunker_args": {"avg_size": 4096},
        "backend": "objectstore",
        "backend_args": {"path": str(tmp_path / "obj")},
        "trace_ring_events": 512})
    store = api.build_store(cfg)
    data = os.urandom(64 << 10)
    with store.open_stream() as s:
        s.write(data)
    h = s.report.handle
    store.close()

    store = api.build_store(api.DedupConfig.from_dict({
        "detector": "dedup-only",
        "chunker_args": {"avg_size": 4096},
        "backend": "objectstore",
        "backend_args": {"path": str(tmp_path / "obj"),
                         # fault every other GET ordinal: each call's
                         # first attempt fails, its retry succeeds.
                         # Ordinal 1 fires during the reopen _scan
                         # (before observability is bound), later ones
                         # during the restore — which is the point: the
                         # bound metrics must catch those
                         "fault_hook":
                             api.FaultSchedule({"get":
                                                list(range(1, 64, 2))}),
                         "retry_backoff": 0.001},
        "trace_ring_events": 512}))
    assert store.restore(h) == data
    assert store.backend.retries >= 1
    parsed = parse_prometheus_text(store.metrics().to_prometheus())
    by = {(n, tuple(sorted(l.items()))): v
          for n, l, v in parsed["samples"]}
    assert by[("repro_objstore_retries_total", ())] == \
        store.backend.retries
    assert by[("repro_objstore_backoff_seconds_total", ())] > 0
    assert by[("repro_objstore_request_seconds_count",
               (("op", "get"),))] >= 1
    assert by[("repro_objstore_get_bytes_count", ())] >= 1
    retry_spans = [e for e in store.observe.tracer.events()
                   if e["op"] == "objstore.retry"]
    assert retry_spans and retry_spans[0]["client_op"] == "get"
    store.close()


def test_reader_run_shape_histograms(tmp_path):
    store = _traced_store(tmp_path)
    with store.open_stream() as s:
        s.write(os.urandom(96 << 10))
    h = s.report.handle
    store.close()
    store = _traced_store(tmp_path)     # cold decode cache: real reads
    assert store.restore(h)
    parsed = parse_prometheus_text(store.metrics().to_prometheus())
    assert parsed["types"]["repro_reader_run_bytes"] == "histogram"
    assert parsed["types"]["repro_reader_run_extents"] == "histogram"
    by = {n: v for n, l, v in parsed["samples"] if n.endswith("_count")}
    assert by["repro_reader_run_bytes_count"] >= 1
    assert by["repro_reader_run_extents_count"] >= 1
    store.close()


def test_trace_sink_written_through_store(tmp_path):
    trace = str(tmp_path / "trace.jsonl")
    store = _traced_store(tmp_path, trace_path=trace)
    with store.open_stream() as s:
        s.write(os.urandom(32 << 10))
    assert store.restore(s.report.handle)
    n_ring = len(store.observe.tracer.events())
    store.close()
    with open(trace) as f:
        sink = [json.loads(line) for line in f if line.strip()]
    assert len(sink) == n_ring >= 2
    ops = {e["op"] for e in sink}
    assert "ingest" in ops and "restore" in ops


def test_observe_cli_dump(tmp_path, capsys):
    trace = str(tmp_path / "trace.jsonl")
    tr = Tracer(ring_events=8, path=trace)
    tr.record("alpha", 0.25, k=1)
    tr.record("alpha", 0.75)
    tr.record("beta", 0.1)
    tr.close()
    assert observe.main(["dump", trace]) == 0
    out = capsys.readouterr().out
    assert "# 3 spans" in out and "alpha" in out and "beta" in out


# ---------------------------------------------------------------------------
# satellite: zero-division guards in the bench helpers


def test_bench_helpers_zero_division_guards():
    from benchmarks import common
    assert common.mbps(0, 0.0) == 0.0
    assert common.mbps(1 << 20, 0.0) == 0.0
    assert common.mbps(1 << 20, 1.0) == 1.0
    assert common.ratio(5, 0) == 0.0
    assert common.ratio(6, 3) == 2.0
    assert common.fmt_ratio(5, 0) == "n/a"
    assert common.fmt_ratio(1, 3, places=3) == "0.333"
