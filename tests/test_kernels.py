"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing
from repro.kernels import gear_hash, ops, ref, shingle_embed, sim_topk


class TestWindowedSum:
    @pytest.mark.parametrize("r,c", [(1, 256), (3, 512), (7, 8192), (2, 128)])
    @pytest.mark.parametrize("taps", [4, 32, 48])
    def test_vs_ref(self, r, c, taps):
        if c < taps:
            pytest.skip("row narrower than window")
        rng = np.random.Generator(np.random.PCG64(r * 1000 + c + taps))
        g = rng.integers(0, 2**32, size=(r, c), dtype=np.uint32)
        weights = tuple(int(w) for w in hashing.poly_powers(taps))
        got = gear_hash.windowed_sum(jnp.asarray(g), weights, interpret=True)
        want = ref.windowed_sum_ref(jnp.asarray(g), np.asarray(weights, np.uint32))
        assert np.array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("n", [100, 8192, 8193, 40000])
    def test_gear_ops_vs_serial(self, n):
        rng = np.random.Generator(np.random.PCG64(n))
        data = rng.integers(0, 256, size=n, dtype=np.uint8)
        got = np.asarray(ops.gear_hashes(jnp.asarray(data)))
        assert np.array_equal(got, hashing.gear_hashes_np(data))

    @pytest.mark.parametrize("window", [16, 48])
    def test_rabin_ops_vs_np(self, window):
        rng = np.random.Generator(np.random.PCG64(window))
        data = rng.integers(0, 256, size=20000, dtype=np.uint8)
        got = np.asarray(ops.rabin_fps(jnp.asarray(data), window))
        assert np.array_equal(got, hashing.rabin_fps_np(data, window))


class TestShingleEmbed:
    @pytest.mark.parametrize("b,s,m", [(1, 61, 64), (8, 61, 64), (13, 61, 50),
                                       (32, 200, 80), (7, 130, 40)])
    def test_vs_ref(self, b, s, m):
        rng = np.random.Generator(np.random.PCG64(b * 100 + s + m))
        ids = rng.integers(0, 2**32, size=(b, s), dtype=np.uint32)
        mask = rng.random((b, s)) < 0.8
        a_np, b_np = hashing.multiply_shift_params(m)
        a, bb = jnp.asarray(a_np), jnp.asarray(b_np)
        got = shingle_embed.shingle_embed_sum(
            jnp.asarray(ids), jnp.asarray(mask.astype(np.float32)),
            a.reshape(1, -1), bb.reshape(1, -1), interpret=True)
        want = ref.shingle_embed_ref(jnp.asarray(ids), jnp.asarray(mask), a, bb)
        # ref divides by count; kernel returns raw sum
        cnt = np.maximum(mask.sum(-1, keepdims=True), 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want) * cnt,
                                   rtol=1e-5, atol=1e-5)

    def test_all_masked(self):
        ids = jnp.zeros((4, 61), jnp.uint32)
        mask = jnp.zeros((4, 61), jnp.float32)
        a_np, b_np = hashing.multiply_shift_params(64)
        out = ops.shingle_embed(ids, mask, jnp.asarray(a_np), jnp.asarray(b_np),
                                normalize=False)
        assert np.allclose(np.asarray(out), 0.0)


class TestSimTopk:
    @pytest.mark.parametrize("b,n,d", [(1, 100, 50), (8, 1024, 50), (5, 3000, 64),
                                       (16, 257, 80), (9, 5000, 40)])
    def test_vs_ref(self, b, n, d):
        rng = np.random.Generator(np.random.PCG64(b * 7 + n + d))
        q = rng.standard_normal((b, d)).astype(np.float32)
        idx = rng.standard_normal((n, d)).astype(np.float32)
        s, a = sim_topk.sim_topk(jnp.asarray(q), jnp.asarray(idx), interpret=True)
        sr, ar = ref.sim_topk_ref(jnp.asarray(q), jnp.asarray(idx))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-4, atol=1e-5)
        assert np.array_equal(np.asarray(a), np.asarray(ar))

    def test_padding_never_wins(self):
        """All-negative scores: padded -inf rows must not be selected."""
        q = -np.eye(4, 16, dtype=np.float32)
        idx = np.eye(3, 16, dtype=np.float32)  # pads to 128+
        s, a = sim_topk.sim_topk(jnp.asarray(q), jnp.asarray(idx), interpret=True)
        assert (np.asarray(a) < 3).all()


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,kv,tq,tk,hd,causal", [
        (2, 8, 4, 300, 300, 32, True),
        (1, 4, 4, 512, 512, 64, True),
        (2, 8, 2, 128, 640, 32, False),
        (1, 6, 3, 257, 257, 16, True),   # ragged vs block size
    ])
    def test_vs_ref(self, b, h, kv, tq, tk, hd, causal):
        from repro.kernels import flash_attn
        rng = np.random.Generator(np.random.PCG64(b * h + tq))
        q = jnp.asarray(rng.standard_normal((b, h, tq, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, kv, tk, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, kv, tk, hd)), jnp.float32)
        got = flash_attn.flash_attention(q, k, v, causal=causal,
                                         block_q=128, block_k=128,
                                         interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        from repro.kernels import flash_attn
        rng = np.random.Generator(np.random.PCG64(9))
        q = jnp.asarray(rng.standard_normal((1, 8, 256, 64)), jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.bfloat16)
        got = flash_attn.flash_attention(q, k, v, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2, atol=2e-2)
